//! §Perf probe: measures each optimization against its unoptimized
//! alternative (both kept in-tree), producing the EXPERIMENTS.md §Perf
//! before/after table. See `cargo bench --bench micro_primitives` for the
//! calibration-grade numbers.
use privlogit::bigint::{BigUint, Montgomery, RandomSource};
use privlogit::crypto::paillier::{ChaChaSource, Keypair};
use privlogit::crypto::rng::ChaChaRng;
use privlogit::gc::backend::CountBackend;
use privlogit::gc::word::FixedFmt;
use privlogit::gc::GcProgram;
use privlogit::mpc::circuits::{tri_len, InverseMaskedProg, SolveProg};
use std::time::Instant;

fn time_it<T>(label: &str, reps: usize, mut f: impl FnMut() -> T) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..reps { std::hint::black_box(f()); }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{label:<40} {per:.3e} s/op");
    per
}

fn main() {
    let mut rng = ChaChaRng::from_u64_seed(5150);
    let kp = Keypair::generate(1024, &mut rng);
    let n2 = kp.pk.n2.clone();
    let base = rng.below(&n2);
    let exp = rng.below(&kp.pk.n);

    // 1. modpow: naive square-and-multiply with divrem reduction vs Montgomery
    let naive = time_it("modpow naive (divrem sq-and-mul)", 3, || {
        let b = base.rem(&n2);
        let mut acc = BigUint::one();
        for i in (0..exp.bit_len()).rev() {
            acc = acc.mul_mod(&acc, &n2);
            if exp.bit(i) { acc = acc.mul_mod(&b, &n2); }
        }
        acc
    });
    let mont = Montgomery::new(&n2);
    let fast = time_it("modpow Montgomery CIOS + 4-bit window", 10, || mont.pow(&base, &exp));
    println!("  -> modpow speedup {:.1}x\n", naive / fast);

    // 2. decryption: plain (lambda over n^2) vs CRT
    let c = kp.pk.encrypt(&BigUint::from_u64(123456), &mut ChaChaSource(&mut rng));
    let plain = time_it("decrypt plain (lambda mod n^2)", 10, || kp.sk.decrypt_plain(&c));
    let crt = time_it("decrypt CRT (Garner)", 20, || kp.sk.decrypt(&c));
    println!("  -> decrypt speedup {:.1}x\n", plain / crt);

    // 3. scalar mul: full-range exponent vs small signed exponent
    let full_k = rng.below(&kp.pk.n);
    let tfull = time_it("scalar_mul full exponent", 10, || kp.pk.scalar_mul(&c, &full_k));
    let small_k = BigUint::from_u64(1 << 30);
    let tsmall =
        time_it("scalar_mul small (f-bit) exponent", 50, || kp.pk.scalar_mul(&c, &small_k));
    println!("  -> scalar speedup {:.1}x (PL-Local's primitive)\n", tfull / tsmall);

    // 4. inverse circuit: naive p-column solves vs triangular T=L^-1,Z=T'T
    let fmt = FixedFmt::DEFAULT;
    for p in [12usize, 24] {
        let prog = InverseMaskedProg { p, fmt };
        let mut cb = CountBackend::default();
        let ga = vec![None; prog.inputs_garbler()];
        let ea = vec![None; prog.inputs_evaluator()];
        prog.run(&mut cb, &ga, &ea);
        let structured = cb.ands;
        // naive: cholesky + p full tri-solves = cholesky + p * solve-body.
        let sp = SolveProg { p, fmt };
        let mut cs = CountBackend::default();
        let ga2 = vec![None; sp.inputs_garbler()];
        let ea2 = vec![None; sp.inputs_evaluator()];
        sp.run(&mut cs, &ga2, &ea2);
        let chol = {
            let cp = privlogit::mpc::circuits::CholeskyShareProg { p, fmt };
            let mut cc = CountBackend::default();
            let ga3 = vec![None; cp.inputs_garbler()];
            let ea3 = vec![None; cp.inputs_evaluator()];
            cp.run(&mut cc, &ga3, &ea3);
            cc.ands
        };
        let naive_gates = chol + p as u64 * cs.ands;
        println!(
            "inverse p={p}: structured {structured} ANDs vs naive {naive_gates} ANDs ({:.1}x), tri_len={}",
            naive_gates as f64 / structured as f64, tri_len(p)
        );
    }
}
