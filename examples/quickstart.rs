//! Quickstart — the end-to-end driver.
//!
//! Runs the full three-layer system on the Wine workload (6 497 × 12,
//! the paper's smallest real study) with **real cryptography end to end**:
//!
//! * node statistics through the PJRT runtime executing the AOT-compiled
//!   JAX/Pallas artifacts (falls back to the rust engine if
//!   `make artifacts` has not been run);
//! * Paillier encryption + aggregation between nodes and the Center;
//! * garbled-circuit Cholesky/solve between the two Center servers;
//! * the PrivLogit-Local protocol (Algorithm 3) against the plaintext
//!   ground truth, reporting iteration count, runtime and R².
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::data::{load_workload, workload};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::metrics::{beta_preview, render_report};
use privlogit::mpc::RealFabric;
use privlogit::optim::{fit, sigmoid, Method, OptimConfig};
use privlogit::protocols::{run_privlogit_local, ProtocolConfig};
use privlogit::runtime;

fn main() {
    let w = workload("Wine").expect("paper suite");
    let data = load_workload(w);
    let orgs = 4;
    let parts = data.partition(orgs);
    println!(
        "Wine stand-in: n={} p={} split across {orgs} organizations",
        data.n(),
        data.p()
    );

    // Ground truth: plaintext distributed Newton (the paper's oracle).
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );
    println!(
        "plaintext Newton: {} iterations, beta {}",
        truth.iterations,
        beta_preview(&truth.beta)
    );

    // Secure run: real Paillier (1024-bit) + real garbled circuits.
    let engine = runtime::default_engine();
    println!("node engine: {}", engine.label());
    let mut fleet = LocalFleet::new(parts.clone(), engine);
    let mut fab = RealFabric::new(1024, FixedFmt::DEFAULT, 7);
    let report = run_privlogit_local(&mut fab, &mut fleet, &cfg).expect("secure run");
    print!("{}", render_report(&report));
    println!("  beta: {}", beta_preview(&report.beta));

    let r2 = r_squared(&report.beta, &truth.beta);
    println!("accuracy vs plaintext Newton: R² = {r2:.6}");
    assert!(r2 > 0.9999, "secure run must reproduce the plaintext optimum");

    // Use the model: training-set classification accuracy.
    let mut correct = 0usize;
    for i in 0..data.n() {
        let z: f64 = data.x.row(i).iter().zip(&report.beta).map(|(a, b)| a * b).sum();
        let pred = if sigmoid(z) >= 0.5 { 1.0 } else { 0.0 };
        if pred == data.y[i] {
            correct += 1;
        }
    }
    println!(
        "training accuracy: {:.1}% ({} / {})",
        100.0 * correct as f64 / data.n() as f64,
        correct,
        data.n()
    );
    println!("quickstart OK");
}
