//! Figure-2 style accuracy check with real cryptography.
//!
//! Fits the Wine workload with both secure PrivLogit protocols (real
//! Paillier + garbled circuits) and prints the QQ pairs of secure vs
//! plaintext-Newton coefficients, plus R² — the paper's Figure 2 shows
//! all points on the diagonal with R² = 1.00.
//!
//! ```sh
//! cargo run --release --example accuracy_qq
//! ```

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::data::{load_workload, workload};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::mpc::RealFabric;
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

fn main() {
    let data = load_workload(workload("Wine").unwrap());
    let parts = data.partition(4);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    let mut fits = Vec::new();
    for proto in [Protocol::PrivLogitHessian, Protocol::PrivLogitLocal] {
        let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut fab = RealFabric::new(1024, FixedFmt::DEFAULT, 1234);
        let rep = proto.run(&mut fab, &mut fleet, &cfg).expect("protocol run");
        fits.push((proto.name(), rep.beta));
    }

    println!("QQ pairs (secure vs ground-truth Newton), Wine p={}:", data.p());
    println!(
        "{:>4} {:>12} {:>18} {:>18}",
        "j", "newton", "privlogit-hessian", "privlogit-local"
    );
    for j in 0..data.p() {
        println!(
            "{:>4} {:>12.6} {:>18.6} {:>18.6}",
            j, truth.beta[j], fits[0].1[j], fits[1].1[j]
        );
    }
    for (name, beta) in &fits {
        let r2 = r_squared(beta, &truth.beta);
        println!("{name}: R² = {r2:.6}");
        assert!(r2 > 0.9999, "Fig. 2 claim: perfect correlation");
    }
    println!("accuracy_qq OK (paper Fig. 2: R² = 1.00)");
}
