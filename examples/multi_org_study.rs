//! Multi-organization consortium study — the paper's motivating scenario.
//!
//! A Loans-scale collaborative study (24 000 × 33) across 10 independent
//! organizations, comparing all three secure protocols. Runs over the
//! *threaded* node fleet (one worker per organization) so node compute is
//! genuinely parallel, with the backend auto-selected (modeled at p=33 —
//! a real garbled Newton run at this size takes tens of minutes; use
//! `--backend real` via the CLI for the full-crypto version).
//!
//! ```sh
//! cargo run --release --example multi_org_study
//! ```

use privlogit::coordinator::fleet::ThreadedFleet;
use privlogit::data::{load_workload, workload};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::metrics::render_report;
use privlogit::mpc::ModelFabric;
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};

fn main() {
    let w = workload("Loans").expect("paper suite");
    let data = load_workload(w);
    let orgs = 10;
    let parts = data.partition(orgs);
    println!(
        "Loans consortium: n={} p={} across {orgs} organizations (paper n={})",
        data.n(),
        data.p(),
        w.paper_n
    );

    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    let mut rows = Vec::new();
    for proto in Protocol::ALL {
        let mut fleet = ThreadedFleet::spawn(parts.clone());
        let mut fab = ModelFabric::new(2048, FixedFmt::DEFAULT);
        let rep = proto.run(&mut fab, &mut fleet, &cfg).expect("protocol run");
        let r2 = r_squared(&rep.beta, &truth.beta);
        println!("{}", render_report(&rep));
        assert!(r2 > 0.9999, "{}: R² = {r2}", proto.name());
        rows.push((proto.name(), rep.iterations, rep.total_secs, rep.setup_secs));
    }

    println!("\nsummary (paper Table 2 row: Loans — 6/17 iters, 492/260/104 s):");
    println!(
        "{:<20} {:>6} {:>12} {:>10} {:>12}",
        "protocol", "iters", "total (s)", "setup (s)", "vs newton"
    );
    let newton_total = rows[0].2;
    for (name, iters, total, setup) in &rows {
        println!(
            "{:<20} {:>6} {:>12.1} {:>10.1} {:>11.2}x",
            name,
            iters,
            total,
            setup,
            newton_total / total
        );
    }
    assert!(rows[2].2 < rows[1].2 && rows[1].2 < rows[0].2, "Table 2 ordering");
    println!("multi_org_study OK");
}
