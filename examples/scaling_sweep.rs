//! Dimensionality sweep — the Fig. 3 / Fig. 4 trends in one run.
//!
//! Sweeps p over the paper's SimuX range on the modeled backend and
//! prints, per dimension: iteration counts (Newton vs PrivLogit, Fig. 3),
//! total runtimes and the relative speedups of both PrivLogit protocols
//! over the secure Newton baseline (Fig. 4).
//!
//! ```sh
//! cargo run --release --example scaling_sweep
//! ```

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::data::synthesize;
use privlogit::gc::word::FixedFmt;
use privlogit::mpc::ModelFabric;
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

fn main() {
    let cfg = ProtocolConfig::default();
    println!(
        "{:>5} | {:>6} {:>6} | {:>10} {:>10} {:>10} | {:>8} {:>8}",
        "p", "itN", "itPL", "newton(s)", "plh(s)", "pll(s)", "plh-x", "pll-x"
    );
    for p in [10usize, 20, 33, 50, 75, 100] {
        let d = synthesize(&format!("sweep{p}"), 4000, p, 777 + p as u64);
        let parts = d.partition(5);
        let mut results = Vec::new();
        for proto in Protocol::ALL {
            let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
            let mut fab = ModelFabric::new(2048, FixedFmt::DEFAULT);
            let rep = proto.run(&mut fab, &mut fleet, &cfg).expect("protocol run");
            assert!(rep.converged, "{} p={p}", proto.name());
            results.push(rep);
        }
        let (n, h, l) = (&results[0], &results[1], &results[2]);
        println!(
            "{:>5} | {:>6} {:>6} | {:>10.1} {:>10.1} {:>10.1} | {:>7.2}x {:>7.2}x",
            p,
            n.iterations,
            h.iterations,
            n.total_secs,
            h.total_secs,
            l.total_secs,
            n.total_secs / h.total_secs,
            n.total_secs / l.total_secs,
        );
        assert!(l.total_secs <= n.total_secs, "PL-Local never slower (p={p})");
    }
    println!("scaling_sweep OK (paper Fig. 4: PL-Local always fastest, PL-Hessian usually faster)");
}
