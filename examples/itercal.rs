//! Calibration scratch: iteration counts per workload vs the paper's
//! Table 2 column (used to tune `Workload::sigma2`).
use privlogit::data::{load_workload, WORKLOADS};
use privlogit::optim::{fit_single, Method, OptimConfig};
fn main() {
    let cfg = OptimConfig::default();
    println!("{:<10} {:>4} | paper N/PL | ours N/PL", "dataset", "p");
    for w in WORKLOADS {
        let d = load_workload(*w);
        let n = fit_single(&d, Method::Newton, cfg).iterations;
        let pl = fit_single(&d, Method::PrivLogit, cfg).iterations;
        println!(
            "{:<10} {:>4} |  {:>3}/{:<4}  | {:>3}/{:<4}",
            w.name, w.p, w.paper_iters.0, w.paper_iters.1, n, pl
        );
    }
}
