//! Distributed loopback — the multi-process deployment shape, in one
//! process you can actually run.
//!
//! Spawns four organization node servers on ephemeral loopback TCP
//! ports (each owning one shard of a synthetic study, exactly what
//! `privlogit node --listen …` does), connects the Center to them as a
//! [`RemoteFleet`], links the two Center servers over real TCP loopback
//! sockets too, and runs PrivLogit-Local with **real cryptography**:
//! every Paillier ciphertext, garbled table, OT message and statistic
//! request crosses the kernel network stack through the framed,
//! CRC-checked wire protocol.
//!
//! ```sh
//! cargo run --release --example distributed_loopback
//! ```
//!
//! The same topology across real machines is two commands — see
//! `docs/DEPLOY.md`.

use privlogit::coordinator::fleet::Fleet;
use privlogit::coordinator::{run_protocol, Backend, CenterLink};
use privlogit::data::synthesize;
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::metrics::{beta_preview, render_report};
use privlogit::net::{NodeServer, RemoteFleet};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};

fn main() {
    let orgs = 4;
    let data = synthesize("LoopbackStudy", 2000, 6, 2026);
    let parts = data.partition(orgs);
    println!("study: n={} p={} split across {orgs} organizations", data.n(), data.p());

    // Ground truth: plaintext distributed Newton (the paper's oracle).
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    // One node server per organization, each on its own loopback port.
    let addrs: Vec<String> = parts
        .into_iter()
        .map(|shard| {
            let mut server = NodeServer::bind("127.0.0.1:0", shard).expect("bind node server");
            let addr = server.local_addr().expect("local addr").to_string();
            std::thread::spawn(move || server.serve_once().expect("node session"));
            addr
        })
        .collect();
    println!("node servers listening on {}", addrs.join(", "));

    // The Center: remote fleet over TCP, GC center link over TCP too.
    let mut fleet = RemoteFleet::connect(&addrs).expect("connect fleet");
    let report = run_protocol(
        Protocol::PrivLogitLocal,
        Backend::Real,
        512,
        FixedFmt::DEFAULT,
        &cfg,
        7,
        &CenterLink::TcpLoopback,
        &mut fleet,
    )
    .expect("distributed run");
    print!("{}", render_report(&report));
    println!("  beta: {}", beta_preview(&report.beta));

    let net = fleet.net_stats();
    println!(
        "fleet wire traffic: {:.1} KiB sent / {:.1} KiB recv in {} requests",
        net.bytes_sent as f64 / 1024.0,
        net.bytes_recv as f64 / 1024.0,
        net.msgs_sent
    );
    assert!(net.bytes_sent > 0 && net.bytes_recv > 0, "traffic in both directions");

    let r2 = r_squared(&report.beta, &truth.beta);
    println!("accuracy vs plaintext Newton: R² = {r2:.6}");
    assert!(r2 > 0.9999, "distributed run must reproduce the plaintext optimum");
    println!("distributed loopback OK");
}
