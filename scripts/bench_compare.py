#!/usr/bin/env python3
"""Compare a fresh BENCH_primitives.json against the committed baseline.

Usage: bench_compare.py <baseline.json> <fresh.json>

Fails (exit 1) when any speedup in the baseline's ``speedups`` table
regresses by more than 25% in the fresh run, or disappears from it.
Extra speedups in the fresh run are reported but never fail the build —
new primitives get a floor only once the baseline is updated.

The committed baseline may be ``"provisional": true`` — analytic floors
rather than measurements — in which case the 25% margin sits on top of
already-conservative numbers, so a failure means a real algorithmic
regression, not machine noise.
"""

import json
import sys

REGRESSION_MARGIN = 0.75  # fresh must reach >= 75% of baseline


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)

    base_speedups = baseline.get("speedups", {})
    fresh_speedups = fresh.get("speedups", {})
    provisional = baseline.get("provisional", False)

    failures = []
    for name, floor in sorted(base_speedups.items()):
        got = fresh_speedups.get(name)
        if got is None:
            failures.append(f"{name}: present in baseline but missing from the fresh run")
        elif got < REGRESSION_MARGIN * floor:
            failures.append(
                f"{name}: {got:.2f}x is a >25% regression vs baseline {floor:.2f}x"
            )
        else:
            print(f"ok  {name}: {got:.2f}x (baseline {floor:.2f}x)")
    for name in sorted(set(fresh_speedups) - set(base_speedups)):
        print(f"new {name}: {fresh_speedups[name]:.2f}x (no baseline floor yet)")

    if failures:
        kind = "provisional floors" if provisional else "measured baseline"
        print(f"\nPERF REGRESSION vs {kind} ({sys.argv[1]}):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("all speedups within 25% of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
