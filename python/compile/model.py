"""Layer-2 JAX compute graph: the node-local statistics functions the
rust runtime executes through PJRT.

Each function wraps the L1 Pallas kernels with the scaling the protocols
need (statistics are *averaged* — scaled by 1/n_total — so every value the
secure layers touch is O(1); see DESIGN.md §5). `scale` arrives as a
traced scalar so one artifact serves any total sample count.

Shapes are fixed at AOT time per (tile_n, p_pad) variant; the rust runtime
pads rows (w=0) and features (zero columns) to the nearest variant.
"""

import jax.numpy as jnp

from .kernels import logistic


def node_stats(x, y, w, beta, scale):
    """Fused local gradient + log-likelihood, pre-scaled.

    Returns (g·scale, l·scale): Eq. 4 / Eq. 9 node shares.
    """
    g, l = logistic.grad_loglik(x, y, w, beta)
    return g * scale, l * scale


def node_gram(x, w, scale):
    """PrivLogit surrogate-Hessian share: ¼ X^T X · scale (Eq. 6/7)."""
    return logistic.gram(x, w) * (0.25 * scale)


def node_hessian(x, w, beta, scale):
    """Exact Hessian share X^T A X · scale (Eq. 5, Newton baseline)."""
    return logistic.hessian(x, w, beta) * scale


def predict_proba(x, beta):
    """Inference-time class-1 probabilities (quickstart example)."""
    return 1.0 / (1.0 + jnp.exp(-(x @ beta)))
