"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Everything here is straight-line jax.numpy with no Pallas, no tiling and
no masking tricks beyond the weight vector — the reference the kernels
must reproduce bit-for-bit up to float associativity.
"""

import jax
import jax.numpy as jnp


def grad_loglik_ref(x, y, w, beta):
    """Reference for kernels.logistic.grad_loglik."""
    z = x @ beta
    prob = jax.nn.sigmoid(z)
    resid = w * (y - prob)
    g = x.T @ resid
    # stable log(1 + e^z)
    log1pexp = jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))
    l = jnp.sum(w * (y * z - log1pexp))
    return g, l.reshape((1,))


def gram_ref(x, w):
    """Reference for kernels.logistic.gram."""
    return x.T @ (x * w[:, None])


def hessian_ref(x, w, beta):
    """Reference for kernels.logistic.hessian."""
    z = x @ beta
    prob = jax.nn.sigmoid(z)
    a = w * prob * (1.0 - prob)
    return x.T @ (x * a[:, None])
