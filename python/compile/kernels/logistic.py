"""Layer-1 Pallas kernels: the node-local numeric hot spot.

Every iteration of every protocol, each organization evaluates its local
statistics over its private rows (paper Eq. 4/5/9):

  * ``grad_loglik``  — fused sigmoid ∘ residual ∘ X^T(y-p) ∘ log-likelihood
  * ``gram``         — X^T X          (PrivLogit SetupOnce, Eq. 6/7)
  * ``hessian``      — X^T A X        (Newton baseline, Eq. 5)

These are the only data-size-dependent computations in the system, so they
are the L1 kernels. Row tiles of ``block_n`` stream through VMEM while a
``(p, ·)`` accumulator stays resident; the masked-weight vector ``w``
makes row padding exact (padded rows carry w=0, contributing nothing to
either the gradient or the log-likelihood).

TPU mapping (DESIGN.md §6): the ``xt @ (w·resid)`` and ``xt @ (a·x)``
contractions are MXU-shaped matmuls over a (block_n × p) tile; ``block_n``
is chosen so x-tile + accumulator fit VMEM. ``interpret=True`` everywhere —
the CPU PJRT plugin cannot execute Mosaic custom-calls (see
/opt/xla-example/README.md), and correctness is asserted against
``ref.py`` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 256 keeps tile + accumulator well under real-TPU VMEM
# (p≤512: 256·512·4 B = 512 KiB per x tile) while amortizing grid overhead.
DEFAULT_BLOCK_N = 256


def _grad_loglik_kernel(x_ref, y_ref, w_ref, beta_ref, g_ref, l_ref):
    """One row tile: accumulate gradient and log-likelihood."""
    i = pl.program_id(0)
    x = x_ref[...]            # (bn, p)
    y = y_ref[...]            # (bn,)
    w = w_ref[...]            # (bn,)
    beta = beta_ref[...]      # (p,)
    z = x @ beta              # (bn,) — MXU matvec
    prob = jax.nn.sigmoid(z)
    resid = w * (y - prob)
    g_tile = x.T @ resid      # (p,) — MXU contraction
    # stable log(1+e^z) = max(z,0) + log1p(exp(-|z|))
    l_tile = jnp.sum(w * (y * z - (jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z))))))

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    g_ref[...] += g_tile
    l_ref[...] += l_tile.reshape(l_ref.shape)


@functools.partial(jax.jit, static_argnames=("block_n",))
def grad_loglik(x, y, w, beta, *, block_n=DEFAULT_BLOCK_N):
    """Fused local gradient + log-likelihood (paper Eq. 4 and 9).

    Args:
      x: (n, p) covariates, n divisible by block_n (runtime pads).
      y: (n,) responses.
      w: (n,) row mask/weights (0 for padding rows).
      beta: (p,) coefficients.

    Returns:
      (g, l): gradient (p,) = X^T(w·(y − σ(Xβ))) and masked log-likelihood.
    """
    n, p = x.shape
    assert n % block_n == 0, f"{n=} not divisible by {block_n=}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _grad_loglik_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype),
        ],
        interpret=True,
    )(x, y, w, beta)


def _gram_kernel(x_ref, w_ref, out_ref):
    """One row tile: accumulate X^T diag(w) X."""
    i = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]
    xw = x * w[:, None]
    tile = x.T @ xw  # (p, p) MXU matmul

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("block_n",))
def gram(x, w, *, block_n=DEFAULT_BLOCK_N):
    """Masked Gram matrix X^T diag(w) X (PrivLogit's H̃ ingredient, Eq. 6)."""
    n, p = x.shape
    assert n % block_n == 0
    return pl.pallas_call(
        _gram_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((p, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, p), x.dtype),
        interpret=True,
    )(x, w)


def _hessian_kernel(x_ref, w_ref, beta_ref, out_ref):
    """One row tile: accumulate X^T diag(w·σ(1−σ)) X."""
    i = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]
    beta = beta_ref[...]
    z = x @ beta
    prob = jax.nn.sigmoid(z)
    a = w * prob * (1.0 - prob)
    tile = x.T @ (x * a[:, None])

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += tile


@functools.partial(jax.jit, static_argnames=("block_n",))
def hessian(x, w, beta, *, block_n=DEFAULT_BLOCK_N):
    """Exact local Hessian X^T A X (Newton baseline, Eq. 5; PD convention)."""
    n, p = x.shape
    assert n % block_n == 0
    return pl.pallas_call(
        _hessian_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((p, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, p), x.dtype),
        interpret=True,
    )(x, w, beta)
