"""AOT pipeline: lower the L2 functions to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
xla crate's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent; invoked
by ``make artifacts``). Writes one ``<fn>_n<tile>_p<pad>.hlo.txt`` per
(function, feature-pad) variant plus ``manifest.txt``::

    # name tile_n p_pad filename
    node_stats 256 16 node_stats_n256_p16.hlo.txt
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Row-tile height must match kernels.logistic.DEFAULT_BLOCK_N: the rust
# runtime feeds exactly one tile per execution and accumulates across
# tiles host-side (keeps every artifact shape-static).
TILE_N = 256

# Feature paddings (lane-friendly). SimuX400 (p=400) lands on 512.
P_PADS = (16, 32, 64, 128, 256, 512)


def _to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variants():
    """Yield (name, lowered-jit) for every artifact to emit."""
    f32 = jnp.float32
    for p in P_PADS:
        xs = jax.ShapeDtypeStruct((TILE_N, p), f32)
        vs = jax.ShapeDtypeStruct((TILE_N,), f32)
        bs = jax.ShapeDtypeStruct((p,), f32)
        ss = jax.ShapeDtypeStruct((), f32)
        yield (
            f"node_stats_n{TILE_N}_p{p}",
            jax.jit(model.node_stats).lower(xs, vs, vs, bs, ss),
            ("node_stats", p),
        )
        yield (
            f"node_gram_n{TILE_N}_p{p}",
            jax.jit(model.node_gram).lower(xs, vs, ss),
            ("node_gram", p),
        )
        yield (
            f"node_hessian_n{TILE_N}_p{p}",
            jax.jit(model.node_hessian).lower(xs, vs, bs, ss),
            ("node_hessian", p),
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = ["# name tile_n p_pad filename"]
    for fname, lowered, (name, p) in variants():
        path = os.path.join(args.out, f"{fname}.hlo.txt")
        text = _to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} {TILE_N} {p} {fname}.hlo.txt")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest) - 1} artifacts")


if __name__ == "__main__":
    main()
