"""L2 + AOT pipeline tests: model functions, scaling, HLO emission."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _case(n=256, p=8, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    w = jnp.ones(n, jnp.float32)
    beta = jnp.asarray(rng.standard_normal(p) * 0.3, jnp.float32)
    return x, y, w, beta


def test_node_stats_scaling():
    x, y, w, beta = _case()
    scale = jnp.float32(1.0 / 5000.0)
    g, l = model.node_stats(x, y, w, beta, scale)
    g_ref, l_ref = ref.grad_loglik_ref(x, y, w, beta)
    np.testing.assert_allclose(g, g_ref * scale, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(l, l_ref * scale, rtol=2e-5, atol=1e-6)


def test_node_gram_quarter_scaling():
    x, y, w, _ = _case(seed=1)
    scale = jnp.float32(1e-3)
    got = model.node_gram(x, w, scale)
    expect = ref.gram_ref(x, w) * 0.25 * scale
    np.testing.assert_allclose(got, expect, rtol=2e-5, atol=1e-6)


def test_node_hessian_scaling():
    x, y, w, beta = _case(seed=2)
    scale = jnp.float32(1e-3)
    got = model.node_hessian(x, w, beta, scale)
    expect = ref.hessian_ref(x, w, beta) * scale
    np.testing.assert_allclose(got, expect, rtol=2e-5, atol=1e-6)


def test_predict_proba_range():
    x, _, _, beta = _case(seed=3)
    p = model.predict_proba(x, beta)
    assert float(p.min()) >= 0.0 and float(p.max()) <= 1.0


def test_variants_cover_paper_dims():
    names = [meta for _, _, meta in aot.variants()]
    pads = sorted({p for _, p in names})
    assert pads == sorted(aot.P_PADS)
    # every paper workload dimension fits a pad
    for paper_p in (12, 33, 38, 52, 100, 150, 200, 400):
        assert any(pad >= paper_p for pad in pads), paper_p
    fns = {n for n, _ in names}
    assert fns == {"node_stats", "node_gram", "node_hessian"}


def test_hlo_text_emission_smallest_variant():
    """Lower one variant and sanity-check the HLO text format."""
    for fname, lowered, (name, p) in aot.variants():
        if p != aot.P_PADS[0] or name != "node_stats":
            continue
        text = aot._to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text
        return
    pytest.fail("variant not found")


def test_aot_main_writes_manifest(tmp_path):
    """Full artifact build into a temp dir (slow-ish but the real deal)."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    entries = [l for l in manifest if not l.startswith("#")]
    assert len(entries) == 3 * len(aot.P_PADS)
    for line in entries:
        name, tile_n, p_pad, fname = line.split()
        assert (out / fname).exists(), fname
        assert int(tile_n) == aot.TILE_N
