"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/values; every kernel must match ref.py to
float32 tolerance, including masked (padded) rows — the property the rust
runtime's padding relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logistic, ref


def make_case(rng, n, p, frac_masked=0.0, dtype=jnp.float32):
    x = jnp.asarray(rng.standard_normal((n, p)), dtype)
    y = jnp.asarray(rng.integers(0, 2, n), dtype)
    w = np.ones(n)
    n_masked = int(n * frac_masked)
    if n_masked:
        w[-n_masked:] = 0.0
    w = jnp.asarray(w, dtype)
    beta = jnp.asarray(rng.standard_normal(p) * 0.5, dtype)
    return x, y, w, beta


@pytest.mark.parametrize("n,p", [(256, 4), (512, 16), (1024, 33)])
def test_grad_loglik_matches_ref(n, p):
    rng = np.random.default_rng(0)
    x, y, w, beta = make_case(rng, n, p)
    g, l = logistic.grad_loglik(x, y, w, beta, block_n=256)
    g_ref, l_ref = ref.grad_loglik_ref(x, y, w, beta)
    np.testing.assert_allclose(g, g_ref, rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(l, l_ref, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("n,p", [(256, 8), (768, 12)])
def test_gram_matches_ref(n, p):
    rng = np.random.default_rng(1)
    x, _, w, _ = make_case(rng, n, p)
    got = logistic.gram(x, w, block_n=256)
    np.testing.assert_allclose(got, ref.gram_ref(x, w), rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("n,p", [(256, 8), (768, 12)])
def test_hessian_matches_ref(n, p):
    rng = np.random.default_rng(2)
    x, _, w, beta = make_case(rng, n, p)
    got = logistic.hessian(x, w, beta, block_n=256)
    np.testing.assert_allclose(got, ref.hessian_ref(x, w, beta), rtol=2e-5, atol=2e-4)


def test_masked_rows_contribute_nothing():
    """The padding contract: w=0 rows must vanish from all statistics."""
    rng = np.random.default_rng(3)
    x, y, w, beta = make_case(rng, 512, 8, frac_masked=0.5)
    n_real = 256
    g_full, l_full = logistic.grad_loglik(x, y, w, beta, block_n=256)
    g_trim, l_trim = ref.grad_loglik_ref(x[:n_real], y[:n_real], jnp.ones(n_real), beta)
    np.testing.assert_allclose(g_full, g_trim, rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(l_full, l_trim, rtol=2e-5, atol=2e-4)
    gram_full = logistic.gram(x, w, block_n=256)
    gram_trim = ref.gram_ref(x[:n_real], jnp.ones(n_real))
    np.testing.assert_allclose(gram_full, gram_trim, rtol=2e-5, atol=2e-4)


def test_zero_feature_padding_is_exact():
    """Zero columns (feature padding) leave real statistics untouched."""
    rng = np.random.default_rng(4)
    x, y, w, beta = make_case(rng, 256, 5)
    xp = jnp.pad(x, ((0, 0), (0, 11)))
    bp = jnp.pad(beta, (0, 11))
    g_pad, l_pad = logistic.grad_loglik(xp, y, w, bp, block_n=256)
    g_ref, l_ref = ref.grad_loglik_ref(x, y, w, beta)
    np.testing.assert_allclose(g_pad[:5], g_ref, rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(g_pad[5:], np.zeros(11), atol=1e-6)
    np.testing.assert_allclose(l_pad, l_ref, rtol=2e-5, atol=2e-4)


@settings(deadline=None, max_examples=20)
@given(
    n_tiles=st.integers(1, 3),
    p=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    frac=st.sampled_from([0.0, 0.1, 0.9]),
)
def test_grad_loglik_property(n_tiles, p, seed, frac):
    """Hypothesis sweep: arbitrary shapes and mask fractions."""
    rng = np.random.default_rng(seed)
    n = 256 * n_tiles
    x, y, w, beta = make_case(rng, n, p, frac_masked=frac)
    g, l = logistic.grad_loglik(x, y, w, beta, block_n=256)
    g_ref, l_ref = ref.grad_loglik_ref(x, y, w, beta)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=5e-4)
    np.testing.assert_allclose(l, l_ref, rtol=1e-4, atol=5e-4)


@settings(deadline=None, max_examples=10)
@given(p=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_gram_property_psd(p, seed):
    """Gram outputs are symmetric PSD for any inputs."""
    rng = np.random.default_rng(seed)
    x, _, w, _ = make_case(rng, 256, p)
    g = np.asarray(logistic.gram(x, w, block_n=256), dtype=np.float64)
    np.testing.assert_allclose(g, g.T, atol=1e-4)
    eig = np.linalg.eigvalsh(g)
    assert eig.min() > -1e-3, f"PSD violated: {eig.min()}"


def test_float64_mode():
    """Kernels work under x64 when enabled (protocol-side uses f32)."""
    rng = np.random.default_rng(5)
    x, y, w, beta = make_case(rng, 256, 6)
    g32, _ = logistic.grad_loglik(x, y, w, beta, block_n=256)
    assert g32.dtype == jnp.float32
