//! Figure 3 reproduction: convergence iterations (log-likelihood
//! trajectories to the 1e-6 relative threshold) for the Newton method and
//! PrivLogit across the real-study stand-ins and the SimuX series.
//!
//! The secure protocols execute the same arithmetic as the plaintext
//! optimizers (verified in protocol tests), so the trajectories here are
//! the protocols' trajectories.

use privlogit::data::{load_workload, WORKLOADS};
use privlogit::optim::{fit_single, Method, OptimConfig};

fn main() {
    println!("=== Figure 3: convergence iterations (ours vs paper) ===\n");
    let cfg = OptimConfig::default();
    println!(
        "{:<10} {:>4} | {:>13} | {:>13} | rel-change series (PrivLogit, first 8)",
        "dataset", "p", "newton (pap.)", "privlogit (pap.)"
    );
    for w in WORKLOADS {
        let d = load_workload(*w);
        let newton = fit_single(&d, Method::Newton, cfg);
        let privlogit = fit_single(&d, Method::PrivLogit, cfg);
        // relative log-likelihood change per iteration — the curves of Fig. 3
        let series: Vec<String> = privlogit
            .loglik_trace
            .windows(2)
            .take(8)
            .map(|v| format!("{:.1e}", ((v[1] - v[0]) / v[0].abs()).abs()))
            .collect();
        println!(
            "{:<10} {:>4} | {:>6} ({:>4}) | {:>6} ({:>4}) | {}",
            w.name,
            w.p,
            newton.iterations,
            w.paper_iters.0,
            privlogit.iterations,
            w.paper_iters.1,
            series.join(" ")
        );
        assert!(newton.converged && privlogit.converged, "{}", w.name);
        assert!(
            privlogit.iterations > newton.iterations,
            "{}: PrivLogit must iterate more (paper Fig. 3)",
            w.name
        );
        // the calibration contract: within 2x of the paper's counts
        let ratio = privlogit.iterations as f64 / w.paper_iters.1 as f64;
        assert!(
            (0.5..2.0).contains(&ratio),
            "{}: iterations {} vs paper {}",
            w.name,
            privlogit.iterations,
            w.paper_iters.1
        );
        // monotone convergence (Proposition 1a)
        for pair in privlogit.loglik_trace.windows(2) {
            assert!(pair[1] >= pair[0] - 1e-9, "{}: monotone loglik", w.name);
        }
    }
    println!("\nfig3_iterations OK (paper: Newton single digits, PrivLogit tens-to-hundreds)");
}
