//! Micro-benchmarks of every secure primitive — the calibration source
//! for the cost model (DESIGN.md §7).
//!
//! Writes `artifacts/calibration.txt`, which [`privlogit::mpc::CostModel`]
//! loads for all modeled experiments. Run before the table/figure benches
//! for machine-accurate modeling:
//!
//! ```sh
//! cargo bench --bench micro_primitives
//! ```

use std::time::Instant;

use privlogit::bigint::{BigUint, RandomSource};
use privlogit::crypto::paillier::{ChaChaSource, Keypair};
use privlogit::crypto::rng::ChaChaRng;
use privlogit::gc::word::{self, FixedFmt};
use privlogit::gc::{GcBackend, GcProgram, GcSession};

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };
/// Paillier modulus for calibration — scaled from the paper's 2048-bit
/// parameter (all protocols scale identically in the key size; see
/// DESIGN.md §7). Override with PRIVLOGIT_MODBITS.
const DEFAULT_MODBITS: usize = 1024;

/// A mult-chain program: measures amortized per-AND cost through the full
/// streamed garble+eval+OT pipeline.
struct MulChain {
    rounds: usize,
}

impl GcProgram for MulChain {
    fn inputs_garbler(&self) -> usize {
        FMT.w
    }
    fn inputs_evaluator(&self) -> usize {
        FMT.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let mut acc = ga.to_vec();
        let x = ea.to_vec();
        for _ in 0..self.rounds {
            acc = word::mul(b, &acc, &x, FMT);
            // keep values bounded: shift back toward small magnitudes
            acc = word::sar_const(b, &acc, 1);
        }
        acc
    }
}

fn time_it<T>(label: &str, reps: usize, mut f: impl FnMut() -> T) -> f64 {
    f(); // warm-up
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{label:<18} {per:>12.3e} s/op  ({reps} reps)");
    per
}

fn main() {
    let modbits: usize = std::env::var("PRIVLOGIT_MODBITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_MODBITS);
    println!("=== micro_primitives (modulus {modbits} bits, W={} F={}) ===", FMT.w, FMT.f);
    let mut rng = ChaChaRng::from_u64_seed(0xCA11B);
    let kp = Keypair::generate(modbits, &mut rng);

    let m = rng.below(&kp.pk.n);
    let t_enc = time_it("paillier_enc", 50, || {
        kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng))
    });
    let c1 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
    let c2 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
    let t_add = time_it("paillier_add", 2000, || kp.pk.add(&c1, &c2));
    let full_k = rng.below(&kp.pk.n);
    let t_scalar_full = time_it("scalar_full", 50, || kp.pk.scalar_mul(&c1, &full_k));
    let small_k = BigUint::from_u64(rng.next_u64() >> 24); // ~f-bit exponent
    let t_scalar_small = time_it("scalar_small", 200, || kp.pk.scalar_mul(&c1, &small_k));
    let t_decrypt = time_it("blind_decrypt", 50, || {
        // blind + decrypt, the to_shares unit
        let rho = rng.below(&kp.pk.n);
        let blinded = kp.pk.add(&c1, &kp.pk.encrypt_trivial(&rho));
        kp.sk.decrypt(&blinded)
    });

    // GC: amortized AND cost through a real session.
    let mut session = GcSession::new(0xCA11);
    let prog = MulChain { rounds: 64 };
    let ga: Vec<bool> = (0..FMT.w).map(|i| i % 3 == 0).collect();
    let ea: Vec<bool> = (0..FMT.w).map(|i| i % 5 == 0).collect();
    let (_, s0) = session.execute(&prog, &ga, &ea); // warm-up
    let t0 = Instant::now();
    let mut ands = 0u64;
    let reps = 5;
    for _ in 0..reps {
        let (_, s) = session.execute(&prog, &ga, &ea);
        ands += s.ands;
    }
    let t_and = t0.elapsed().as_secs_f64() / ands as f64;
    println!("gc_and             {t_and:>12.3e} s/gate ({ands} gates; warm-up {})", s0.ands);

    // OT extension amortized per evaluator-input bit.
    let prog_small = MulChain { rounds: 1 };
    let t0 = Instant::now();
    let ot_reps = 50;
    for _ in 0..ot_reps {
        session.execute(&prog_small, &ga, &ea);
    }
    let t_ot = t0.elapsed().as_secs_f64() / (ot_reps * FMT.w) as f64;
    println!("ot_per_bit(approx) {t_ot:>12.3e} s/bit");

    let cal = format!(
        "# measured by `cargo bench --bench micro_primitives` (modulus {modbits} bits)\n\
         t_and = {t_and:.3e}\nt_ot = {t_ot:.3e}\nt_enc = {t_enc:.3e}\nt_add = {t_add:.3e}\n\
         t_scalar_full = {t_scalar_full:.3e}\nt_scalar_small = {t_scalar_small:.3e}\n\
         t_decrypt = {t_decrypt:.3e}\n"
    );
    std::fs::create_dir_all("artifacts").ok();
    std::fs::write("artifacts/calibration.txt", &cal).expect("write calibration");
    println!("\nwrote artifacts/calibration.txt:\n{cal}");
    assert!(
        t_scalar_small < t_scalar_full,
        "PrivLogit-Local's premise: multiply-by-small-constant must be cheaper"
    );
}
