//! Micro-benchmarks of every secure primitive — the calibration source
//! for the cost model (DESIGN.md §7) and the perf-trajectory artifact.
//!
//! Writes two artifacts:
//!
//! * `artifacts/calibration.txt` — per-primitive seconds, loaded by
//!   [`privlogit::mpc::CostModel`] for all modeled experiments. The
//!   measured constants come from the *fast* paths (fixed-base
//!   encryption, Straus multi-exp, cached CRT decryption), so the cost
//!   model picks up every optimization automatically.
//! * `BENCH_primitives.json` at the repo root — machine-readable op
//!   timings (ns/op) plus fast-vs-reference speedups, modulus bits,
//!   worker-thread count and git revision, so future PRs can track the
//!   perf trajectory. Schema documented in docs/ARCHITECTURE.md.
//!
//! Run before the table/figure benches for machine-accurate modeling:
//!
//! ```sh
//! cargo bench --bench micro_primitives
//! ```
//!
//! Env knobs: `PRIVLOGIT_MODBITS` (modulus bits, default 1024),
//! `PRIVLOGIT_BENCH_QUICK` (any value: fewer reps — the CI smoke mode),
//! `PRIVLOGIT_THREADS` (worker count for the parallel entries).

use std::time::Instant;

use privlogit::bigint::{BigUint, RandomSource};
use privlogit::crypto::paillier::{ChaChaSource, Ciphertext, Keypair};
use privlogit::crypto::rng::ChaChaRng;
use privlogit::crypto::PackedCodec;
use privlogit::gc::word::{self, FixedFmt};
use privlogit::gc::{GcBackend, GcProgram, GcSession};
use privlogit::mpc::fabric::{apply_hinv_cts_reference, PreparedHinv};
use privlogit::mpc::tri_len;
use privlogit::runtime::pool;

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };
/// Paillier modulus for calibration — scaled from the paper's 2048-bit
/// parameter (all protocols scale identically in the key size; see
/// DESIGN.md §7). Override with PRIVLOGIT_MODBITS.
const DEFAULT_MODBITS: usize = 1024;
/// Row dimensionality for the `apply_hinv` row primitive (a mid-size
/// PrivLogit-Local workload shape).
const APPLY_P: usize = 16;

/// A mult-chain program: measures amortized per-AND cost through the full
/// streamed garble+eval+OT pipeline.
struct MulChain {
    rounds: usize,
}

impl GcProgram for MulChain {
    fn inputs_garbler(&self) -> usize {
        FMT.w
    }
    fn inputs_evaluator(&self) -> usize {
        FMT.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let mut acc = ga.to_vec();
        let x = ea.to_vec();
        for _ in 0..self.rounds {
            acc = word::mul(b, &acc, &x, FMT);
            // keep values bounded: shift back toward small magnitudes
            acc = word::sar_const(b, &acc, 1);
        }
        acc
    }
}

/// Timed ops collected for the JSON artifact (name → seconds/op).
struct OpLog(Vec<(&'static str, f64)>);

impl OpLog {
    fn time_it<T>(&mut self, label: &'static str, reps: usize, mut f: impl FnMut() -> T) -> f64 {
        f(); // warm-up
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("{label:<26} {per:>12.3e} s/op  ({reps} reps)");
        self.0.push((label, per));
        per
    }

    fn push(&mut self, label: &'static str, per: f64) {
        self.0.push((label, per));
    }

    /// Like [`OpLog::time_it`], but attributes each rep's cost across
    /// `items` units (rows of an apply, ciphertexts of a batch); the
    /// warm-up call also fills any lazy tables so the steady state is
    /// what gets timed.
    fn time_scaled<T>(
        &mut self,
        label: &'static str,
        reps: usize,
        items: usize,
        note: &str,
        mut f: impl FnMut() -> T,
    ) -> f64 {
        f(); // warm-up
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        let per = t0.elapsed().as_secs_f64() / (reps * items) as f64;
        println!("{label:<26} {per:>12.3e} s/unit ({note})");
        self.0.push((label, per));
        per
    }
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}

fn main() {
    let modbits: usize = std::env::var("PRIVLOGIT_MODBITS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_MODBITS);
    let quick = std::env::var("PRIVLOGIT_BENCH_QUICK").is_ok();
    let r = |full: usize, q: usize| if quick { q } else { full };
    let workers = pool::threads();
    println!(
        "=== micro_primitives (modulus {modbits} bits, W={} F={}, {workers} workers{}) ===",
        FMT.w,
        FMT.f,
        if quick { ", quick" } else { "" }
    );
    let mut rng = ChaChaRng::from_u64_seed(0xCA11B);
    let kp = Keypair::generate(modbits, &mut rng);
    let mut log = OpLog(Vec::new());

    // --- Paillier encryption: fixed-base fast path vs generic modpow ---
    let m = rng.below(&kp.pk.n);
    let t_enc = log.time_it("paillier_enc", r(50, 8), || {
        kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng))
    });
    let t_enc_ref = log.time_it("paillier_enc_reference", r(50, 8), || {
        kp.pk.encrypt_reference(&m, &mut ChaChaSource(&mut rng))
    });

    let c1 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
    let c2 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
    let t_add = log.time_it("paillier_add", r(2000, 200), || kp.pk.add(&c1, &c2));

    // --- Subtraction: modular inverse vs scalar-multiply-by-(n−1) ---
    let t_sub = log.time_it("paillier_sub", r(200, 20), || kp.pk.sub(&c1, &c2));
    let t_sub_ref =
        log.time_it("paillier_sub_reference", r(50, 8), || kp.pk.sub_reference(&c1, &c2));

    let full_k = rng.below(&kp.pk.n);
    let t_scalar_full = log.time_it("scalar_full", r(50, 8), || kp.pk.scalar_mul(&c1, &full_k));
    let small_k = BigUint::from_u64(rng.next_u64() >> 24); // ~f-bit exponent
    let t_scalar_small =
        log.time_it("scalar_small", r(200, 20), || kp.pk.scalar_mul(&c1, &small_k));
    // Tiny exponents take the table-free square-and-multiply fast path.
    let tiny_k = BigUint::from_u64((rng.next_u64() >> 52) | 1); // ≤ 12-bit exponent
    log.time_it("scalar_tiny", r(400, 40), || kp.pk.scalar_mul(&c1, &tiny_k));

    let t_decrypt = log.time_it("blind_decrypt", r(50, 8), || {
        // blind + decrypt, the to_shares unit
        let rho = rng.below(&kp.pk.n);
        let blinded = kp.pk.add(&c1, &kp.pk.encrypt_trivial(&rho));
        kp.sk.decrypt(&blinded)
    });

    // --- apply_hinv row primitive: Straus multi-exp vs naive loop ---
    // (single-threaded for the algorithmic comparison, plus the
    // parallel-row figure at the configured worker count)
    let tri: Vec<Ciphertext> = (0..tri_len(APPLY_P))
        .map(|i| {
            kp.pk.encrypt(&BigUint::from_u64(10_000 + i as u64), &mut ChaChaSource(&mut rng))
        })
        .collect();
    let v: Vec<f64> = (0..APPLY_P)
        .map(|j| {
            let sign = if j % 2 == 0 { 1.0 } else { -1.0 };
            sign * (0.05 + j as f64 * 0.07)
        })
        .collect();
    let apply_reps = r(5, 2);
    let prepared_1 = PreparedHinv::prepare(&kp.pk, APPLY_P, &tri, 1);
    let t_row = log.time_scaled("apply_hinv_row", apply_reps, APPLY_P, "1 worker", || {
        prepared_1.apply(FMT, &v, 1)
    });
    let t_row_ref =
        log.time_scaled("apply_hinv_row_reference", apply_reps, APPLY_P, "naive loop", || {
            apply_hinv_cts_reference(&kp.pk, FMT, APPLY_P, &tri, &v)
        });
    let prepared_n = PreparedHinv::prepare(&kp.pk, APPLY_P, &tri, workers);
    let note_workers = format!("{workers} workers");
    let t_row_par =
        log.time_scaled("apply_hinv_row_parallel", apply_reps, APPLY_P, &note_workers, || {
            prepared_n.apply(FMT, &v, workers)
        });

    // --- Batch encryption at the configured worker count ---
    let batch_ms: Vec<BigUint> = (0..32u64).map(BigUint::from_u64).collect();
    log.time_scaled("paillier_enc_batch", r(5, 2), batch_ms.len(), &note_workers, || {
        kp.pk.encrypt_batch(&batch_ms, &mut ChaChaSource(&mut rng), workers)
    });

    // --- Ciphertext packing: k statistics per Paillier plaintext ---
    // Each packed op is timed against its unpacked analogue and
    // attributed per *logical value*, so the ratios below read directly
    // as the fan-in speedup (ideal: k×, minus constant overheads).
    let codec = PackedCodec::plan(kp.pk.n.bit_len() as u32, FMT, 8, APPLY_P as u64)
        .expect("the calibration modulus hosts a packed layout at w = 40");
    let pk_k = codec.k() as usize;
    let pack_len = 4 * pk_k;
    let note_k = format!("k={pk_k}");
    let pack_vals: Vec<f64> = (0..pack_len).map(|i| (i as f64 - 7.5) * 0.125).collect();
    let t_pack = log.time_scaled("pack_values", r(2000, 200), pack_len, &note_k, || {
        codec.pack(&pack_vals, FMT.f).expect("bench values fit the slot budget")
    });
    let packed_ms = codec.pack(&pack_vals, FMT.f).expect("bench values fit the slot budget");
    let t_unpack = log.time_scaled("unpack_values", r(2000, 200), pack_len, &note_k, || {
        codec
            .unpack_vec(&packed_ms, pack_len, 1, FMT.f)
            .expect("freshly packed plaintexts unpack")
    });
    // The encode analogue on the unpacked path is one fixed-point
    // encode per value — dwarfed by encryption either way; pack/unpack
    // only need to stay off the critical path (≪ t_enc).
    println!("pack+unpack/value   {:>12.3e} s (vs t_enc {t_enc:.3e})", t_pack + t_unpack);

    // Fold: one homomorphic add carries k statistics in packed form.
    let pc1 = kp.pk.encrypt(&packed_ms[0], &mut ChaChaSource(&mut rng));
    let pc2 = kp.pk.encrypt(&packed_ms[1], &mut ChaChaSource(&mut rng));
    let t_fold_packed =
        log.time_scaled("fold_add_packed", r(2000, 200), pk_k, &note_k, || kp.pk.add(&pc1, &pc2));

    // Apply: multiply-by-constant hits all k slots of a packed
    // ciphertext at once (the hinv_apply headroom term is what makes
    // this sound); per-term cost vs the unpacked scalar-multiply.
    let t_apply_term_packed = log
        .time_scaled("apply_term_packed", r(200, 20), pk_k, &note_k, || {
            kp.pk.scalar_mul(&pc1, &small_k)
        });

    // --- GC: amortized AND cost through a real session ---
    let mut session = GcSession::new(0xCA11);
    let prog = MulChain { rounds: r(64, 16) };
    let ga: Vec<bool> = (0..FMT.w).map(|i| i % 3 == 0).collect();
    let ea: Vec<bool> = (0..FMT.w).map(|i| i % 5 == 0).collect();
    let (_, s0) = session.execute(&prog, &ga, &ea); // warm-up
    let t0 = Instant::now();
    let mut ands = 0u64;
    let reps = r(5, 2);
    for _ in 0..reps {
        let (_, s) = session.execute(&prog, &ga, &ea);
        ands += s.ands;
    }
    let t_and = t0.elapsed().as_secs_f64() / ands as f64;
    println!("gc_and             {t_and:>12.3e} s/gate ({ands} gates; warm-up {})", s0.ands);
    log.push("gc_and", t_and);

    // OT extension amortized per evaluator-input bit.
    let prog_small = MulChain { rounds: 1 };
    let t0 = Instant::now();
    let ot_reps = r(50, 10);
    for _ in 0..ot_reps {
        session.execute(&prog_small, &ga, &ea);
    }
    let t_ot = t0.elapsed().as_secs_f64() / (ot_reps * FMT.w) as f64;
    println!("ot_per_bit(approx) {t_ot:>12.3e} s/bit");
    log.push("ot_per_bit", t_ot);

    // --- calibration.txt (cost-model input; fast-path constants) ---
    let t_apply_term = t_row / APPLY_P as f64;
    let cal = format!(
        "# measured by `cargo bench --bench micro_primitives` (modulus {modbits} bits)\n\
         t_and = {t_and:.3e}\nt_ot = {t_ot:.3e}\nt_enc = {t_enc:.3e}\nt_add = {t_add:.3e}\n\
         t_scalar_full = {t_scalar_full:.3e}\nt_scalar_small = {t_scalar_small:.3e}\n\
         t_apply_term = {t_apply_term:.3e}\nt_apply_term_packed = {t_apply_term_packed:.3e}\n\
         t_decrypt = {t_decrypt:.3e}\n"
    );
    std::fs::create_dir_all("artifacts").ok();
    std::fs::write("artifacts/calibration.txt", &cal).expect("write calibration");
    println!("\nwrote artifacts/calibration.txt:\n{cal}");

    // --- BENCH_primitives.json (perf trajectory artifact) ---
    let speedup_enc = t_enc_ref / t_enc;
    let speedup_sub = t_sub_ref / t_sub;
    let speedup_row = t_row_ref / t_row;
    let speedup_row_par = t_row_ref / t_row_par;
    let speedup_fold_packed = t_add / t_fold_packed;
    let speedup_apply_packed = t_scalar_small / t_apply_term_packed;
    let mut ops_json = String::new();
    for (i, (name, secs)) in log.0.iter().enumerate() {
        if i > 0 {
            ops_json.push_str(",\n");
        }
        ops_json.push_str(&format!("    \"{name}\": {:.1}", secs * 1e9));
    }
    let json = format!(
        "{{\n  \"schema\": \"privlogit-bench-primitives/v1\",\n  \"git_rev\": \"{}\",\n  \
         \"modulus_bits\": {modbits},\n  \"threads\": {workers},\n  \"quick\": {quick},\n  \
         \"ops_ns\": {{\n{ops_json}\n  }},\n  \"speedups\": {{\n    \
         \"encrypt_fixed_base\": {speedup_enc:.2},\n    \
         \"sub_inverse\": {speedup_sub:.2},\n    \
         \"apply_hinv_row_multiexp\": {speedup_row:.2},\n    \
         \"apply_hinv_row_parallel\": {speedup_row_par:.2},\n    \
         \"fold_add_packed\": {speedup_fold_packed:.2},\n    \
         \"apply_term_packed\": {speedup_apply_packed:.2}\n  }},\n  \
         \"packing\": {{ \"k\": {pk_k}, \"slot_bits\": {} }}\n}}\n",
        git_rev(),
        codec.slot_bits()
    );
    // The artifact lives at the repo root (the bench runs with cwd =
    // rust/); fall back to the cwd when run from elsewhere.
    let json_path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_primitives.json"
    } else {
        "BENCH_primitives.json"
    };
    std::fs::write(json_path, &json).expect("write BENCH_primitives.json");
    println!("wrote {json_path}:\n{json}");

    println!(
        "speedups: encrypt {speedup_enc:.2}x, sub {speedup_sub:.2}x, \
         apply_hinv row {speedup_row:.2}x (parallel {speedup_row_par:.2}x), \
         packed fold {speedup_fold_packed:.2}x, packed apply {speedup_apply_packed:.2}x"
    );
    assert!(
        speedup_fold_packed > pk_k as f64 / 2.0,
        "packing's premise: one homomorphic add must carry ≥ k/2 statistics' worth of work \
         (measured {speedup_fold_packed:.2}x at k = {pk_k})"
    );
    assert!(
        t_scalar_small < t_scalar_full,
        "PrivLogit-Local's premise: multiply-by-small-constant must be cheaper"
    );
}
