//! Figure 2 reproduction: QQ accuracy of secure coefficients vs the
//! ground-truth (plaintext distributed Newton) across the four real-study
//! stand-ins. The paper reports perfect alignment, R² = 1.00.
//!
//! Real cryptography on Wine (p=12); the quantized cost-model backend —
//! which reproduces the real backend's fixed-point rounding — on the
//! larger studies.

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::data::{load_workload, workload};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::mpc::{ModelFabric, RealFabric};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

fn main() {
    println!("=== Figure 2: secure vs ground-truth coefficients (QQ R²) ===\n");
    let cfg = ProtocolConfig::default();
    println!(
        "{:<10} {:>7} {:>22} {:>22}",
        "dataset", "backend", "R²(PL-Hessian)", "R²(PL-Local)"
    );
    for name in ["Wine", "Loans", "Insurance", "News"] {
        let data = load_workload(workload(name).unwrap());
        let parts = data.partition(4);
        let truth = fit(
            &parts,
            Method::Newton,
            OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
        );
        let real = data.p() <= 12;
        let mut r2s = Vec::new();
        for proto in [Protocol::PrivLogitHessian, Protocol::PrivLogitLocal] {
            let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
            let rep = if real {
                let mut fab = RealFabric::new(1024, FixedFmt::DEFAULT, 2024);
                proto.run(&mut fab, &mut fleet, &cfg).expect("protocol run")
            } else {
                let mut fab = ModelFabric::new(2048, FixedFmt::DEFAULT);
                proto.run(&mut fab, &mut fleet, &cfg).expect("protocol run")
            };
            r2s.push(r_squared(&rep.beta, &truth.beta));
        }
        println!(
            "{:<10} {:>7} {:>22.6} {:>22.6}",
            name,
            if real { "real" } else { "model" },
            r2s[0],
            r2s[1]
        );
        assert!(r2s[0] > 0.9999 && r2s[1] > 0.9999, "{name}: Fig.2 claim R²=1.00");
    }
    println!("\nfig2_accuracy OK (paper: all points on the diagonal, R² = 1.00)");
}
