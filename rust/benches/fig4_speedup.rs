//! Figure 4 reproduction: relative speedup of PrivLogit-Hessian and
//! PrivLogit-Local over the secure distributed Newton baseline, across
//! the paper's workloads.
//!
//! Reports both accountings:
//! * **total** — everything including one-time setup (our honest number);
//! * **iteration-phase** — setup amortized out, the accounting the
//!   paper's PL-Local column implies (its reported SimuX400 total is
//!   smaller than a single garbled Cholesky at p=400 would cost on its
//!   own testbed — see EXPERIMENTS.md for the analysis).
//!
//! `PRIVLOGIT_QUICK=1` skips the largest workloads.

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::data::{load_workload, WORKLOADS};
use privlogit::gc::word::FixedFmt;
use privlogit::mpc::ModelFabric;
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

/// Paper Fig. 4 speedups (PL-Hessian, PL-Local) where legible from the
/// text: up to 2.32x and 8.1x.
fn paper_speedup(name: &str) -> Option<(f64, f64)> {
    Some(match name {
        "Wine" => (1.33, 1.88),
        "Loans" => (1.89, 4.73),
        "Insurance" => (0.86, 5.85),
        "News" => (2.32, 4.61),
        "SimuX100" => (1.68, 7.27),
        "SimuX150" => (1.72, 7.09),
        "SimuX200" => (2.01, 8.12),
        _ => return None,
    })
}

fn main() {
    let quick = std::env::var("PRIVLOGIT_QUICK").is_ok();
    let cfg = ProtocolConfig::default();
    println!("=== Figure 4: speedup over the secure Newton baseline ===\n");
    println!(
        "{:<10} {:>4} | {:>9} {:>9} | {:>9} {:>9} | paper (PLH, PLL)",
        "dataset", "p", "PLH tot", "PLL tot", "PLH iter", "PLL iter"
    );
    for w in WORKLOADS {
        if quick && w.p > 100 {
            continue;
        }
        let data = load_workload(*w);
        let parts = data.partition(4);
        let mut totals = [0.0f64; 3];
        let mut iterph = [0.0f64; 3];
        for (k, proto) in Protocol::ALL.iter().enumerate() {
            let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
            let mut fab = ModelFabric::new(2048, FixedFmt::DEFAULT);
            let rep = proto.run(&mut fab, &mut fleet, &cfg).expect("protocol run");
            totals[k] = rep.total_secs;
            iterph[k] = rep.total_secs - rep.setup_secs;
        }
        let paper = paper_speedup(w.name)
            .map(|(a, b)| format!("({a:.2}x, {b:.2}x)"))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<10} {:>4} | {:>8.2}x {:>8.2}x | {:>8.2}x {:>8.2}x | {}",
            w.name,
            w.p,
            totals[0] / totals[1],
            totals[0] / totals[2],
            iterph[0] / iterph[1],
            iterph[0] / iterph[2],
            paper
        );
        // Shape assertions from the paper's Fig. 4 narrative:
        assert!(
            totals[2] <= totals[0] * 1.05,
            "{}: PL-Local never meaningfully slower",
            w.name
        );
        assert!(
            iterph[2] < iterph[0],
            "{}: PL-Local iteration phase always wins",
            w.name
        );
    }
    println!("\nfig4_speedup OK (paper: PLH 1.03–2.32x; PLL up to 8.1x, growing with scale)");
}
