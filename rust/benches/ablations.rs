//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! 1. **Fixed-point format** (W, F): accuracy of the secure iterates and
//!    gate cost per multiply — why W=40/F=24 is the default.
//! 2. **Paillier modulus size**: per-primitive scaling (the DESIGN.md §7
//!    claim that key size scales all protocols identically, so relative
//!    speedups survive the 2048→1024-bit substitution).
//! 3. **Ridge one-shot baseline** (Nikolaenko et al. 2013 shape): total
//!    secure cost vs one PrivLogit-Hessian setup — iteration-free linear
//!    regression as the cross-paper reference point.

use std::time::Instant;

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::crypto::paillier::{ChaChaSource, Keypair};
use privlogit::crypto::rng::ChaChaRng;
use privlogit::bigint::{BigUint, RandomSource};
use privlogit::data::synthesize;
use privlogit::gc::backend::CountBackend;
use privlogit::gc::word::{self, FixedFmt};
use privlogit::linalg::r_squared;
use privlogit::mpc::RealFabric;
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{ridge, Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

fn mul_gates(fmt: FixedFmt) -> u64 {
    let mut cb = CountBackend::default();
    let a: Vec<Option<bool>> = vec![None; fmt.w];
    let x: Vec<Option<bool>> = vec![None; fmt.w];
    word::mul(&mut cb, &a, &x, fmt);
    cb.ands
}

fn main() {
    // ---- 1. fixed-point format ----
    println!("=== ablation 1: fixed-point format (real crypto, p=4) ===");
    println!("{:>10} {:>12} {:>14} {:>10}", "W/F", "mul ANDs", "R² vs f64", "iters");
    let d = synthesize("abl", 1200, 4, 61);
    let parts = d.partition(3);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::PrivLogit,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );
    for fmt in [
        FixedFmt { w: 24, f: 12 },
        FixedFmt { w: 32, f: 18 },
        FixedFmt { w: 40, f: 24 },
        FixedFmt { w: 48, f: 28 },
    ] {
        let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut fab = RealFabric::new(256, fmt, 62);
        let rep = Protocol::PrivLogitHessian.run(&mut fab, &mut fleet, &cfg).expect("run");
        let r2 = r_squared(&rep.beta, &truth.beta);
        println!(
            "{:>7}/{:<2} {:>12} {:>14.8} {:>10}",
            fmt.w,
            fmt.f,
            mul_gates(fmt),
            r2,
            rep.iterations
        );
        if fmt.w >= 32 {
            assert!(r2 > 0.999, "W={} must already be accurate", fmt.w);
        }
    }
    println!("(default W=40/F=24: headroom for the 1e-6 threshold at ~6.1k ANDs/mul)\n");

    // ---- 2. modulus scaling ----
    println!("=== ablation 2: Paillier modulus scaling ===");
    println!("{:>6} {:>12} {:>14} {:>14}", "bits", "enc (s)", "scalar_sm (s)", "decrypt (s)");
    let mut rng = ChaChaRng::from_u64_seed(63);
    let mut encs = Vec::new();
    for bits in [512usize, 1024, 2048] {
        let kp = Keypair::generate(bits, &mut rng);
        let m = rng.below(&kp.pk.n);
        let reps = if bits >= 2048 { 5 } else { 20 };
        let t0 = Instant::now();
        let mut c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        for _ in 0..reps {
            c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        }
        let t_enc = t0.elapsed().as_secs_f64() / (reps + 1) as f64;
        let k = BigUint::from_u64(1 << 30);
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(kp.pk.scalar_mul(&c, &k));
        }
        let t_sm = t0.elapsed().as_secs_f64() / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(kp.sk.decrypt(&c));
        }
        let t_dec = t0.elapsed().as_secs_f64() / reps as f64;
        println!("{bits:>6} {t_enc:>12.3e} {t_sm:>14.3e} {t_dec:>14.3e}");
        encs.push(t_enc);
    }
    // scaling claim: ops grow superlinearly in modulus bits, uniformly —
    // every protocol pays the same factor, preserving relative speedups.
    assert!(encs[2] > encs[1] && encs[1] > encs[0], "monotone in key size");
    println!(
        "(uniform scaling across primitives → relative Table-2 ratios are key-size invariant)\n"
    );

    // ---- 3. ridge one-shot baseline ----
    println!("=== ablation 3: one-shot secure ridge (Nikolaenko'13 shape) ===");
    let d = synthesize("ridge", 1500, 8, 64);
    let parts = d.partition(4);
    let expect = ridge::fit_ridge_plaintext(&parts, 1.0);
    let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
    let mut fab = RealFabric::new(512, FixedFmt::DEFAULT, 65);
    let rep = ridge::run_ridge(&mut fab, &mut fleet, 1.0).expect("run");
    let r2 = r_squared(&rep.beta, &expect);
    println!(
        "ridge p=8: total {:.2}s, {} GC ANDs, R²={:.6} (logistic PL-Hessian needs the same\n\
         setup *plus* one solve per iteration — ridge is the iteration-free floor)",
        rep.total_secs, rep.ledger.gc_ands, r2
    );
    assert!(r2 > 0.9999);
    println!("\nablations OK");
}
