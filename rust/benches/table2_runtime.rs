//! Table 2 reproduction: convergence iterations + total runtime for
//! Newton / PrivLogit-Hessian / PrivLogit-Local on every paper workload.
//!
//! Backend policy (DESIGN.md §7): **real** cryptography for p ≤ 12
//! workloads, the **calibrated cost model** above (run
//! `cargo bench --bench micro_primitives` first to calibrate for this
//! machine). Absolute seconds differ from the paper's Java/ObliVM two-PC
//! testbed; the comparison shape is the reproduction target.
//!
//! `PRIVLOGIT_QUICK=1` skips the largest SimuX workloads.

use privlogit::coordinator::fleet::LocalFleet;
use privlogit::coordinator::{Backend, Experiment};
use privlogit::data::{load_workload, Workload, WORKLOADS};
use privlogit::gc::word::FixedFmt;
use privlogit::metrics::{table2_header, table2_row};
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

/// Paper Table 2 runtimes (seconds): (Newton, PL-Hessian, PL-Local).
fn paper_secs(name: &str) -> Option<(f64, f64, f64)> {
    Some(match name {
        "Wine" => (32.0, 24.0, 17.0),
        "Loans" => (492.0, 260.0, 104.0),
        "Insurance" => (843.0, 978.0, 144.0),
        "News" => (1442.0, 621.0, 313.0),
        "SimuX10" => (26.0, 24.0, 13.0),
        "SimuX12" => (38.0, 37.0, 17.0),
        "SimuX50" => (1549.0, 1052.0, 383.0),
        "SimuX100" => (13138.0, 7817.0, 1807.0),
        "SimuX150" => (42951.0, 25030.0, 6055.0),
        "SimuX200" => (114522.0, 56917.0, 14105.0),
        "SimuX400" => (f64::NAN, f64::NAN, 110598.0),
        _ => return None,
    })
}

fn run_workload(w: &Workload) -> (usize, usize, [f64; 3], [f64; 3], &'static str) {
    let data = load_workload(*w);
    let backend = if w.p <= 12 { Backend::Real } else { Backend::Model };
    let mut iters = (0usize, 0usize);
    let mut totals = [0.0; 3];
    let mut iter_phase = [0.0; 3];
    for (k, proto) in Protocol::ALL.iter().enumerate() {
        let exp = Experiment {
            dataset: data.clone(),
            orgs: 4,
            protocol: *proto,
            backend,
            modulus_bits: 1024,
            fmt: FixedFmt::DEFAULT,
            cfg: ProtocolConfig::default(),
            threaded_nodes: false,
            center_tcp: false,
            peer: None,
            seed: 99,
        };
        // avoid PJRT client churn across many runs: CPU engine here
        let mut fleet = LocalFleet::new(data.partition(4), Box::new(CpuCompute));
        let rep = match backend {
            Backend::Real => {
                let mut fab =
                    privlogit::mpc::RealFabric::new(exp.modulus_bits, exp.fmt, exp.seed);
                proto.run(&mut fab, &mut fleet, &exp.cfg).expect("run")
            }
            _ => {
                let mut fab = privlogit::mpc::ModelFabric::new(2048, exp.fmt);
                proto.run(&mut fab, &mut fleet, &exp.cfg).expect("run")
            }
        };
        assert!(rep.converged, "{} on {}", proto.name(), w.name);
        totals[k] = rep.total_secs;
        iter_phase[k] = rep.total_secs - rep.setup_secs;
        match proto {
            Protocol::Newton => iters.0 = rep.iterations,
            _ => iters.1 = rep.iterations,
        }
    }
    let label = if backend == Backend::Real { "real" } else { "model" };
    (iters.0, iters.1, totals, iter_phase, label)
}

fn main() {
    let quick = std::env::var("PRIVLOGIT_QUICK").is_ok();
    println!("=== Table 2: iterations and runtime (ours vs paper) ===\n");
    println!("{}", table2_header());
    let mut summary = Vec::new();
    for w in WORKLOADS {
        if quick && (w.p > 100) {
            eprintln!("[quick] skipping {}", w.name);
            continue;
        }
        let (it_n, it_pl, totals, iter_phase, label) = run_workload(w);
        println!(
            "{}  <- ours [{label}]",
            table2_row(w.name, (it_n, it_pl), (totals[0], totals[1], totals[2]))
        );
        if let Some(ps) = paper_secs(w.name) {
            println!(
                "{}  <- paper",
                table2_row(w.name, w.paper_iters, ps)
            );
        }
        summary.push((w.name, it_n, it_pl, totals, iter_phase, label));
    }
    println!("\niteration-phase times (setup amortized — the accounting the paper's");
    println!("PL-Local column implies; see EXPERIMENTS.md):");
    for (name, _, _, _, ip, _) in &summary {
        println!(
            "  {:<10} newton {:>9.1}s  pl-hessian {:>9.1}s  pl-local {:>9.1}s",
            name, ip[0], ip[1], ip[2]
        );
    }
    // Reproduction checks. The modeled rows carry the paper's cost
    // structure and must honor its Table-2 claim strictly. The real
    // small-p rows run on in-process AES-NI garbling, where GC is
    // relatively ~100× cheaper vs Paillier than on the paper's 2015
    // ObliVM/ethernet testbed — there PL-Local's many cheap iterations
    // can total slightly more than Newton's few garbled ones (a genuine
    // cost-structure finding, recorded in EXPERIMENTS.md), so only a
    // loose bound applies.
    for (name, it_n, it_pl, totals, _, label) in &summary {
        assert!(it_pl > it_n, "{name}: PrivLogit iterates more");
        let slack = if *label == "model" { 1.05 } else { 1.6 };
        assert!(
            totals[2] <= totals[0] * slack,
            "{name} [{label}]: PL-Local bound ({:.1}s vs {:.1}s)",
            totals[2],
            totals[0]
        );
    }
    println!("\ntable2_runtime OK");
}
