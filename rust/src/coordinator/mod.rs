//! Layer-3 coordination: the experiment runner tying together datasets,
//! node fleets, secure fabrics and protocols (the deployment shape of the
//! paper's Figure 1).
//!
//! [`fleet`] implements the organizations (including the threaded
//! worker topology); [`Experiment`] is the single entry point the CLI,
//! examples and benches all drive.

pub mod fleet;

use crate::config::Config;
use crate::data::{load_workload, workload, Dataset};
use crate::gc::word::FixedFmt;
use crate::mpc::{ModelFabric, RealFabric};
use crate::protocols::{Protocol, ProtocolConfig, RunReport};
use crate::runtime;
use fleet::{Fleet, LocalFleet, ThreadedFleet};

/// Which secure backend to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Everything executed (Paillier + garbled circuits).
    Real,
    /// Calibrated cost model (paper-scale sweeps).
    Model,
    /// Real for small p, modeled above [`Experiment::REAL_P_LIMIT`].
    Auto,
}

impl Backend {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "real" => Some(Backend::Real),
            "model" | "modeled" => Some(Backend::Model),
            "auto" => Some(Backend::Auto),
            _ => None,
        }
    }
}

/// A fully-specified experiment.
#[derive(Clone, Debug)]
pub struct Experiment {
    /// Dataset (a paper workload name or synthetic spec).
    pub dataset: Dataset,
    /// Number of organizations (paper partitions 4–20).
    pub orgs: usize,
    /// Protocol to run.
    pub protocol: Protocol,
    /// Secure backend selection.
    pub backend: Backend,
    /// Paillier modulus bits for the real backend.
    pub modulus_bits: usize,
    /// Fixed-point format.
    pub fmt: FixedFmt,
    /// Optimizer settings.
    pub cfg: ProtocolConfig,
    /// Use the threaded node fleet (real parallel node workers).
    pub threaded_nodes: bool,
    /// RNG seed for the real backend.
    pub seed: u64,
}

impl Experiment {
    /// Above this dimensionality `Backend::Auto` switches to the cost
    /// model (a real garbled Cholesky at p=24 is ~10⁷ AND gates — fine;
    /// at p=100 it is ~10⁹ per Newton iteration).
    pub const REAL_P_LIMIT: usize = 24;

    /// Build from a parsed [`Config`].
    pub fn from_config(c: &Config) -> anyhow::Result<Experiment> {
        let dataset = match workload(&c.dataset) {
            Some(w) => load_workload(w),
            None => anyhow::bail!(
                "unknown dataset {:?} — `privlogit list` shows the paper suite",
                c.dataset
            ),
        };
        let protocol = Protocol::parse(&c.protocol)
            .ok_or_else(|| anyhow::anyhow!("unknown protocol {:?}", c.protocol))?;
        let backend = Backend::parse(&c.backend)
            .ok_or_else(|| anyhow::anyhow!("unknown backend {:?}", c.backend))?;
        Ok(Experiment {
            dataset,
            orgs: c.orgs,
            protocol,
            backend,
            modulus_bits: c.modulus_bits,
            fmt: FixedFmt::DEFAULT,
            cfg: ProtocolConfig { lambda: c.lambda, tol: c.tol, max_iters: c.max_iters },
            threaded_nodes: c.threaded,
            seed: c.seed,
        })
    }

    /// Resolve `Auto` for this experiment's dimensionality.
    pub fn effective_backend(&self) -> Backend {
        match self.backend {
            Backend::Auto => {
                if self.dataset.p() <= Self::REAL_P_LIMIT {
                    Backend::Real
                } else {
                    Backend::Model
                }
            }
            b => b,
        }
    }

    fn make_fleet(&self) -> Box<dyn Fleet> {
        let parts = self.dataset.partition(self.orgs);
        if self.threaded_nodes {
            Box::new(ThreadedFleet::spawn(parts))
        } else {
            Box::new(LocalFleet::new(parts, runtime::default_engine()))
        }
    }

    /// Run the experiment, returning the protocol report.
    pub fn run(&self) -> RunReport {
        let mut fleet = self.make_fleet();
        match self.effective_backend() {
            Backend::Real => {
                let mut fab = RealFabric::new(self.modulus_bits, self.fmt, self.seed);
                self.protocol.run(&mut fab, fleet.as_mut(), &self.cfg)
            }
            Backend::Model | Backend::Auto => {
                let mut fab = ModelFabric::new(2048, self.fmt);
                self.protocol.run(&mut fab, fleet.as_mut(), &self.cfg)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_backend_switches_on_p() {
        let mut c = Config::default();
        c.dataset = "Wine".into();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.effective_backend(), Backend::Real); // p=12
        c.dataset = "SimuX100".into();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.effective_backend(), Backend::Model);
    }

    #[test]
    fn from_config_rejects_unknowns() {
        let mut c = Config::default();
        c.dataset = "nope".into();
        assert!(Experiment::from_config(&c).is_err());
        let mut c = Config::default();
        c.protocol = "sgd".into();
        assert!(Experiment::from_config(&c).is_err());
    }

    /// Full experiment pipeline smoke: modeled backend over the threaded
    /// fleet on a paper workload.
    #[test]
    fn experiment_runs_end_to_end_modeled() {
        let mut c = Config::default();
        c.dataset = "Wine".into();
        c.protocol = "privlogit-local".into();
        c.backend = "model".into();
        c.threaded = true;
        c.orgs = 4;
        let e = Experiment::from_config(&c).unwrap();
        let rep = e.run();
        assert!(rep.converged);
        assert_eq!(rep.orgs, 4);
        assert_eq!(rep.p, 12);
        assert!(rep.total_secs > 0.0);
    }
}
