//! Layer-3 coordination: the experiment runner tying together datasets,
//! node fleets, secure fabrics and protocols (the deployment shape of the
//! paper's Figure 1).
//!
//! [`fleet`] implements the organizations (including the threaded
//! worker topology; the TCP topology lives in [`crate::net`]);
//! [`Experiment`] is the single entry point the CLI, examples and
//! benches all drive for local runs, and [`run_protocol`] is the shared
//! runner the distributed `privlogit center` mode reuses with a
//! [`crate::net::RemoteFleet`] over real node servers.

pub mod checkpoint;
pub mod fleet;

use crate::config::Config;
use crate::data::{dataset_by_name, Dataset};
use crate::gc::word::FixedFmt;
use crate::mpc::{ModelFabric, RealFabric};
use crate::protocols::{DurableRun, Protocol, ProtocolConfig, RunReport};
use crate::runtime;
use fleet::{Fleet, LocalFleet, ThreadedFleet};

/// Which secure backend to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Everything executed (Paillier + garbled circuits).
    Real,
    /// Calibrated cost model (paper-scale sweeps).
    Model,
    /// Real for small p, modeled above [`Experiment::REAL_P_LIMIT`].
    Auto,
}

impl Backend {
    /// Valid CLI spellings, for error messages.
    pub const VALID_NAMES: &'static str = "real | model (modeled) | auto";

    /// Parse a CLI name (no error text; prefer `str::parse::<Backend>`
    /// where a descriptive error can reach the user).
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "real" => Some(Backend::Real),
            "model" | "modeled" => Some(Backend::Model),
            "auto" => Some(Backend::Auto),
            _ => None,
        }
    }
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;

    /// Parse a CLI name; a typo's error names the valid spellings.
    fn from_str(s: &str) -> Result<Backend, anyhow::Error> {
        Backend::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown backend {s:?} — valid: {}", Backend::VALID_NAMES)
        })
    }
}

/// How the two Center servers' garbled-circuit link is deployed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CenterLink {
    /// Both halves as threads of this process over an in-memory queue.
    Mem,
    /// Both halves as threads of this process over real TCP loopback
    /// sockets (the paper's two-PC testbed shape, one process).
    TcpLoopback,
    /// The evaluator half is a remote `privlogit center-b` process at
    /// this address — the fully split deployment.
    Peer(String),
}

/// A fully-specified experiment.
#[derive(Clone, Debug)]
pub struct Experiment {
    /// Dataset (a paper workload name or synthetic spec).
    pub dataset: Dataset,
    /// Number of organizations (paper partitions 4–20).
    pub orgs: usize,
    /// Protocol to run.
    pub protocol: Protocol,
    /// Secure backend selection.
    pub backend: Backend,
    /// Paillier modulus bits for the real backend.
    pub modulus_bits: usize,
    /// Fixed-point format.
    pub fmt: FixedFmt,
    /// Optimizer settings.
    pub cfg: ProtocolConfig,
    /// Use the threaded node fleet (real parallel node workers).
    pub threaded_nodes: bool,
    /// Run the two Center servers' GC link over real TCP loopback
    /// sockets instead of the in-memory queue (real backend only).
    pub center_tcp: bool,
    /// Address of a remote `privlogit center-b` evaluator process
    /// (real backend only; overrides `center_tcp`).
    pub peer: Option<String>,
    /// Disable ciphertext slot-packing of the statistic fan-in (the
    /// legacy parity-reference wire; real backend only).
    pub no_pack: bool,
    /// RNG seed for the real backend.
    pub seed: u64,
}

impl Experiment {
    /// Above this dimensionality `Backend::Auto` switches to the cost
    /// model (a real garbled Cholesky at p=24 is ~10⁷ AND gates — fine;
    /// at p=100 it is ~10⁹ per Newton iteration).
    pub const REAL_P_LIMIT: usize = 24;

    /// Build from a parsed [`Config`].
    pub fn from_config(c: &Config) -> anyhow::Result<Experiment> {
        let dataset = match dataset_by_name(&c.dataset) {
            Some(d) => d,
            None => anyhow::bail!(
                "unknown dataset {:?} — `privlogit list` shows the paper suite, \
                 or use an inline spec like synth:n=1200,p=4,seed=7",
                c.dataset
            ),
        };
        let protocol: Protocol = c.protocol.parse()?;
        let backend: Backend = c.backend.parse()?;
        Ok(Experiment {
            dataset,
            orgs: c.orgs,
            protocol,
            backend,
            modulus_bits: c.modulus_bits,
            fmt: FixedFmt::DEFAULT,
            cfg: ProtocolConfig { lambda: c.lambda, tol: c.tol, max_iters: c.max_iters },
            threaded_nodes: c.threaded,
            center_tcp: c.center_tcp,
            peer: (!c.peer.is_empty()).then(|| c.peer.clone()),
            no_pack: c.no_pack,
            seed: c.seed,
        })
    }

    /// The center-link deployment this experiment asks for.
    pub fn center_link(&self) -> CenterLink {
        match (&self.peer, self.center_tcp) {
            (Some(addr), _) => CenterLink::Peer(addr.clone()),
            (None, true) => CenterLink::TcpLoopback,
            (None, false) => CenterLink::Mem,
        }
    }

    /// Resolve `Auto` for this experiment's dimensionality.
    pub fn effective_backend(&self) -> Backend {
        resolve_backend(self.backend, self.dataset.p())
    }

    fn make_fleet(&self) -> Box<dyn Fleet> {
        let parts = self.dataset.partition(self.orgs);
        if self.threaded_nodes {
            Box::new(ThreadedFleet::spawn(parts))
        } else {
            Box::new(LocalFleet::new(parts, runtime::default_engine()))
        }
    }

    /// Run the experiment, returning the protocol report (or the error
    /// a dying node/center peer surfaced).
    pub fn run(&self) -> anyhow::Result<RunReport> {
        let mut fleet = self.make_fleet();
        run_protocol_durable(
            self.protocol,
            self.backend,
            self.modulus_bits,
            self.fmt,
            &self.cfg,
            self.seed,
            &self.center_link(),
            fleet.as_mut(),
            crate::mpc::peer::PEER_CONNECT_TIMEOUT,
            &DurableRun::default(),
            self.no_pack,
        )
    }
}

/// The one `Auto` resolution rule: real crypto up to
/// [`Experiment::REAL_P_LIMIT`], the calibrated cost model above it.
fn resolve_backend(backend: Backend, p: usize) -> Backend {
    match backend {
        Backend::Auto => {
            if p <= Experiment::REAL_P_LIMIT {
                Backend::Real
            } else {
                Backend::Model
            }
        }
        b => b,
    }
}

/// Run one protocol over an already-built fleet — the shared runner
/// behind [`Experiment::run`] and the distributed `privlogit center` /
/// `center-a` modes (which supply a [`crate::net::RemoteFleet`] and
/// have no local [`Dataset`]). `Backend::Auto` resolves against the
/// fleet's dimensionality.
///
/// With the real backend the fabric's Paillier key is first installed
/// at the fleet ([`Fleet::install_key`]): a remote fleet switches its
/// node servers to node-side encryption, so only ciphertexts cross the
/// fleet wire; in-process fleets decline and keep encrypting at the
/// fabric boundary.
#[allow(clippy::too_many_arguments)]
pub fn run_protocol(
    protocol: Protocol,
    backend: Backend,
    modulus_bits: usize,
    fmt: FixedFmt,
    cfg: &ProtocolConfig,
    seed: u64,
    link: &CenterLink,
    fleet: &mut dyn Fleet,
) -> anyhow::Result<RunReport> {
    run_protocol_durable(
        protocol,
        backend,
        modulus_bits,
        fmt,
        cfg,
        seed,
        link,
        fleet,
        crate::mpc::peer::PEER_CONNECT_TIMEOUT,
        &DurableRun::default(),
        false,
    )
}

/// [`run_protocol`] with session durability (`--state-dir` /
/// `--resume`) and the connect-retry budget the center-b peer link
/// shares with the fleet. `durable.epoch` is announced on the peer
/// handshake and `SetKey` so S2's replay guard matches the node
/// fleet's; a resuming caller must also have built its fleet at the
/// same epoch ([`crate::net::fleet::FleetOptions::epoch`]).
///
/// A resume re-validates session identity before any crypto runs: the
/// checkpoint's protocol, seed and modulus bits must match this
/// invocation, because the same seed is what regenerates the same
/// Paillier modulus — and with it the session id that stitches both
/// incarnations into one logical session in the merged timeline.
#[allow(clippy::too_many_arguments)]
pub fn run_protocol_durable(
    protocol: Protocol,
    backend: Backend,
    modulus_bits: usize,
    fmt: FixedFmt,
    cfg: &ProtocolConfig,
    seed: u64,
    link: &CenterLink,
    fleet: &mut dyn Fleet,
    connect_timeout: std::time::Duration,
    durable: &DurableRun,
    no_pack: bool,
) -> anyhow::Result<RunReport> {
    if let Some(cp) = &durable.resume {
        anyhow::ensure!(
            cp.protocol == protocol.name(),
            "checkpoint was written by {:?}, this run is {:?} — resume cannot \
             switch protocols",
            cp.protocol,
            protocol.name()
        );
        anyhow::ensure!(
            cp.seed == seed && cp.modulus_bits == modulus_bits as u64,
            "checkpoint session identity mismatch: it ran seed={} modulus_bits={}, \
             this run has seed={seed} modulus_bits={modulus_bits} — the same seed is \
             required to regenerate the same Paillier key and session id",
            cp.seed,
            cp.modulus_bits
        );
    }
    let report = match resolve_backend(backend, fleet.p()) {
        Backend::Real => {
            let mut fab = match link {
                CenterLink::Mem => RealFabric::new(modulus_bits, fmt, seed),
                CenterLink::TcpLoopback => {
                    RealFabric::new_tcp_loopback(modulus_bits, fmt, seed)?
                }
                CenterLink::Peer(addr) => RealFabric::connect_peer_with(
                    modulus_bits,
                    fmt,
                    seed,
                    addr,
                    connect_timeout,
                    durable.epoch,
                )?,
            };
            if !no_pack {
                // Negotiate the slot-packing layout before the key is
                // installed: the fan-in bound covers one contribution
                // per organization plus the center's regularizer
                // `add_plain` and one spare fold; the apply headroom is
                // validated for `Enc(H̃⁻¹)⊗g` rows of width p. A
                // modulus too small to host two slots falls back to the
                // unpacked wire rather than failing the run.
                let packed =
                    fab.enable_packing(fleet.orgs() as u64 + 2, fleet.p() as u64)?;
                if !packed {
                    crate::obs::info(format_args!(
                        "modulus too small for ciphertext packing; running unpacked"
                    ));
                }
            }
            fleet.install_key(&fab.fleet_key())?;
            protocol.run_durable(&mut fab, fleet, cfg, durable)
        }
        Backend::Model | Backend::Auto => {
            anyhow::ensure!(
                !matches!(link, CenterLink::Peer(_)),
                "the remote center-b peer link requires the real backend"
            );
            let mut fab = ModelFabric::new(2048, fmt);
            protocol.run_durable(&mut fab, fleet, cfg, durable)
        }
    };
    // Protocol end is a trace boundary: buffered span events hit the
    // JSONL file now, whatever happens to this process afterwards.
    crate::obs::flush();
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_backend_switches_on_p() {
        let mut c = Config { dataset: "Wine".into(), ..Config::default() };
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.effective_backend(), Backend::Real); // p=12
        c.dataset = "SimuX100".into();
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.effective_backend(), Backend::Model);
    }

    #[test]
    fn from_config_rejects_unknowns() {
        let c = Config { dataset: "nope".into(), ..Config::default() };
        assert!(Experiment::from_config(&c).is_err());
        let c = Config { protocol: "sgd".into(), ..Config::default() };
        assert!(Experiment::from_config(&c).is_err());
    }

    /// CLI typos must come back with the valid spellings, not a bare
    /// "unknown" (the errors surface verbatim from `privlogit run`).
    #[test]
    fn parse_errors_name_valid_spellings() {
        let c = Config { backend: "gpu".into(), ..Config::default() };
        let err = Experiment::from_config(&c).unwrap_err().to_string();
        assert!(err.contains("gpu"), "{err}");
        assert!(err.contains("real"), "{err}");
        assert!(err.contains("model"), "{err}");
        assert!(err.contains("auto"), "{err}");
        assert_eq!("MODELED".parse::<Backend>().unwrap(), Backend::Model);
    }

    /// Full experiment pipeline smoke: modeled backend over the threaded
    /// fleet on a paper workload.
    #[test]
    fn experiment_runs_end_to_end_modeled() {
        let c = Config {
            dataset: "Wine".into(),
            protocol: "privlogit-local".into(),
            backend: "model".into(),
            threaded: true,
            orgs: 4,
            ..Config::default()
        };
        let e = Experiment::from_config(&c).unwrap();
        assert_eq!(e.center_link(), CenterLink::Mem);
        let rep = e.run().unwrap();
        assert!(rep.converged);
        assert_eq!(rep.orgs, 4);
        assert_eq!(rep.p, 12);
        assert!(rep.total_secs > 0.0);
    }
}
