//! The node fleet: the organizations of Figure 1 as workers.
//!
//! A [`Fleet`] answers the Center's per-round statistic requests. Two
//! implementations live here:
//!
//! * [`LocalFleet`] — sequential in-process evaluation through one
//!   [`NodeCompute`] engine (PJRT or CPU); per-node wall times are still
//!   measured individually so the ledger's parallel-round accounting is
//!   exact.
//! * [`ThreadedFleet`] — one long-lived worker thread per organization,
//!   command/reply message channels, genuinely parallel node compute —
//!   the deployment shape of the paper's distributed architecture.
//!
//! A third implementation lives in the networking subsystem:
//! [`crate::net::fleet::RemoteFleet`] reaches real node *servers* over
//! persistent TCP connections (`privlogit node --listen …`), with the
//! same per-node wall-time attribution plus measured wire bytes
//! ([`FleetNet`]).
//!
//! Every round method returns `Result`: a fleet whose worker or TCP peer
//! dies mid-protocol surfaces a descriptive error the protocol bubbles
//! up to the CLI, instead of panicking.
//!
//! **Where encryption happens.** In-process fleets return *plaintext*
//! statistics ([`NodePayload::Plain`]) — organizations compute freely
//! over their own data (the paper's "privacy-free" node work) and the
//! fabric encrypts at its boundary, attributing the cost to the node.
//! The remote fleet instead installs the Center's Paillier key at the
//! node servers ([`Fleet::install_key`]); from then on nodes encrypt
//! their own replies ([`NodePayload::Enc`]) and only ciphertexts cross
//! the fleet wire — the deployed topology of the paper's threat model.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::bigint::BigUint;
use crate::data::Dataset;
use crate::optim::{local_gram_quarter, local_hessian, local_stats};
use crate::protocols::common::pack_tri;
use crate::runtime::NodeCompute;

/// Paillier + fixed-point material a fleet needs for node-side
/// encryption: the public modulus and the fixed-point format. (Only the
/// modulus travels — the Paillier public key reconstructs from `n`.)
#[derive(Clone, Debug)]
pub struct FleetKey {
    /// Paillier modulus `n`.
    pub n: BigUint,
    /// Fixed-point word width (bits).
    pub w: u32,
    /// Fixed-point fractional bits.
    pub f: u32,
    /// Slot-packing layout for statistic replies (wire v6), when the
    /// session packs; `None` keeps the legacy one-value-per-ciphertext
    /// replies (`--no-pack`, or a modulus too small for two slots).
    pub packing: Option<crate::crypto::packed::PackingParams>,
}

/// An encrypted statistic payload as raw ciphertext residues (elements
/// of `Z*_{n²}`), tagged with its fixed-point scale. The fleet layer
/// stays free of `mpc` types; `protocols::common` converts to `EncVec`.
#[derive(Clone, Debug)]
pub struct EncStat {
    /// Fixed-point scale (bits) of the encoded plaintexts.
    pub scale: u32,
    /// Ciphertext values.
    pub cts: Vec<BigUint>,
}

/// Payload of one node statistic reply.
#[derive(Clone, Debug)]
pub enum NodePayload {
    /// Plaintext values (in-process fleets; the fabric encrypts).
    Plain {
        /// Flat payload (gradient / packed Hessian triangle).
        values: Vec<f64>,
        /// Log-likelihood share (stats requests only).
        loglik: f64,
    },
    /// Node-encrypted Paillier ciphertexts (remote fleets after
    /// [`Fleet::install_key`]). For stats rounds the encrypted
    /// log-likelihood share is appended as the last ciphertext.
    Enc(EncStat),
}

/// One node's reply to a statistics request, with its compute seconds
/// (encryption included when the node encrypts).
#[derive(Clone, Debug)]
pub struct NodeReply {
    /// The statistic payload.
    pub payload: NodePayload,
    /// Node compute seconds (ledger attribution).
    pub secs: f64,
    /// 0-based index of the organization that produced this reply.
    /// Replies can no longer be attributed by *position* once a fleet
    /// supports quorum rounds: after an exclusion the reply vector is a
    /// subset of the original membership, so ledger and error
    /// attribution go through this field.
    pub org: usize,
}

impl NodeReply {
    /// Construct a plaintext reply (the in-process fleets' form),
    /// attributed to org 0 — fleets re-attribute with
    /// [`NodeReply::with_org`].
    pub fn plain(values: Vec<f64>, loglik: f64, secs: f64) -> NodeReply {
        NodeReply { payload: NodePayload::Plain { values, loglik }, secs, org: 0 }
    }

    /// Attribute this reply to organization `org`.
    pub fn with_org(mut self, org: usize) -> NodeReply {
        self.org = org;
        self
    }

    /// Plaintext values. Panics on an encrypted payload — for tests and
    /// plain-path diagnostics; protocol code matches on the payload.
    pub fn values(&self) -> &[f64] {
        match &self.payload {
            NodePayload::Plain { values, .. } => values,
            NodePayload::Enc(_) => panic!("encrypted node reply has no plaintext values"),
        }
    }

    /// Plaintext log-likelihood share. Panics on an encrypted payload.
    pub fn loglik(&self) -> f64 {
        match &self.payload {
            NodePayload::Plain { loglik, .. } => *loglik,
            NodePayload::Enc(_) => panic!("encrypted node reply has no plaintext loglik"),
        }
    }
}

/// One node's reply to a PrivLogit-Local step round: the locally-applied
/// `Enc(H̃⁻¹ g_j)` (scale `2f`) and the encrypted log-likelihood share
/// (scale `f`). Only fleets with node-side encryption produce these.
#[derive(Clone, Debug)]
pub struct StepReply {
    /// `Enc(H̃⁻¹ g_j)` — the node's partial Newton step.
    pub part: EncStat,
    /// `Enc(l_sj)` — one ciphertext.
    pub loglik: EncStat,
    /// Node compute seconds (stats + apply + encryption).
    pub secs: f64,
    /// 0-based index of the organization that produced this reply (see
    /// [`NodeReply::org`]).
    pub org: usize,
}

/// Network traffic measured by a fleet, from the Center's perspective.
/// Zero for the in-process fleets (nothing crosses a real boundary).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetNet {
    /// Bytes sent center → nodes.
    pub bytes_sent: u64,
    /// Bytes received nodes → center.
    pub bytes_recv: u64,
    /// Messages sent center → nodes.
    pub msgs_sent: u64,
    /// Messages received nodes → center.
    pub msgs_recv: u64,
}

/// The Center's view of the organizations.
pub trait Fleet {
    /// Number of organizations.
    fn orgs(&self) -> usize;
    /// Total sample count (public: drives the 1/n scaling).
    fn n_total(&self) -> usize;
    /// Dimensionality.
    fn p(&self) -> usize;
    /// Dataset display name.
    fn dataset_name(&self) -> String;
    /// Per-node fused gradient + log-likelihood at `beta`, × `scale`.
    fn stats(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>>;
    /// Per-node `¼X_jᵀX_j·scale` (packed triangle).
    fn gram(&mut self, scale: f64) -> anyhow::Result<Vec<NodeReply>>;
    /// Per-node exact Hessian `X_jᵀAX_j·scale` (packed triangle).
    fn hessian(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>>;
    /// Engine label for reports.
    fn label(&self) -> String;
    /// Wire traffic between the Center and the nodes (both directions);
    /// zero unless the fleet actually crosses a process boundary.
    fn net_stats(&self) -> FleetNet {
        FleetNet::default()
    }
    /// Fleet-wire traffic broken down per wire tag (both directions);
    /// empty unless the fleet actually crosses a process boundary.
    fn tag_flows(&self) -> std::collections::BTreeMap<u8, crate::obs::TagFlow> {
        std::collections::BTreeMap::new()
    }
    /// Install the Center's Paillier key material at the nodes. Returns
    /// `true` iff nodes will encrypt their replies from now on. The
    /// in-process default declines (plaintext replies, fabric-side
    /// encryption — nothing crosses a process boundary).
    fn install_key(&mut self, _key: &FleetKey) -> anyhow::Result<bool> {
        Ok(false)
    }
    /// Whether [`Fleet::install_key`] succeeded and replies arrive
    /// encrypted.
    fn nodes_encrypt(&self) -> bool {
        false
    }
    /// Broadcast `Enc(H̃⁻¹)` to the nodes (PrivLogit-Local setup; only
    /// meaningful after [`Fleet::install_key`] returned `true`).
    fn install_hinv(&mut self, _hinv: &EncStat) -> anyhow::Result<()> {
        anyhow::bail!("this fleet does not support node-side Enc(H̃⁻¹) application")
    }
    /// One PrivLogit-Local iteration at the nodes: local gradient,
    /// `Enc(H̃⁻¹)⊗g_j`, encrypted log-likelihood (only after
    /// [`Fleet::install_hinv`]).
    fn step(&mut self, _beta: &[f64], _scale: f64) -> anyhow::Result<Vec<StepReply>> {
        anyhow::bail!("this fleet does not support node-side step rounds")
    }
    /// Number of nodes this fleet has excluded after missed rounds
    /// (quorum mode) and not readmitted since; zero for fleets without
    /// fault tolerance.
    fn excluded_count(&self) -> u64 {
        0
    }
    /// Number of readmission events: previously-excluded nodes restored
    /// to live membership after answering a round-boundary probe; zero
    /// for fleets without fault tolerance.
    fn readmitted_count(&self) -> u64 {
        0
    }
    /// `(live, excluded)` node addresses, for session checkpoints;
    /// empty for in-process fleets (no addresses to record).
    fn membership(&self) -> (Vec<String>, Vec<String>) {
        (Vec::new(), Vec::new())
    }
}

/// Sequential fleet over one shared engine.
pub struct LocalFleet {
    parts: Vec<Dataset>,
    engine: Box<dyn NodeCompute>,
}

impl LocalFleet {
    /// Build from partitions and an engine.
    pub fn new(parts: Vec<Dataset>, engine: Box<dyn NodeCompute>) -> Self {
        assert!(!parts.is_empty());
        LocalFleet { parts, engine }
    }
}

impl Fleet for LocalFleet {
    fn orgs(&self) -> usize {
        self.parts.len()
    }
    fn n_total(&self) -> usize {
        self.parts.iter().map(|d| d.n()).sum()
    }
    fn p(&self) -> usize {
        self.parts[0].p()
    }
    fn dataset_name(&self) -> String {
        self.parts[0].name.split('#').next().unwrap_or("?").to_string()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        Ok(self
            .parts
            .iter()
            .enumerate()
            .map(|(j, d)| {
                let t0 = Instant::now();
                let (g, l) = self.engine.stats(d, beta, scale);
                NodeReply::plain(g, l, t0.elapsed().as_secs_f64()).with_org(j)
            })
            .collect())
    }

    fn gram(&mut self, scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        Ok(self
            .parts
            .iter()
            .enumerate()
            .map(|(j, d)| {
                let t0 = Instant::now();
                let h = self.engine.gram_quarter(d, scale);
                NodeReply::plain(pack_tri(&h), 0.0, t0.elapsed().as_secs_f64()).with_org(j)
            })
            .collect())
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        Ok(self
            .parts
            .iter()
            .enumerate()
            .map(|(j, d)| {
                let t0 = Instant::now();
                let h = self.engine.hessian(d, beta, scale);
                NodeReply::plain(pack_tri(&h), 0.0, t0.elapsed().as_secs_f64()).with_org(j)
            })
            .collect())
    }

    fn label(&self) -> String {
        format!("local fleet / {}", self.engine.label())
    }
}

/// Commands the Center sends to node workers.
enum NodeCmd {
    Stats { beta: Vec<f64>, scale: f64 },
    Gram { scale: f64 },
    Hessian { beta: Vec<f64>, scale: f64 },
    Shutdown,
}

/// One worker thread per organization, communicating over channels.
pub struct ThreadedFleet {
    workers: Vec<Worker>,
    n_total: usize,
    p: usize,
    name: String,
}

struct Worker {
    cmd: Sender<NodeCmd>,
    reply: Receiver<NodeReply>,
    handle: Option<JoinHandle<()>>,
}

impl ThreadedFleet {
    /// Spawn one worker per partition (each with its own CPU engine —
    /// PJRT clients are not shared across threads).
    pub fn spawn(parts: Vec<Dataset>) -> Self {
        assert!(!parts.is_empty());
        let n_total = parts.iter().map(|d| d.n()).sum();
        let p = parts[0].p();
        let name = parts[0].name.split('#').next().unwrap_or("?").to_string();
        let workers = parts
            .into_iter()
            .map(|data| {
                let (cmd_tx, cmd_rx) = channel::<NodeCmd>();
                let (rep_tx, rep_rx) = channel::<NodeReply>();
                let handle = std::thread::spawn(move || node_main(data, cmd_rx, rep_tx));
                Worker { cmd: cmd_tx, reply: rep_rx, handle: Some(handle) }
            })
            .collect();
        ThreadedFleet { workers, n_total, p, name }
    }

    fn round(&mut self, make: impl Fn() -> NodeCmd) -> anyhow::Result<Vec<NodeReply>> {
        for (j, w) in self.workers.iter().enumerate() {
            w.cmd
                .send(make())
                .map_err(|_| anyhow::anyhow!("node worker {j} died before the round"))?;
        }
        self.workers
            .iter()
            .enumerate()
            .map(|(j, w)| {
                w.reply
                    .recv()
                    .map(|r| r.with_org(j))
                    .map_err(|_| anyhow::anyhow!("node worker {j} died mid-round"))
            })
            .collect()
    }
}

fn node_main(data: Dataset, cmd: Receiver<NodeCmd>, reply: Sender<NodeReply>) {
    while let Ok(c) = cmd.recv() {
        let t0 = Instant::now();
        let rep = match c {
            NodeCmd::Stats { beta, scale } => {
                let s = local_stats(&data, &beta);
                NodeReply::plain(
                    s.grad.iter().map(|v| v * scale).collect(),
                    s.loglik * scale,
                    0.0,
                )
            }
            NodeCmd::Gram { scale } => {
                let mut h = local_gram_quarter(&data);
                h.scale(scale);
                NodeReply::plain(pack_tri(&h), 0.0, 0.0)
            }
            NodeCmd::Hessian { beta, scale } => {
                let mut h = local_hessian(&data, &beta);
                h.scale(scale);
                NodeReply::plain(pack_tri(&h), 0.0, 0.0)
            }
            NodeCmd::Shutdown => return,
        };
        let rep = NodeReply { secs: t0.elapsed().as_secs_f64(), ..rep };
        if reply.send(rep).is_err() {
            return;
        }
    }
}

impl Fleet for ThreadedFleet {
    fn orgs(&self) -> usize {
        self.workers.len()
    }
    fn n_total(&self) -> usize {
        self.n_total
    }
    fn p(&self) -> usize {
        self.p
    }
    fn dataset_name(&self) -> String {
        self.name.clone()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let b = beta.to_vec();
        self.round(|| NodeCmd::Stats { beta: b.clone(), scale })
    }

    fn gram(&mut self, scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        self.round(|| NodeCmd::Gram { scale })
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let b = beta.to_vec();
        self.round(|| NodeCmd::Hessian { beta: b.clone(), scale })
    }

    fn label(&self) -> String {
        format!("threaded fleet ({} workers)", self.workers.len())
    }
}

impl Drop for ThreadedFleet {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(NodeCmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthesize;
    use crate::runtime::CpuCompute;
    use crate::testutil::assert_all_close;

    #[test]
    fn threaded_matches_local() {
        let d = synthesize("t", 900, 5, 41);
        let parts = d.partition(3);
        let mut local = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut threaded = ThreadedFleet::spawn(parts);
        let beta = vec![0.1, -0.2, 0.3, 0.0, 0.05];
        let scale = 1.0 / 900.0;
        let a = local.stats(&beta, scale).unwrap();
        let b = threaded.stats(&beta, scale).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_all_close(x.values(), y.values(), 1e-12, "stats parity");
            assert!((x.loglik() - y.loglik()).abs() < 1e-12);
        }
        let ga = local.gram(scale).unwrap();
        let gb = threaded.gram(scale).unwrap();
        for (x, y) in ga.iter().zip(&gb) {
            assert_all_close(x.values(), y.values(), 1e-12, "gram parity");
        }
        let ha = local.hessian(&beta, scale).unwrap();
        let hb = threaded.hessian(&beta, scale).unwrap();
        for (x, y) in ha.iter().zip(&hb) {
            assert_all_close(x.values(), y.values(), 1e-12, "hessian parity");
        }
        assert_eq!(threaded.orgs(), 3);
        assert_eq!(threaded.n_total(), 900);
        assert_eq!(threaded.p(), 5);
        assert_eq!(threaded.dataset_name(), "t");
        // In-process fleets never encrypt node-side.
        assert!(!threaded.nodes_encrypt());
        assert!(threaded
            .install_key(&FleetKey { n: BigUint::from_u64(77), w: 40, f: 24, packing: None })
            .is_ok_and(|enc| !enc));
        assert!(threaded.step(&beta, scale).is_err());
    }

    #[test]
    fn threaded_fleet_shutdown_clean() {
        let d = synthesize("t", 90, 3, 42);
        let fleet = ThreadedFleet::spawn(d.partition(5));
        drop(fleet); // must join without hanging
    }
}
