//! The node fleet: the organizations of Figure 1 as workers.
//!
//! A [`Fleet`] answers the Center's per-round statistic requests. Two
//! implementations:
//!
//! * [`LocalFleet`] — sequential in-process evaluation through one
//!   [`NodeCompute`] engine (PJRT or CPU); per-node wall times are still
//!   measured individually so the ledger's parallel-round accounting is
//!   exact.
//! * [`ThreadedFleet`] — one long-lived worker thread per organization,
//!   command/reply message channels, genuinely parallel node compute —
//!   the deployment shape of the paper's distributed architecture.
//!
//! A third implementation lives in the networking subsystem:
//! [`crate::net::fleet::RemoteFleet`] reaches real node *servers* over
//! persistent TCP connections (`privlogit node --listen …`), with the
//! same per-node wall-time attribution plus measured wire bytes
//! ([`FleetNet`]).
//!
//! Node-side values returned here are *plaintext* (organizations compute
//! freely over their own data — the paper's "privacy-free" node work);
//! encryption happens at the fabric boundary and is attributed to the
//! node by the ledger.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::data::Dataset;
use crate::optim::{local_gram_quarter, local_hessian, local_stats};
use crate::protocols::common::pack_tri;
use crate::runtime::NodeCompute;

/// One node's reply to a statistics request, with its compute seconds.
#[derive(Clone, Debug)]
pub struct NodeReply {
    /// Flat payload (gradient / packed Hessian triangle).
    pub values: Vec<f64>,
    /// Log-likelihood share (stats requests only).
    pub loglik: f64,
    /// Node compute seconds (ledger attribution).
    pub secs: f64,
}

/// Network traffic measured by a fleet, from the Center's perspective.
/// Zero for the in-process fleets (nothing crosses a real boundary).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetNet {
    /// Bytes sent center → nodes.
    pub bytes_sent: u64,
    /// Bytes received nodes → center.
    pub bytes_recv: u64,
    /// Messages sent center → nodes.
    pub msgs_sent: u64,
    /// Messages received nodes → center.
    pub msgs_recv: u64,
}

/// The Center's view of the organizations.
pub trait Fleet {
    /// Number of organizations.
    fn orgs(&self) -> usize;
    /// Total sample count (public: drives the 1/n scaling).
    fn n_total(&self) -> usize;
    /// Dimensionality.
    fn p(&self) -> usize;
    /// Dataset display name.
    fn dataset_name(&self) -> String;
    /// Per-node fused gradient + log-likelihood at `beta`, × `scale`.
    fn stats(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply>;
    /// Per-node `¼X_jᵀX_j·scale` (packed triangle).
    fn gram(&mut self, scale: f64) -> Vec<NodeReply>;
    /// Per-node exact Hessian `X_jᵀAX_j·scale` (packed triangle).
    fn hessian(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply>;
    /// Engine label for reports.
    fn label(&self) -> String;
    /// Wire traffic between the Center and the nodes (both directions);
    /// zero unless the fleet actually crosses a process boundary.
    fn net_stats(&self) -> FleetNet {
        FleetNet::default()
    }
}

/// Sequential fleet over one shared engine.
pub struct LocalFleet {
    parts: Vec<Dataset>,
    engine: Box<dyn NodeCompute>,
}

impl LocalFleet {
    /// Build from partitions and an engine.
    pub fn new(parts: Vec<Dataset>, engine: Box<dyn NodeCompute>) -> Self {
        assert!(!parts.is_empty());
        LocalFleet { parts, engine }
    }
}

impl Fleet for LocalFleet {
    fn orgs(&self) -> usize {
        self.parts.len()
    }
    fn n_total(&self) -> usize {
        self.parts.iter().map(|d| d.n()).sum()
    }
    fn p(&self) -> usize {
        self.parts[0].p()
    }
    fn dataset_name(&self) -> String {
        self.parts[0].name.split('#').next().unwrap_or("?").to_string()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply> {
        self.parts
            .iter()
            .map(|d| {
                let t0 = Instant::now();
                let (g, l) = self.engine.stats(d, beta, scale);
                NodeReply { values: g, loglik: l, secs: t0.elapsed().as_secs_f64() }
            })
            .collect()
    }

    fn gram(&mut self, scale: f64) -> Vec<NodeReply> {
        self.parts
            .iter()
            .map(|d| {
                let t0 = Instant::now();
                let h = self.engine.gram_quarter(d, scale);
                NodeReply {
                    values: pack_tri(&h),
                    loglik: 0.0,
                    secs: t0.elapsed().as_secs_f64(),
                }
            })
            .collect()
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply> {
        self.parts
            .iter()
            .map(|d| {
                let t0 = Instant::now();
                let h = self.engine.hessian(d, beta, scale);
                NodeReply {
                    values: pack_tri(&h),
                    loglik: 0.0,
                    secs: t0.elapsed().as_secs_f64(),
                }
            })
            .collect()
    }

    fn label(&self) -> String {
        format!("local fleet / {}", self.engine.label())
    }
}

/// Commands the Center sends to node workers.
enum NodeCmd {
    Stats { beta: Vec<f64>, scale: f64 },
    Gram { scale: f64 },
    Hessian { beta: Vec<f64>, scale: f64 },
    Shutdown,
}

/// One worker thread per organization, communicating over channels.
pub struct ThreadedFleet {
    workers: Vec<Worker>,
    n_total: usize,
    p: usize,
    name: String,
}

struct Worker {
    cmd: Sender<NodeCmd>,
    reply: Receiver<NodeReply>,
    handle: Option<JoinHandle<()>>,
}

impl ThreadedFleet {
    /// Spawn one worker per partition (each with its own CPU engine —
    /// PJRT clients are not shared across threads).
    pub fn spawn(parts: Vec<Dataset>) -> Self {
        assert!(!parts.is_empty());
        let n_total = parts.iter().map(|d| d.n()).sum();
        let p = parts[0].p();
        let name = parts[0].name.split('#').next().unwrap_or("?").to_string();
        let workers = parts
            .into_iter()
            .map(|data| {
                let (cmd_tx, cmd_rx) = channel::<NodeCmd>();
                let (rep_tx, rep_rx) = channel::<NodeReply>();
                let handle = std::thread::spawn(move || node_main(data, cmd_rx, rep_tx));
                Worker { cmd: cmd_tx, reply: rep_rx, handle: Some(handle) }
            })
            .collect();
        ThreadedFleet { workers, n_total, p, name }
    }

    fn round(&mut self, make: impl Fn() -> NodeCmd) -> Vec<NodeReply> {
        for w in &self.workers {
            w.cmd.send(make()).expect("node worker alive");
        }
        self.workers
            .iter()
            .map(|w| w.reply.recv().expect("node reply"))
            .collect()
    }
}

fn node_main(data: Dataset, cmd: Receiver<NodeCmd>, reply: Sender<NodeReply>) {
    while let Ok(c) = cmd.recv() {
        let t0 = Instant::now();
        let rep = match c {
            NodeCmd::Stats { beta, scale } => {
                let s = local_stats(&data, &beta);
                NodeReply {
                    values: s.grad.iter().map(|v| v * scale).collect(),
                    loglik: s.loglik * scale,
                    secs: 0.0,
                }
            }
            NodeCmd::Gram { scale } => {
                let mut h = local_gram_quarter(&data);
                h.scale(scale);
                NodeReply { values: pack_tri(&h), loglik: 0.0, secs: 0.0 }
            }
            NodeCmd::Hessian { beta, scale } => {
                let mut h = local_hessian(&data, &beta);
                h.scale(scale);
                NodeReply { values: pack_tri(&h), loglik: 0.0, secs: 0.0 }
            }
            NodeCmd::Shutdown => return,
        };
        let rep = NodeReply { secs: t0.elapsed().as_secs_f64(), ..rep };
        if reply.send(rep).is_err() {
            return;
        }
    }
}

impl Fleet for ThreadedFleet {
    fn orgs(&self) -> usize {
        self.workers.len()
    }
    fn n_total(&self) -> usize {
        self.n_total
    }
    fn p(&self) -> usize {
        self.p
    }
    fn dataset_name(&self) -> String {
        self.name.clone()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply> {
        let b = beta.to_vec();
        self.round(|| NodeCmd::Stats { beta: b.clone(), scale })
    }

    fn gram(&mut self, scale: f64) -> Vec<NodeReply> {
        self.round(|| NodeCmd::Gram { scale })
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply> {
        let b = beta.to_vec();
        self.round(|| NodeCmd::Hessian { beta: b.clone(), scale })
    }

    fn label(&self) -> String {
        format!("threaded fleet ({} workers)", self.workers.len())
    }
}

impl Drop for ThreadedFleet {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(NodeCmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthesize;
    use crate::runtime::CpuCompute;
    use crate::testutil::assert_all_close;

    #[test]
    fn threaded_matches_local() {
        let d = synthesize("t", 900, 5, 41);
        let parts = d.partition(3);
        let mut local = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut threaded = ThreadedFleet::spawn(parts);
        let beta = vec![0.1, -0.2, 0.3, 0.0, 0.05];
        let scale = 1.0 / 900.0;
        let a = local.stats(&beta, scale);
        let b = threaded.stats(&beta, scale);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_all_close(&x.values, &y.values, 1e-12, "stats parity");
            assert!((x.loglik - y.loglik).abs() < 1e-12);
        }
        let ga = local.gram(scale);
        let gb = threaded.gram(scale);
        for (x, y) in ga.iter().zip(&gb) {
            assert_all_close(&x.values, &y.values, 1e-12, "gram parity");
        }
        let ha = local.hessian(&beta, scale);
        let hb = threaded.hessian(&beta, scale);
        for (x, y) in ha.iter().zip(&hb) {
            assert_all_close(&x.values, &y.values, 1e-12, "hessian parity");
        }
        assert_eq!(threaded.orgs(), 3);
        assert_eq!(threaded.n_total(), 900);
        assert_eq!(threaded.p(), 5);
        assert_eq!(threaded.dataset_name(), "t");
    }

    #[test]
    fn threaded_fleet_shutdown_clean() {
        let d = synthesize("t", 90, 3, 42);
        let fleet = ThreadedFleet::spawn(d.partition(5));
        drop(fleet); // must join without hanging
    }
}
