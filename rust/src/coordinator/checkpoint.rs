//! Round-boundary session checkpoints: the durability layer behind
//! `privlogit center --state-dir <dir>` / `--resume <dir>`.
//!
//! At every round boundary the center persists one
//! [`SessionCheckpoint`] — protocol, completed-iteration index, the
//! model iterate β (bit-exact), fixed-point format, session identity
//! (seed / modulus bits / epoch), live and excluded membership, and a
//! scalar ledger snapshot — as a single-line JSON document under the
//! state directory, schema [`CHECKPOINT_SCHEMA`]. Writes are atomic
//! (tmp file + rename, fsynced) so a crash mid-write can never corrupt
//! the latest durable state: a reader sees either the previous
//! checkpoint or the new one, never a torn file.
//!
//! β travels twice in each document: as `beta_bits` (the `f64` bit
//! patterns, lowercase hex — what resume actually loads, so the
//! restored iterate is *bit-identical* to the crashed process's) and as
//! `beta` (plain JSON numbers, for operators reading the file). The
//! approximate copy is never read back.
//!
//! File layout inside the state dir: `checkpoint-000007.json` for the
//! checkpoint at round 7. Round indices are zero-padded to six digits
//! so lexicographic order is numeric order and
//! [`load_latest`] can pick the newest without parsing every file.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::mpc::CostLedger;
use crate::obs::json::{self, JsonObj, JsonValue};

/// Schema tag every checkpoint document carries.
pub const CHECKPOINT_SCHEMA: &str = "privlogit-checkpoint/v1";

/// Everything a `--resume` needs to continue a PrivLogit-Local session
/// from the last completed round instead of round 0.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCheckpoint {
    /// Protocol name (resume is scoped to `privlogit-local`).
    pub protocol: String,
    /// Completed global iterations when this checkpoint was written
    /// (the resumed run continues at this iteration index).
    pub round: u64,
    /// The model iterate, restored bit-exactly.
    pub beta: Vec<f64>,
    /// Fixed-point word width (bits).
    pub w: u32,
    /// Fixed-point fractional bits.
    pub f: u32,
    /// The RNG seed the session was started with — the resumed center
    /// must regenerate the *same* Paillier keypair, so the session id
    /// (a hash of the modulus) stitches both incarnations into one
    /// logical session in the merged timeline.
    pub seed: u64,
    /// Paillier modulus bits the session was started with.
    pub modulus_bits: u64,
    /// Session epoch this incarnation ran at; a resume reconnects at
    /// `epoch + 1` so node replay guards accept the re-key.
    pub epoch: u64,
    /// Session id (hash of the Paillier modulus; 0 pre-key or modeled).
    pub session: u64,
    /// Dimensionality the fleet served.
    pub p: u64,
    /// Sample total over the live membership at checkpoint time.
    pub n_total: u64,
    /// Dataset name (shard agreement check on resume is the fleet's).
    pub dataset: String,
    /// Live node addresses at checkpoint time (empty for in-process
    /// fleets, which have no addresses).
    pub live: Vec<String>,
    /// Excluded node addresses at checkpoint time.
    pub excluded: Vec<String>,
    /// Scalar ledger snapshot (headline counters, for operators and
    /// tests; a resumed run's report accounts the new incarnation only
    /// and does *not* re-add these).
    pub ledger: Vec<(String, f64)>,
}

/// The headline scalar counters checkpointed from a [`CostLedger`].
pub fn ledger_snapshot(l: &CostLedger) -> Vec<(String, f64)> {
    [
        ("center_secs", l.center_secs),
        ("node_secs", l.node_secs),
        ("bytes", l.bytes as f64),
        ("bytes_recv", l.bytes_recv as f64),
        ("fleet_bytes_sent", l.fleet_bytes_sent as f64),
        ("fleet_bytes_recv", l.fleet_bytes_recv as f64),
        ("rounds", l.rounds as f64),
        ("paillier_encs", l.paillier_encs as f64),
        ("paillier_adds", l.paillier_adds as f64),
        ("paillier_scalar", l.paillier_scalar as f64),
        ("paillier_decrypts", l.paillier_decrypts as f64),
        ("gc_ands", l.gc_ands as f64),
        ("ot_bits", l.ot_bits as f64),
        ("excluded_nodes", l.excluded_nodes as f64),
        ("readmitted_nodes", l.readmitted_nodes as f64),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v))
    .collect()
}

impl SessionCheckpoint {
    /// Serialize to a single-line JSON document (see the module doc for
    /// the dual β encoding).
    pub fn to_json(&self) -> JsonValue {
        let beta_bits: Vec<JsonValue> = self
            .beta
            .iter()
            .map(|b| JsonValue::Str(format!("{:016x}", b.to_bits())))
            .collect();
        let beta_approx: Vec<JsonValue> =
            self.beta.iter().map(|b| JsonValue::Num(*b)).collect();
        let mut ledger = JsonObj::new();
        for (k, v) in &self.ledger {
            ledger = ledger.f64(k, *v);
        }
        JsonObj::new()
            .str("schema", CHECKPOINT_SCHEMA)
            .str("protocol", &self.protocol)
            .u64("round", self.round)
            .u64("session", self.session)
            .u64("epoch", self.epoch)
            .u64("seed", self.seed)
            .u64("modulus_bits", self.modulus_bits)
            .u64("w", self.w as u64)
            .u64("f", self.f as u64)
            .u64("p", self.p)
            .u64("n_total", self.n_total)
            .str("dataset", &self.dataset)
            .push("beta_bits", JsonValue::Arr(beta_bits))
            .push("beta", JsonValue::Arr(beta_approx))
            .push(
                "live",
                JsonValue::Arr(
                    self.live.iter().map(|a| JsonValue::Str(a.clone())).collect(),
                ),
            )
            .push(
                "excluded",
                JsonValue::Arr(
                    self.excluded.iter().map(|a| JsonValue::Str(a.clone())).collect(),
                ),
            )
            .push("ledger", ledger.build())
            .build()
    }

    /// Parse a checkpoint document, validating the schema tag. β is
    /// restored from `beta_bits` (bit-exact); the approximate `beta`
    /// member is ignored.
    pub fn from_json(doc: &JsonValue) -> anyhow::Result<SessionCheckpoint> {
        let schema = doc.get("schema").and_then(JsonValue::as_str).unwrap_or("");
        anyhow::ensure!(
            schema == CHECKPOINT_SCHEMA,
            "not a checkpoint document: schema {schema:?}, expected {CHECKPOINT_SCHEMA:?}"
        );
        let str_field = |key: &str| -> anyhow::Result<String> {
            Ok(doc
                .get(key)
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow::anyhow!("checkpoint is missing {key:?}"))?
                .to_string())
        };
        let u64_field = |key: &str| -> anyhow::Result<u64> {
            doc.get(key)
                .and_then(JsonValue::as_u64)
                .ok_or_else(|| anyhow::anyhow!("checkpoint is missing {key:?}"))
        };
        let bits = doc
            .get("beta_bits")
            .and_then(JsonValue::as_arr)
            .ok_or_else(|| anyhow::anyhow!("checkpoint is missing \"beta_bits\""))?;
        let mut beta = Vec::with_capacity(bits.len());
        for (i, b) in bits.iter().enumerate() {
            let hex = b
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("beta_bits[{i}] is not a string"))?;
            let raw = u64::from_str_radix(hex, 16)
                .map_err(|_| anyhow::anyhow!("beta_bits[{i}] = {hex:?} is not f64 bits"))?;
            beta.push(f64::from_bits(raw));
        }
        let addrs = |key: &str| -> Vec<String> {
            doc.get(key)
                .and_then(JsonValue::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect())
                .unwrap_or_default()
        };
        let ledger = match doc.get("ledger") {
            Some(JsonValue::Obj(pairs)) => pairs
                .iter()
                .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n)))
                .collect(),
            _ => Vec::new(),
        };
        Ok(SessionCheckpoint {
            protocol: str_field("protocol")?,
            round: u64_field("round")?,
            beta,
            w: u64_field("w")? as u32,
            f: u64_field("f")? as u32,
            seed: u64_field("seed")?,
            modulus_bits: u64_field("modulus_bits")?,
            epoch: u64_field("epoch")?,
            session: u64_field("session")?,
            p: u64_field("p")?,
            n_total: u64_field("n_total")?,
            dataset: str_field("dataset")?,
            live: addrs("live"),
            excluded: addrs("excluded"),
            ledger,
        })
    }
}

/// The file name for a given round's checkpoint.
fn file_name(round: u64) -> String {
    format!("checkpoint-{round:06}.json")
}

/// Persist one checkpoint atomically under `dir` (created if missing):
/// the document is written to a dot-prefixed tmp file, fsynced, then
/// renamed over the final name — a crash at any point leaves either no
/// file or a complete one. Returns the final path.
pub fn save(dir: &Path, cp: &SessionCheckpoint) -> anyhow::Result<PathBuf> {
    fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("creating state dir {}: {e}", dir.display()))?;
    let final_path = dir.join(file_name(cp.round));
    let tmp_path = dir.join(format!(".{}.tmp", file_name(cp.round)));
    let mut text = cp.to_json().render();
    text.push('\n');
    let write = || -> std::io::Result<()> {
        let mut f = fs::File::create(&tmp_path)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
        fs::rename(&tmp_path, &final_path)
    };
    write().map_err(|e| {
        anyhow::anyhow!("writing checkpoint {}: {e}", final_path.display())
    })?;
    Ok(final_path)
}

/// Load the newest checkpoint under `dir` (highest round index), or
/// `None` when the directory holds no checkpoints (or does not exist —
/// a fresh `--state-dir` is not an error, an unreadable newest
/// checkpoint is).
pub fn load_latest(dir: &Path) -> anyhow::Result<Option<SessionCheckpoint>> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => anyhow::bail!("reading state dir {}: {e}", dir.display()),
    };
    let mut newest: Option<String> = None;
    for entry in entries {
        let name = entry
            .map_err(|e| anyhow::anyhow!("reading state dir {}: {e}", dir.display()))?
            .file_name()
            .to_string_lossy()
            .into_owned();
        if name.starts_with("checkpoint-") && name.ends_with(".json") {
            // Zero-padded round ⇒ lexicographic max is the newest.
            if newest.as_deref().map_or(true, |n| name.as_str() > n) {
                newest = Some(name);
            }
        }
    }
    let Some(name) = newest else { return Ok(None) };
    let path = dir.join(&name);
    let text = fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
    let doc = json::parse(text.trim())
        .map_err(|e| anyhow::anyhow!("parsing checkpoint {}: {e}", path.display()))?;
    let cp = SessionCheckpoint::from_json(&doc)
        .map_err(|e| anyhow::anyhow!("checkpoint {}: {e}", path.display()))?;
    Ok(Some(cp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(round: u64) -> SessionCheckpoint {
        SessionCheckpoint {
            protocol: "privlogit-local".into(),
            round,
            // Values chosen so any decimal round-trip would drift:
            // 0.1+0.2, a subnormal, a negative zero and an exact power.
            beta: vec![0.1 + 0.2, f64::MIN_POSITIVE / 8.0, -0.0, -1048576.0],
            w: 40,
            f: 24,
            seed: 42,
            modulus_bits: 256,
            epoch: 1,
            session: 0xDEAD_BEEF,
            p: 4,
            n_total: 1200,
            dataset: "synth:n=1200,p=4,seed=7".into(),
            live: vec!["127.0.0.1:9001".into(), "127.0.0.1:9002".into()],
            excluded: vec!["127.0.0.1:9003".into()],
            ledger: vec![("rounds".into(), 9.0), ("paillier_encs".into(), 120.0)],
        }
    }

    /// β must survive the JSON round-trip bit-exactly, including the
    /// sign of negative zero and subnormals.
    #[test]
    fn round_trips_bit_exactly() {
        let cp = sample(7);
        let back = SessionCheckpoint::from_json(&cp.to_json()).unwrap();
        assert_eq!(back, cp);
        for (a, b) in cp.beta.iter().zip(&back.beta) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact β");
        }
        assert!(back.beta[2].is_sign_negative(), "-0.0 keeps its sign");
    }

    #[test]
    fn save_and_load_latest_picks_highest_round() {
        let dir = std::env::temp_dir().join(format!("plgt-ckpt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        assert!(load_latest(&dir).unwrap().is_none(), "missing dir is no checkpoint");
        for round in [0, 3, 12] {
            let path = save(&dir, &sample(round)).unwrap();
            assert!(path.ends_with(file_name(round)));
        }
        let latest = load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.round, 12);
        assert_eq!(latest.live.len(), 2);
        assert_eq!(latest.excluded, vec!["127.0.0.1:9003".to_string()]);
        // No tmp files survive the atomic rename.
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().ends_with(".tmp")
            })
            .collect();
        assert!(leftovers.is_empty(), "tmp files left behind: {leftovers:?}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_foreign_and_malformed_documents() {
        let doc = json::parse(r#"{"schema":"privlogit-trace/v1"}"#).unwrap();
        let err = SessionCheckpoint::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("privlogit-checkpoint/v1"), "{err}");
        let mut cp = sample(1).to_json();
        if let JsonValue::Obj(pairs) = &mut cp {
            pairs.retain(|(k, _)| k != "beta_bits");
        }
        let err = SessionCheckpoint::from_json(&cp).unwrap_err().to_string();
        assert!(err.contains("beta_bits"), "{err}");
    }

    /// An unreadable newest checkpoint must surface as an error, not be
    /// silently skipped in favor of an older (stale) one.
    #[test]
    fn corrupt_latest_is_an_error() {
        let dir =
            std::env::temp_dir().join(format!("plgt-ckpt-bad-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        save(&dir, &sample(2)).unwrap();
        fs::write(dir.join(file_name(5)), b"{torn").unwrap();
        let err = load_latest(&dir).unwrap_err().to_string();
        assert!(err.contains(&file_name(5)), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }
}
