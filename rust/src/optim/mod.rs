//! Plaintext logistic-regression model and the two numerical optimizers
//! of the paper: the Newton method (§2.2) and PrivLogit (§3, the
//! Böhning–Lindsay constant-Hessian bound).
//!
//! These are the ground truth for the secure protocols: the secure
//! iterates must match these to fixed-point precision (Fig. 2, R² = 1.00),
//! and the iteration counts here are by construction the iteration counts
//! of the secure runs (the secure arithmetic computes the same updates).

use crate::data::Dataset;
use crate::linalg::Matrix;

/// Numerically-stable logistic sigmoid.
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable `log(1 + e^z)`.
pub fn log1p_exp(z: f64) -> f64 {
    if z > 0.0 {
        z + (-z).exp().ln_1p()
    } else {
        z.exp().ln_1p()
    }
}

/// Per-organization local statistics at a given β (what nodes compute
/// plaintext-side each iteration; Equations 4 and 9).
#[derive(Clone, Debug)]
pub struct LocalStats {
    /// `g_j = X_jᵀ(y_j − p_j)` (no regularization term).
    pub grad: Vec<f64>,
    /// `l_sj = Σ_i [y_i·xᵀβ − log(1+e^{xᵀβ})]`.
    pub loglik: f64,
}

/// Compute a node's local gradient and log-likelihood share.
pub fn local_stats(data: &Dataset, beta: &[f64]) -> LocalStats {
    let (n, p) = (data.n(), data.p());
    assert_eq!(beta.len(), p);
    let mut grad = vec![0.0; p];
    let mut loglik = 0.0;
    for i in 0..n {
        let row = data.x.row(i);
        let z: f64 = row.iter().zip(beta).map(|(a, b)| a * b).sum();
        let pi = sigmoid(z);
        let resid = data.y[i] - pi;
        for j in 0..p {
            grad[j] += row[j] * resid;
        }
        loglik += data.y[i] * z - log1p_exp(z);
    }
    LocalStats { grad, loglik }
}

/// A node's exact Hessian contribution `X_jᵀ A X_j` (Newton baseline;
/// Equation 5, sign-flipped to the positive-definite convention).
pub fn local_hessian(data: &Dataset, beta: &[f64]) -> Matrix {
    let (n, p) = (data.n(), data.p());
    let mut h = Matrix::zeros(p, p);
    for i in 0..n {
        let row = data.x.row(i);
        let z: f64 = row.iter().zip(beta).map(|(a, b)| a * b).sum();
        let pi = sigmoid(z);
        let a = pi * (1.0 - pi);
        for j in 0..p {
            let aj = a * row[j];
            if aj == 0.0 {
                continue;
            }
            for k in j..p {
                h[(j, k)] += aj * row[k];
            }
        }
    }
    for j in 0..p {
        for k in 0..j {
            h[(j, k)] = h[(k, j)];
        }
    }
    h
}

/// A node's constant PrivLogit Hessian contribution `¼ X_jᵀX_j`
/// (Equation 6, positive-definite convention).
pub fn local_gram_quarter(data: &Dataset) -> Matrix {
    let mut g = data.x.gram();
    g.scale(0.25);
    g
}

/// Which optimizer to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Exact-Hessian Newton (the paper's baseline).
    Newton,
    /// Constant-Hessian PrivLogit (Böhning–Lindsay bound).
    PrivLogit,
}

/// Optimizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct OptimConfig {
    /// `ℓ₂` regularization λ (0 disables).
    pub lambda: f64,
    /// Relative log-likelihood convergence threshold (paper: 1e-6).
    pub tol: f64,
    /// Iteration cap (defensive; the paper's runs converge well below).
    pub max_iters: usize,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig { lambda: 1.0, tol: 1e-6, max_iters: 500 }
    }
}

/// Fit result.
#[derive(Clone, Debug)]
pub struct Fit {
    /// Final coefficients.
    pub beta: Vec<f64>,
    /// Iterations to convergence.
    pub iterations: usize,
    /// Log-likelihood trajectory (ℓ₂-penalized), one entry per iteration.
    pub loglik_trace: Vec<f64>,
    /// Whether the tolerance was met (vs. hitting `max_iters`).
    pub converged: bool,
}

/// Penalized log-likelihood over partitioned data (Equation 2 / 9).
pub fn total_loglik(parts: &[Dataset], beta: &[f64], lambda: f64) -> f64 {
    let l: f64 = parts.iter().map(|d| local_stats(d, beta).loglik).sum();
    let b2: f64 = beta.iter().map(|b| b * b).sum();
    l - 0.5 * lambda * b2
}

/// Distributed plaintext model fit — the exact computation sequence of
/// the secure protocols, minus the cryptography.
///
/// `Method::Newton` re-evaluates and re-solves the exact Hessian every
/// iteration; `Method::PrivLogit` factors `H̃ = ¼XᵀX + λI` once and
/// reuses it (Equation 8).
pub fn fit(parts: &[Dataset], method: Method, cfg: OptimConfig) -> Fit {
    let p = parts[0].p();
    let mut beta = vec![0.0; p];
    let mut loglik_trace = vec![total_loglik(parts, &beta, cfg.lambda)];
    // PrivLogit: one-time surrogate Hessian factorization.
    let l_privlogit = match method {
        Method::PrivLogit => {
            let mut h = Matrix::zeros(p, p);
            for d in parts {
                h = h.add(&local_gram_quarter(d));
            }
            h.add_diag(cfg.lambda);
            Some(h.cholesky().expect("¼XᵀX + λI is SPD"))
        }
        Method::Newton => None,
    };
    for iter in 1..=cfg.max_iters {
        // gradient with regularization (Equation 4)
        let mut grad = vec![0.0; p];
        for d in parts {
            let s = local_stats(d, &beta);
            for j in 0..p {
                grad[j] += s.grad[j];
            }
        }
        for j in 0..p {
            grad[j] -= cfg.lambda * beta[j];
        }
        // step
        let delta = match method {
            Method::Newton => {
                let mut h = Matrix::zeros(p, p);
                for d in parts {
                    h = h.add(&local_hessian(d, &beta));
                }
                h.add_diag(cfg.lambda);
                h.solve_spd(&grad).expect("Newton Hessian SPD")
            }
            Method::PrivLogit => l_privlogit.as_ref().unwrap().solve_cholesky(&grad),
        };
        // β ← β + H⁻¹g  (concave maximization; H in PD convention)
        for j in 0..p {
            beta[j] += delta[j];
        }
        let l_new = total_loglik(parts, &beta, cfg.lambda);
        let l_old = *loglik_trace.last().unwrap();
        loglik_trace.push(l_new);
        if (l_new - l_old).abs() < cfg.tol * l_old.abs() {
            return Fit { beta, iterations: iter, loglik_trace, converged: true };
        }
        let _ = iter;
    }
    Fit { beta, iterations: cfg.max_iters, loglik_trace, converged: false }
}

/// Convenience: fit an unpartitioned dataset.
pub fn fit_single(data: &Dataset, method: Method, cfg: OptimConfig) -> Fit {
    fit(std::slice::from_ref(data), method, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthesize;
    use crate::linalg::r_squared;
    use crate::testutil::assert_close;

    #[test]
    fn sigmoid_stable() {
        assert_close(sigmoid(0.0), 0.5, 1e-12, "sigmoid(0)");
        assert!(sigmoid(800.0) <= 1.0 && sigmoid(800.0) > 0.999);
        assert!(sigmoid(-800.0) >= 0.0 && sigmoid(-800.0) < 1e-3);
        assert_close(log1p_exp(0.0), std::f64::consts::LN_2, 1e-12, "log1p_exp(0)");
        assert!(log1p_exp(1000.0).is_finite());
    }

    #[test]
    fn newton_converges_fast() {
        let d = synthesize("t", 4000, 8, 11);
        let fit = fit_single(&d, Method::Newton, OptimConfig::default());
        assert!(fit.converged);
        assert!(fit.iterations <= 10, "Newton should take single digits, got {}", fit.iterations);
        // monotone non-decreasing log-likelihood
        for w in fit.loglik_trace.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "loglik must not decrease: {w:?}");
        }
    }

    #[test]
    fn privlogit_matches_newton_fixed_point() {
        let d = synthesize("t", 4000, 8, 12);
        let newton = fit_single(&d, Method::Newton, OptimConfig::default());
        // tighter tolerance so both land on the same optimum
        let cfg = OptimConfig { tol: 1e-10, ..Default::default() };
        let newton_tight = fit_single(&d, Method::Newton, cfg);
        let privlogit = fit_single(&d, Method::PrivLogit, cfg);
        assert!(privlogit.converged);
        // PrivLogit converges linearly: at the loglik tolerance the
        // coefficients agree to ~1e-4 relative — the paper's "perfect
        // correlation at displayed precision".
        for (a, b) in newton_tight.beta.iter().zip(&privlogit.beta) {
            assert_close(*a, *b, 1e-3, "same optimum");
        }
        // paper's headline accuracy metric
        let r2 = r_squared(&newton.beta, &privlogit.beta);
        assert!(r2 > 0.99999, "R² = {r2}");
    }

    /// The paper's iteration-count shape (Fig. 3): PrivLogit takes
    /// noticeably more iterations than Newton, and the gap grows with p.
    #[test]
    fn privlogit_iteration_inflation() {
        let cfg = OptimConfig::default();
        let small = synthesize("s", 3000, 5, 13);
        let big = synthesize("b", 3000, 40, 14);
        let n_s = fit_single(&small, Method::Newton, cfg).iterations;
        let p_s = fit_single(&small, Method::PrivLogit, cfg).iterations;
        let n_b = fit_single(&big, Method::Newton, cfg).iterations;
        let p_b = fit_single(&big, Method::PrivLogit, cfg).iterations;
        assert!(p_s > n_s, "PrivLogit {p_s} > Newton {n_s} at p=5");
        assert!(p_b > n_b, "PrivLogit {p_b} > Newton {n_b} at p=40");
        assert!(
            p_b as f64 / n_b as f64 > p_s as f64 / n_s as f64 * 0.8,
            "inflation should not shrink with p ({p_s}/{n_s} vs {p_b}/{n_b})"
        );
    }

    /// Partitioned fit must be identical to the pooled fit (the whole
    /// point of distributed estimation).
    #[test]
    fn partitioned_equals_pooled() {
        let d = synthesize("t", 3000, 6, 15);
        let cfg = OptimConfig::default();
        let pooled = fit_single(&d, Method::PrivLogit, cfg);
        let parts = d.partition(7);
        let dist = fit(&parts, Method::PrivLogit, cfg);
        assert_eq!(pooled.iterations, dist.iterations);
        for (a, b) in pooled.beta.iter().zip(&dist.beta) {
            assert_close(*a, *b, 1e-9, "pooled == partitioned");
        }
    }

    #[test]
    fn local_hessian_psd_and_symmetric() {
        let d = synthesize("t", 500, 6, 16);
        let h = local_hessian(&d, &vec![0.1; 6]);
        for i in 0..6 {
            for j in 0..6 {
                assert_close(h[(i, j)], h[(j, i)], 1e-12, "symmetric");
            }
        }
        assert!(h.cholesky().is_some(), "PSD (PD for generic data)");
        // Böhning–Lindsay: ¼XᵀX − XᵀAX is PSD (the bound is valid)
        let bound = local_gram_quarter(&d);
        let mut diff = bound.add(&{
            let mut hneg = h.clone();
            hneg.scale(-1.0);
            hneg
        });
        // PSD check via Cholesky with tiny jitter
        diff.add_diag(1e-9);
        assert!(diff.cholesky().is_some(), "¼XᵀX ⪰ XᵀAX");
    }

    #[test]
    fn unregularized_fit_works() {
        let d = synthesize("t", 3000, 4, 17);
        let cfg = OptimConfig { lambda: 0.0, ..Default::default() };
        let f = fit_single(&d, Method::Newton, cfg);
        assert!(f.converged);
        // recovers the generating coefficients decently (standardized scale)
        let bt = d.beta_true.clone().unwrap();
        let r2 = r_squared(&f.beta, &bt);
        assert!(r2 > 0.8, "R² vs generating β = {r2}");
    }
}
