//! Configuration system: defaults < config file < CLI flags.
//!
//! The file format is simple `key = value` lines (`#` comments), parsed
//! without external crates. The same keys are accepted as `--key value`
//! CLI flags (dashes and underscores interchangeable).

/// All experiment settings (see `privlogit --help` for semantics).
#[derive(Clone, Debug)]
pub struct Config {
    /// Dataset name from the paper suite (e.g. "Loans", "SimuX100").
    pub dataset: String,
    /// Protocol: newton | privlogit-hessian | privlogit-local.
    pub protocol: String,
    /// Backend: real | model | auto.
    pub backend: String,
    /// Number of organizations (paper: 4–20).
    pub orgs: usize,
    /// ℓ₂ regularization λ.
    pub lambda: f64,
    /// Relative convergence tolerance.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Paillier modulus bits (real backend). Paper: 2048.
    pub modulus_bits: usize,
    /// Spawn one worker thread per organization.
    pub threaded: bool,
    /// Run the two Center servers' GC link over real TCP loopback
    /// sockets (real backend only).
    pub center_tcp: bool,
    /// `privlogit node` / `center-b`: address to listen on.
    pub listen: String,
    /// `privlogit node`: which partition (0-based) of the dataset this
    /// node serves, out of `orgs` shards.
    pub org: usize,
    /// `privlogit center` / `center-a`: comma-separated node server
    /// addresses.
    pub nodes: String,
    /// `privlogit center-a`: address of the `center-b` evaluator process.
    pub peer: String,
    /// `privlogit center-b`: serve exactly one center-a session, then
    /// exit (default: serve forever).
    pub once: bool,
    /// Emit the run report as JSON (schema `privlogit-report/v1`)
    /// instead of the human-readable table.
    pub json: bool,
    /// RNG seed.
    pub seed: u64,
    /// `privlogit center`: per-round fleet socket deadline in seconds.
    /// Unset means "use `PRIVLOGIT_ROUND_TIMEOUT` or the 120 s default";
    /// a non-positive value disables deadlines entirely.
    pub round_timeout: Option<f64>,
    /// `privlogit center`: minimum node replies for a fleet round to
    /// proceed (failed nodes are excluded for the session). `0` = every
    /// live node must reply (strict all-or-abort).
    pub quorum: usize,
    /// `privlogit center`: per-address connect retry budget in seconds.
    /// Also bounds the center-a → center-b peer connect (one knob for
    /// both link kinds).
    pub connect_timeout: f64,
    /// `privlogit center`: directory to persist round-boundary session
    /// checkpoints under (empty = no checkpointing). See
    /// docs/DEPLOY.md §Crash recovery.
    pub state_dir: String,
    /// `privlogit center`: resume from the latest checkpoint in this
    /// directory instead of starting at round 0 (implies checkpointing
    /// into the same directory unless `--state-dir` overrides it).
    pub resume: String,
    /// Disable ciphertext slot-packing of the statistic fan-in and run
    /// the legacy one-value-per-ciphertext wire (the parity reference
    /// path; see docs/ARCHITECTURE.md §Packing).
    pub no_pack: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            dataset: "Wine".into(),
            protocol: "privlogit-local".into(),
            backend: "auto".into(),
            orgs: 4,
            lambda: 1.0,
            tol: 1e-6,
            max_iters: 500,
            modulus_bits: 1024,
            threaded: false,
            center_tcp: false,
            listen: "127.0.0.1:9401".into(),
            org: 0,
            nodes: String::new(),
            peer: String::new(),
            once: false,
            json: false,
            seed: 42,
            round_timeout: None,
            quorum: 0,
            connect_timeout: 10.0,
            state_dir: String::new(),
            resume: String::new(),
            no_pack: false,
        }
    }
}

/// Parse `value` for config key `key`, naming the offending flag in the
/// error — `--quorum banana` must say which knob was wrong, not just
/// "invalid digit found in string".
fn parse_keyed<T: std::str::FromStr>(key: &str, value: &str) -> anyhow::Result<T>
where
    T::Err: std::fmt::Display,
{
    value.parse().map_err(|e| {
        anyhow::anyhow!("invalid value {value:?} for --{}: {e}", key.replace('_', "-"))
    })
}

impl Config {
    /// Apply one key/value pair; unknown keys are errors.
    pub fn set(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        let key = key.replace('-', "_");
        match key.as_str() {
            "dataset" => self.dataset = value.to_string(),
            "protocol" => self.protocol = value.to_string(),
            "backend" => self.backend = value.to_string(),
            "orgs" => self.orgs = parse_keyed(&key, value)?,
            "lambda" => self.lambda = parse_keyed(&key, value)?,
            "tol" => self.tol = parse_keyed(&key, value)?,
            "max_iters" => self.max_iters = parse_keyed(&key, value)?,
            "modulus_bits" | "modulus" => self.modulus_bits = parse_keyed(&key, value)?,
            "threaded" => self.threaded = parse_keyed(&key, value)?,
            "center_tcp" => self.center_tcp = parse_keyed(&key, value)?,
            "listen" => self.listen = value.to_string(),
            "org" => self.org = parse_keyed(&key, value)?,
            "nodes" => self.nodes = value.to_string(),
            "peer" => self.peer = value.to_string(),
            "once" => self.once = parse_keyed(&key, value)?,
            "json" => self.json = parse_keyed(&key, value)?,
            "seed" => self.seed = parse_keyed(&key, value)?,
            "round_timeout" => self.round_timeout = Some(parse_keyed(&key, value)?),
            "quorum" => self.quorum = parse_keyed(&key, value)?,
            "connect_timeout" => self.connect_timeout = parse_keyed(&key, value)?,
            "state_dir" => self.state_dir = value.to_string(),
            "resume" => self.resume = value.to_string(),
            "no_pack" => self.no_pack = parse_keyed(&key, value)?,
            other => anyhow::bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Parse a `key = value` config file into `self`.
    pub fn load_file(&mut self, path: &str) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("{path}:{}: expected key = value", lineno + 1))?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Boolean keys that may appear as bare `--flag` (no value) on the
    /// command line.
    const BOOL_FLAGS: [&'static str; 5] = ["threaded", "center_tcp", "once", "json", "no_pack"];

    /// Parse CLI arguments (`--key value` pairs, plus `--config FILE`;
    /// boolean flags may omit the value).
    pub fn parse_args(&mut self, args: &[String]) -> anyhow::Result<()> {
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            let key = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got {arg:?}"))?;
            let norm = key.replace('-', "_");
            if Self::BOOL_FLAGS.contains(&norm.as_str())
                && (i + 1 >= args.len() || args[i + 1].starts_with("--"))
            {
                self.set(&norm, "true")?;
                i += 1;
                continue;
            }
            let value = args
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("missing value for --{key}"))?;
            if key == "config" {
                self.load_file(value)?;
            } else {
                self.set(key, value)?;
            }
            i += 2;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let mut c = Config::default();
        assert_eq!(c.orgs, 4);
        c.set("orgs", "12").unwrap();
        c.set("max-iters", "50").unwrap();
        c.set("lambda", "0.5").unwrap();
        assert_eq!(c.orgs, 12);
        assert_eq!(c.max_iters, 50);
        assert_eq!(c.lambda, 0.5);
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn cli_parsing() {
        let mut c = Config::default();
        let args: Vec<String> = ["--dataset", "Loans", "--orgs", "8", "--threaded", "--tol", "1e-7"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        c.parse_args(&args).unwrap();
        assert_eq!(c.dataset, "Loans");
        assert_eq!(c.orgs, 8);
        assert!(c.threaded);
        assert_eq!(c.tol, 1e-7);
        assert!(c.parse_args(&["--orgs".to_string()]).is_err());
        assert!(c.parse_args(&["orgs".to_string(), "3".to_string()]).is_err());
    }

    #[test]
    fn net_keys_and_bare_bool_flags() {
        let mut c = Config::default();
        let args: Vec<String> =
            ["--center-tcp", "--nodes", "127.0.0.1:9401,127.0.0.1:9402", "--org", "2",
             "--listen", "0.0.0.0:9500"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        c.parse_args(&args).unwrap();
        assert!(c.center_tcp);
        assert_eq!(c.nodes, "127.0.0.1:9401,127.0.0.1:9402");
        assert_eq!(c.org, 2);
        assert_eq!(c.listen, "0.0.0.0:9500");
        // explicit value form still works
        let mut c = Config::default();
        c.set("center_tcp", "true").unwrap();
        assert!(c.center_tcp);
    }

    #[test]
    fn center_split_keys() {
        let mut c = Config::default();
        let args: Vec<String> = ["--peer", "127.0.0.1:9700", "--once", "--json"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        c.parse_args(&args).unwrap();
        assert_eq!(c.peer, "127.0.0.1:9700");
        assert!(c.once);
        assert!(c.json);
        assert!(!Config::default().once);
        assert!(!Config::default().json);
        assert!(Config::default().peer.is_empty());
    }

    #[test]
    fn fault_tolerance_keys() {
        let mut c = Config::default();
        assert_eq!(c.round_timeout, None);
        assert_eq!(c.quorum, 0);
        assert_eq!(c.connect_timeout, 10.0);
        let args: Vec<String> =
            ["--round-timeout", "2.5", "--quorum", "13", "--connect-timeout", "4"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        c.parse_args(&args).unwrap();
        assert_eq!(c.round_timeout, Some(2.5));
        assert_eq!(c.quorum, 13);
        assert_eq!(c.connect_timeout, 4.0);
        // A non-positive round_timeout is accepted (it disables deadlines).
        c.set("round_timeout", "0").unwrap();
        assert_eq!(c.round_timeout, Some(0.0));
    }

    #[test]
    fn invalid_values_name_the_offending_key() {
        let mut c = Config::default();
        let err = c.set("round-timeout", "soon").unwrap_err().to_string();
        assert!(err.contains("--round-timeout"), "error should name the flag: {err}");
        assert!(err.contains("soon"), "error should quote the value: {err}");
        let err = c.set("quorum", "-3").unwrap_err().to_string();
        assert!(err.contains("--quorum"), "error should name the flag: {err}");
        let err = c.set("connect_timeout", "10s").unwrap_err().to_string();
        assert!(err.contains("--connect-timeout"), "error should name the flag: {err}");
        let err = c.set("max_iters", "many").unwrap_err().to_string();
        assert!(err.contains("--max-iters"), "error should name the flag: {err}");
        // None of the failed sets may have clobbered the config.
        assert_eq!(c.round_timeout, None);
        assert_eq!(c.quorum, 0);
        assert_eq!(c.connect_timeout, 10.0);
    }

    #[test]
    fn durability_keys() {
        let mut c = Config::default();
        assert!(c.state_dir.is_empty());
        assert!(c.resume.is_empty());
        let args: Vec<String> = ["--state-dir", "/tmp/plgt-state", "--resume", "/tmp/plgt-state"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        c.parse_args(&args).unwrap();
        assert_eq!(c.state_dir, "/tmp/plgt-state");
        assert_eq!(c.resume, "/tmp/plgt-state");
    }

    #[test]
    fn no_pack_flag() {
        let mut c = Config::default();
        assert!(!c.no_pack, "packing is on by default");
        c.parse_args(&["--no-pack".to_string()]).unwrap();
        assert!(c.no_pack);
        let mut c = Config::default();
        c.set("no_pack", "true").unwrap();
        assert!(c.no_pack);
    }

    #[test]
    fn file_parsing() {
        let dir = std::env::temp_dir().join("privlogit_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.conf");
        std::fs::write(&path, "# experiment\ndataset = News\nprotocol = newton\nseed = 7\n")
            .unwrap();
        let mut c = Config::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.dataset, "News");
        assert_eq!(c.protocol, "newton");
        assert_eq!(c.seed, 7);
    }
}
