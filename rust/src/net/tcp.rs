//! TCP transport: the wire format over real sockets.
//!
//! A [`TcpTransport`] carries framed messages (see [`super::wire`]) over
//! one `TcpStream`, after a magic/version handshake in both directions.
//! Wrapped in a [`Channel`](crate::gc::channel::Channel) it is a drop-in
//! replacement for the in-memory `mpsc` pair: same duplex byte interface,
//! same write-combining and flush semantics, same byte/message counters —
//! which is what lets `RealFabric`'s two Center servers and the node
//! fleet run across process and machine boundaries.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::wire;
use super::Transport;
use crate::gc::channel::Channel;

/// Read/write timeout applied for the duration of the hello exchange:
/// a peer that accepts the connection but never completes the
/// handshake must not hang the connecting side.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// The fleet round deadline configured by `PRIVLOGIT_ROUND_TIMEOUT`
/// (seconds, `f64`): `None` when the variable is unset, unparsable, or
/// non-positive (non-positive explicitly disables deadlines). Config
/// files take precedence over this variable where both are given; the
/// peer (GC) link honors only the environment, because its legitimate
/// silent gaps while garbling make a default deadline unsafe.
pub fn env_deadline() -> Option<Duration> {
    let raw = std::env::var("PRIVLOGIT_ROUND_TIMEOUT").ok()?;
    let secs: f64 = raw.trim().parse().ok()?;
    if secs > 0.0 && secs.is_finite() {
        Some(Duration::from_secs_f64(secs))
    } else {
        None
    }
}

/// One end of a framed TCP connection (handshake already verified).
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Peer's handshake role byte.
    pub peer_role: u8,
    /// Peer's handshake session epoch (0 for a fresh session; a
    /// resuming center announces the advanced epoch here).
    pub peer_epoch: u64,
}

impl TcpTransport {
    /// Complete the handshake on a connected stream: send our hello,
    /// validate the peer's. Both sides write first, so there is no
    /// ordering deadlock. The handshake itself runs under a bounded
    /// read timeout so an accepted-but-silent peer cannot hang us; the
    /// timeout is cleared afterwards (callers opt back in with
    /// [`TcpTransport::set_deadline`]).
    fn handshake(stream: TcpStream, role: u8, epoch: u64) -> io::Result<TcpTransport> {
        TcpTransport::handshake_within(stream, role, epoch, HANDSHAKE_TIMEOUT)
    }

    /// [`handshake`](TcpTransport::handshake) with an explicit bound on
    /// the hello exchange (probes pass their own small budget).
    fn handshake_within(
        stream: TcpStream,
        role: u8,
        epoch: u64,
        within: Duration,
    ) -> io::Result<TcpTransport> {
        let within = within.max(Duration::from_millis(1)); // zero would disable the timeout
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(within))?;
        stream.set_write_timeout(Some(within))?;
        let mut writer = BufWriter::new(stream.try_clone()?);
        let mut reader = BufReader::new(stream);
        writer.write_all(&wire::hello(role, epoch))?;
        writer.flush()?;
        let mut peer = [0u8; wire::HELLO_LEN];
        reader.read_exact(&mut peer)?;
        let (peer_role, peer_epoch) = wire::check_hello(&peer)?;
        let mut t = TcpTransport { reader, writer, peer_role, peer_epoch };
        t.set_deadline(None)?;
        Ok(t)
    }

    /// Connect to `addr` and handshake, announcing `role` at session
    /// epoch 0 (fresh session).
    pub fn connect<A: ToSocketAddrs>(addr: A, role: u8) -> io::Result<TcpTransport> {
        TcpTransport::handshake(TcpStream::connect(addr)?, role, 0)
    }

    /// Like [`TcpTransport::connect`], but announcing a specific session
    /// epoch — how a resuming center tells the accepting side this
    /// connection belongs to a re-keyed incarnation of the session.
    pub fn connect_at_epoch<A: ToSocketAddrs>(
        addr: A,
        role: u8,
        epoch: u64,
    ) -> io::Result<TcpTransport> {
        TcpTransport::handshake(TcpStream::connect(addr)?, role, epoch)
    }

    /// Set (or clear, with `None`) the per-operation socket deadline:
    /// any single read or write that makes no progress for this long
    /// fails with [`io::ErrorKind::TimedOut`] / `WouldBlock` instead of
    /// blocking forever. This is what turns a hung peer into a
    /// classifiable round failure for the quorum layer.
    pub fn set_deadline(&mut self, deadline: Option<Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(deadline)?;
        self.writer.get_ref().set_write_timeout(deadline)?;
        Ok(())
    }

    /// Connect with retries until `deadline_in` elapses — servers started
    /// "at the same time" (scripts, tests, compose files) may not be
    /// listening yet. Waits between attempts grow exponentially (25 ms
    /// doubling to a 800 ms cap) so a long deadline does not hammer an
    /// unreachable address. Permanent failures (handshake rejection:
    /// wrong magic or version skew) fail fast instead of burning the
    /// deadline.
    pub fn connect_retry(addr: &str, role: u8, deadline_in: Duration) -> io::Result<TcpTransport> {
        TcpTransport::connect_retry_at_epoch(addr, role, deadline_in, 0)
    }

    /// Connect and handshake with both the TCP connect *and* the hello
    /// exchange bounded by `within` — so a short-budget caller (a
    /// readmission probe, a retry loop's remaining deadline) cannot be
    /// stalled for the full [`HANDSHAKE_TIMEOUT`] by a peer whose kernel
    /// accepts the connection but whose process never answers.
    fn connect_within(
        addr: &str,
        role: u8,
        epoch: u64,
        within: Duration,
    ) -> io::Result<TcpTransport> {
        let within = within.max(Duration::from_millis(1));
        let sock = addr.to_socket_addrs()?.next().ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::AddrNotAvailable,
                format!("{addr}: no usable socket address"),
            )
        })?;
        let stream = TcpStream::connect_timeout(&sock, within)?;
        TcpTransport::handshake_within(stream, role, epoch, within.min(HANDSHAKE_TIMEOUT))
    }

    /// [`TcpTransport::connect_retry`] announcing a specific session
    /// epoch (resume re-key path).
    pub fn connect_retry_at_epoch(
        addr: &str,
        role: u8,
        deadline_in: Duration,
        epoch: u64,
    ) -> io::Result<TcpTransport> {
        let deadline = Instant::now() + deadline_in;
        let mut backoff = Duration::from_millis(25);
        loop {
            let remaining = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(1));
            match TcpTransport::connect_within(addr, role, epoch, remaining) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    let retryable = matches!(
                        e.kind(),
                        io::ErrorKind::ConnectionRefused
                            | io::ErrorKind::ConnectionReset
                            | io::ErrorKind::ConnectionAborted
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::WouldBlock
                            | io::ErrorKind::AddrNotAvailable
                            | io::ErrorKind::Interrupted
                            | io::ErrorKind::UnexpectedEof
                    );
                    if !retryable || Instant::now() >= deadline {
                        return Err(io::Error::new(
                            e.kind(),
                            format!("connecting to {addr}: {e}"),
                        ));
                    }
                    std::thread::sleep(backoff.min(deadline.saturating_duration_since(
                        Instant::now(),
                    )));
                    backoff = (backoff * 2).min(Duration::from_millis(800));
                }
            }
        }
    }

    /// Handshake on an accepted stream, announcing `role` (the
    /// accepting side always answers at epoch 0 — the epoch is the
    /// *connector's* claim, read back via `peer_epoch`).
    pub fn accept(stream: TcpStream, role: u8) -> io::Result<TcpTransport> {
        TcpTransport::handshake(stream, role, 0)
    }

    /// Send one framed [`wire::WireMsg`].
    pub fn send_wire(&mut self, msg: &wire::WireMsg) -> io::Result<()> {
        wire::write_frame(&mut self.writer, &msg.encode())
    }

    /// Receive one framed [`wire::WireMsg`].
    pub fn recv_wire(&mut self) -> io::Result<wire::WireMsg> {
        let frame = wire::read_frame(&mut self.reader)?;
        Ok(wire::WireMsg::decode(&frame)?)
    }
}

impl Transport for TcpTransport {
    fn send_msg(&mut self, msg: Vec<u8>) -> io::Result<()> {
        wire::write_frame(&mut self.writer, &msg)
    }

    fn recv_msg(&mut self) -> io::Result<Vec<u8>> {
        wire::read_frame(&mut self.reader)
    }

    fn label(&self) -> &'static str {
        "tcp"
    }

    fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.writer.write_all(bytes)?;
        self.writer.flush()
    }
}

/// Wrap a TCP transport in the duplex byte-channel interface used by the
/// garbling engine and OT (write combining, flush, counters preserved).
pub fn tcp_channel(t: TcpTransport) -> Channel {
    Channel::over(Box::new(t))
}

/// A connected pair of TCP channels over a loopback socket: the two
/// Center servers' link as real kernel sockets instead of an in-process
/// queue. Returns `(garbler_end, evaluator_end)`.
pub fn loopback_channel_pair() -> io::Result<(Channel, Channel)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let connector = std::thread::spawn(move || TcpTransport::connect(addr, wire::ROLE_PEER));
    let (accepted, _) = listener.accept()?;
    let server_end = TcpTransport::accept(accepted, wire::ROLE_PEER)?;
    let client_end = connector
        .join()
        .map_err(|_| io::Error::new(io::ErrorKind::Other, "loopback connector panicked"))??;
    Ok((tcp_channel(client_end), tcp_channel(server_end)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::WireMsg;

    #[test]
    fn tcp_transport_frames_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = TcpTransport::connect(addr, wire::ROLE_CENTER).unwrap();
            t.send_wire(&WireMsg::MetaReq).unwrap();
            t.send_msg(vec![7; 100_000]).unwrap(); // bigger than one TCP segment
            assert_eq!(t.recv_msg().unwrap(), b"pong".to_vec());
            t.peer_role
        });
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
        assert_eq!(t.recv_wire().unwrap(), WireMsg::MetaReq);
        assert_eq!(t.recv_msg().unwrap(), vec![7; 100_000]);
        t.send_msg(b"pong".to_vec()).unwrap();
        assert_eq!(t.peer_role, wire::ROLE_CENTER);
        assert_eq!(t.peer_epoch, 0, "plain connect announces epoch 0");
        assert_eq!(client.join().unwrap(), wire::ROLE_NODE);
    }

    /// The session epoch a resuming center announces in its hello must
    /// surface on the accepting side's transport.
    #[test]
    fn handshake_carries_session_epoch() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            TcpTransport::connect_at_epoch(addr, wire::ROLE_CENTER, 3).unwrap()
        });
        let (stream, _) = listener.accept().unwrap();
        let t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
        assert_eq!(t.peer_epoch, 3, "accept side sees the connector's epoch");
        let c = client.join().unwrap();
        assert_eq!(c.peer_epoch, 0, "accept side answers at epoch 0");
    }

    /// A peer that opens with the wrong magic must be rejected during the
    /// handshake, before any payload parsing.
    #[test]
    fn handshake_rejects_bad_magic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let rogue = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET / HTTP/1.1\r\n").unwrap(); // an HTTP client, say
            s.flush().unwrap();
            // Keep the socket open until the server has judged us.
            let mut buf = [0u8; wire::HELLO_LEN];
            let _ = s.read(&mut buf);
        });
        let (stream, _) = listener.accept().unwrap();
        let err = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        rogue.join().unwrap();
    }

    /// Version skew must be detected symmetrically.
    #[test]
    fn handshake_rejects_version_mismatch() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let old_peer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut h = wire::hello(wire::ROLE_CENTER, 0);
            h[4] = 0xFE; // future version 0x__FE
            h[5] = 0x7F;
            s.write_all(&h).unwrap();
            s.flush().unwrap();
            let mut buf = [0u8; 8];
            let _ = s.read(&mut buf);
        });
        let (stream, _) = listener.accept().unwrap();
        let err = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("version"), "got: {err}");
        old_peer.join().unwrap();
    }

    /// The loopback channel pair must behave exactly like the mpsc pair:
    /// byte-oriented reads across message boundaries, both directions.
    #[test]
    fn loopback_channels_match_channel_semantics() {
        let (mut a, mut b) = loopback_channel_pair().unwrap();
        let t = std::thread::spawn(move || {
            a.send_u64(42);
            a.send_blob(b"hello center");
            a.send_u128(0xdead_beef_dead_beef_dead_beef_dead_beefu128);
            a.flush();
            assert_eq!(a.recv_u64(), 7);
            a
        });
        assert_eq!(b.recv_u64(), 42);
        assert_eq!(b.recv_blob(), b"hello center");
        assert_eq!(b.recv_u128(), 0xdead_beef_dead_beef_dead_beef_dead_beefu128);
        b.send_u64(7);
        b.flush();
        let a = t.join().unwrap();
        let (sent, msgs) = a.stats().snapshot();
        assert_eq!(sent, 8 + 8 + 12 + 16);
        assert!(msgs >= 1);
        let (recv_bytes, recv_msgs) = a.stats().snapshot_recv();
        assert_eq!(recv_bytes, 8);
        assert_eq!(recv_msgs, 1);
    }

    /// A peer that handshakes but then never replies must fail the read
    /// with a timeout-class error once a deadline is set — not block.
    #[test]
    fn deadline_turns_silent_peer_into_timeout() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let silent = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
            // Hold the connection open, never send a frame.
            std::thread::sleep(Duration::from_millis(400));
            drop(t);
        });
        let mut t = TcpTransport::connect(addr, wire::ROLE_CENTER).unwrap();
        t.set_deadline(Some(Duration::from_millis(50))).unwrap();
        let start = Instant::now();
        let err = t.recv_wire().unwrap_err();
        assert!(
            matches!(err.kind(), io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock),
            "expected a timeout-class error, got {err:?}"
        );
        assert!(start.elapsed() < Duration::from_millis(350), "deadline not enforced");
        silent.join().unwrap();
    }

    #[test]
    fn connect_retry_times_out_with_address_in_error() {
        // A port from the ephemeral range with nothing listening.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let err = TcpTransport::connect_retry(&addr, wire::ROLE_CENTER, Duration::from_millis(120))
            .unwrap_err();
        assert!(err.to_string().contains(&addr), "error should name the address: {err}");
    }
}
