//! Real networked transport for the paper's distributed deployment.
//!
//! The paper's testbed (Figure 1) is a *network*: organizations and two
//! Center servers exchanging Paillier ciphertexts and garbled-circuit
//! material over ethernet. This module makes the reproduction runnable
//! across real process and machine boundaries:
//!
//! * [`Transport`] — the seam between the byte-oriented
//!   [`Channel`](crate::gc::channel::Channel) / fleet layers and the
//!   medium that carries the bytes. Two implementations: [`MemTransport`]
//!   (the original in-process `mpsc` pair) and
//!   [`tcp::TcpTransport`] (length-prefixed, CRC-framed TCP with a
//!   magic/version handshake).
//! * [`wire`] — the versioned binary wire format: codecs for every
//!   cross-boundary payload (bigints, Paillier ciphertexts, garbled
//!   tables, OT messages, fleet statistic requests/replies) plus the
//!   frame and handshake encodings.
//! * [`fleet::RemoteFleet`] — the Center's view of node servers reached
//!   over persistent TCP connections, with concurrent request fan-out and
//!   node-measured wall-time attribution (so the ledger's parallel-round
//!   accounting stays exact across machines).
//! * [`server::NodeServer`] — the organization side: a server that owns
//!   one data partition and answers statistic requests
//!   (`privlogit node --listen …`).
//!
//! The CLI wires these together (`privlogit node`, `privlogit center`,
//! and the split two-server center `privlogit center-a`/`center-b` —
//! the peer half lives in [`crate::mpc::peer`]); see `docs/DEPLOY.md`
//! for invocation lines, `docs/ARCHITECTURE.md` for the wire-protocol
//! reference, and `examples/distributed_loopback.rs` for a
//! self-contained loopback run.
//!
//! Privacy note: once the center installs its Paillier key
//! (`Fleet::install_key` → [`wire::WireMsg::SetKey`]), node servers
//! encrypt every statistic themselves and only
//! [`wire::WireMsg::Ciphertexts`] payloads cross the fleet wire — the
//! paper's Figure 1 data flow, in which the Center never sees node
//! plaintext. The in-process fleets (and the cost-model backend, which
//! has no key) instead return plaintext summaries that the *fabric*
//! encrypts at its boundary, attributing the cost to the node.
//!
//! Cheap wire-format round trip:
//!
//! ```
//! use privlogit::net::wire::WireMsg;
//! let msg = WireMsg::GramReq { scale: 0.25 };
//! assert_eq!(WireMsg::decode(&msg.encode()).unwrap(), msg);
//! ```

pub mod fleet;
pub mod server;
pub mod tcp;
pub mod wire;

use std::io;
use std::sync::mpsc::{Receiver, SyncSender};

pub use fleet::{ExcludedNode, FleetOptions, ReadmittedNode, RemoteFleet};
pub use server::NodeServer;
pub use tcp::TcpTransport;

/// A duplex, message-oriented byte carrier: the seam between the protocol
/// layers and the medium (in-memory queue vs TCP socket).
///
/// Messages are atomic: one `send_msg` arrives as one `recv_msg` on the
/// peer. The byte-stream view (write combining, partial reads) lives above
/// this trait, in [`Channel`](crate::gc::channel::Channel).
pub trait Transport: Send {
    /// Send one message to the peer.
    fn send_msg(&mut self, msg: Vec<u8>) -> io::Result<()>;
    /// Block until the peer's next message arrives.
    fn recv_msg(&mut self) -> io::Result<Vec<u8>>;
    /// Human-readable medium label ("mem", "tcp") for reports.
    fn label(&self) -> &'static str;
    /// Write raw bytes to the medium without any framing, bypassing the
    /// one-`send_msg`-per-`recv_msg` message discipline. Only
    /// stream-oriented transports can honor this; the default refuses
    /// with [`io::ErrorKind::Unsupported`]. Exists for the
    /// fault-injection harness (`testutil::faults`), which needs to cut
    /// a frame off mid-payload to simulate a node dying mid-write.
    fn send_raw(&mut self, _bytes: &[u8]) -> io::Result<()> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "transport is not stream-oriented"))
    }
}

/// The original in-process transport: a bounded `mpsc` pair between two
/// threads of one process.
pub struct MemTransport {
    tx: SyncSender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl Transport for MemTransport {
    fn send_msg(&mut self, msg: Vec<u8>) -> io::Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| io::Error::new(io::ErrorKind::BrokenPipe, "mem peer hung up"))
    }

    fn recv_msg(&mut self) -> io::Result<Vec<u8>> {
        self.rx
            .recv()
            .map_err(|_| io::Error::new(io::ErrorKind::UnexpectedEof, "mem peer hung up"))
    }

    fn label(&self) -> &'static str {
        "mem"
    }
}

/// Create a connected duplex pair of in-memory transports.
///
/// Generous bound: the streaming garbler can run ahead of the evaluator by
/// up to 256 messages (~16 MiB) before backpressure kicks in.
pub fn mem_transport_pair() -> (MemTransport, MemTransport) {
    let (tx_ab, rx_ab) = std::sync::mpsc::sync_channel(256);
    let (tx_ba, rx_ba) = std::sync::mpsc::sync_channel(256);
    (MemTransport { tx: tx_ab, rx: rx_ba }, MemTransport { tx: tx_ba, rx: rx_ab })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_transport_roundtrip() {
        let (mut a, mut b) = mem_transport_pair();
        a.send_msg(vec![1, 2, 3]).unwrap();
        b.send_msg(vec![9]).unwrap();
        assert_eq!(b.recv_msg().unwrap(), vec![1, 2, 3]);
        assert_eq!(a.recv_msg().unwrap(), vec![9]);
        assert_eq!(a.label(), "mem");
    }

    #[test]
    fn mem_transport_peer_drop_is_error() {
        let (mut a, b) = mem_transport_pair();
        drop(b);
        assert!(a.send_msg(vec![0]).is_err());
        assert!(a.recv_msg().is_err());
    }
}
