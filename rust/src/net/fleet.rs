//! The node fleet over real sockets: [`RemoteFleet`] is the Center's
//! view of organizations running as [`super::server::NodeServer`]
//! processes (or threads) reached over persistent TCP connections.
//!
//! Requests fan out concurrently — one scoped thread per connection per
//! round, matching the genuinely-parallel deployment of the paper's
//! Figure 1 — and every reply carries the *node-measured* compute
//! seconds, so the ledger's parallel-round accounting stays exact across
//! machine boundaries (network time is measured separately, from the
//! wire byte counters and round structure).
//!
//! After [`Fleet::install_key`] the node servers hold the Center's
//! Paillier public key and encrypt every statistic reply themselves:
//! only [`WireMsg::Ciphertexts`] payloads cross the fleet wire, matching
//! the paper's threat model (the Center never sees node plaintext). The
//! per-connection wire-tag census ([`RemoteFleet::reply_tag_counts`])
//! lets tests *prove* that no plaintext statistic reply ever crossed.
//!
//! A node that fails mid-protocol surfaces as a clean `Err` from the
//! round — the [`Fleet`] contract threads `Result` all the way to the
//! CLI, so `privlogit center` exits with a message naming the node
//! instead of panicking.

use std::collections::BTreeMap;
use std::io;
use std::time::Duration;

use super::tcp::TcpTransport;
use super::wire::{self, WireMsg};
use super::Transport;
use crate::coordinator::fleet::{
    EncStat, Fleet, FleetKey, FleetNet, NodePayload, NodeReply, StepReply,
};
use crate::obs::{self, TagFlow};

/// One persistent connection to a node server, with wire counters and a
/// census of reply tag bytes (used to assert the ciphertext-only wire).
struct NodeConn {
    addr: String,
    transport: TcpTransport,
    bytes_sent: u64,
    bytes_recv: u64,
    msgs_sent: u64,
    msgs_recv: u64,
    reply_tags: BTreeMap<u8, u64>,
    /// Per-tag byte/frame accounting, both directions.
    tag_flows: BTreeMap<u8, TagFlow>,
    /// Set once the key is installed: from then on a plaintext
    /// statistic reply is a protocol violation, not a fallback.
    require_enc: bool,
}

/// Frame overhead per message: u32 length prefix + u32 CRC.
const FRAME_OVERHEAD: u64 = 8;

impl NodeConn {
    fn send(&mut self, req: &WireMsg) -> io::Result<()> {
        let body = req.encode();
        let framed = body.len() as u64 + FRAME_OVERHEAD;
        self.bytes_sent += framed;
        self.msgs_sent += 1;
        let flow = self.tag_flows.entry(req.tag()).or_default();
        flow.sent_frames += 1;
        flow.sent_bytes += framed;
        self.transport.send_msg(body)
    }

    fn recv(&mut self) -> io::Result<WireMsg> {
        let reply = self.transport.recv_msg()?;
        let framed = reply.len() as u64 + FRAME_OVERHEAD;
        self.bytes_recv += framed;
        self.msgs_recv += 1;
        if let Some(&tag) = reply.first() {
            *self.reply_tags.entry(tag).or_insert(0) += 1;
            let flow = self.tag_flows.entry(tag).or_default();
            flow.recv_frames += 1;
            flow.recv_bytes += framed;
        }
        Ok(WireMsg::decode(&reply)?)
    }

    /// One request/reply exchange, counting framed bytes both directions.
    fn exchange(&mut self, req: &WireMsg) -> io::Result<WireMsg> {
        self.send(req)?;
        self.recv()
    }

    /// A statistic reply in either form: plaintext (no key installed) or
    /// node-encrypted ciphertexts. After the key install, a plaintext
    /// reply is rejected — the ciphertext-only wire is enforced, not
    /// just observed.
    fn expect_stat_reply(&mut self, req: &WireMsg) -> io::Result<NodeReply> {
        match self.exchange(req)? {
            WireMsg::NodeReply { .. } if self.require_enc => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "node downgraded to a plaintext statistic after the key install",
            )),
            WireMsg::NodeReply { values, loglik, secs } => {
                Ok(NodeReply { payload: NodePayload::Plain { values, loglik }, secs })
            }
            WireMsg::Ciphertexts { scale, secs, cts } => {
                Ok(NodeReply { payload: NodePayload::Enc(EncStat { scale, cts }), secs })
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where a statistic reply was expected"),
            )),
        }
    }

    fn expect_ciphertexts(&mut self) -> io::Result<(EncStat, f64)> {
        match self.recv()? {
            WireMsg::Ciphertexts { scale, secs, cts } => Ok((EncStat { scale, cts }, secs)),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where ciphertexts were expected"),
            )),
        }
    }

    fn expect_ack(&mut self, req: &WireMsg) -> io::Result<()> {
        match self.exchange(req)? {
            WireMsg::Ack => Ok(()),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where an acknowledgement was expected"),
            )),
        }
    }

    /// One step round: `StepReq` out, two `Ciphertexts` frames back
    /// (partial step, then log-likelihood).
    fn expect_step_reply(&mut self, req: &WireMsg) -> io::Result<StepReply> {
        self.send(req)?;
        let (part, secs) = self.expect_ciphertexts()?;
        let (loglik, _) = self.expect_ciphertexts()?;
        Ok(StepReply { part, loglik, secs })
    }
}

/// [`Fleet`] implementation over persistent TCP connections to node
/// servers.
pub struct RemoteFleet {
    conns: Vec<NodeConn>,
    n_total: usize,
    p: usize,
    name: String,
    encrypted: bool,
    /// Session id (hash of the installed Paillier modulus; 0 pre-key).
    session: u64,
    /// Per-tag round counters: the Nth broadcast of a tag is round N
    /// within this session. Node servers number the same occurrences
    /// independently, so cross-process traces join on (session, round,
    /// tag) without any wire change.
    round_ctr: BTreeMap<u8, u64>,
}

/// How long `connect` keeps retrying each node address before giving up
/// (covers start-up ordering between node and center processes).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

impl RemoteFleet {
    /// Connect to every node server, retrying each address for up to
    /// [`CONNECT_TIMEOUT`], and fetch shard metadata. All shards must
    /// agree on dimensionality.
    pub fn connect(addrs: &[String]) -> anyhow::Result<RemoteFleet> {
        anyhow::ensure!(!addrs.is_empty(), "remote fleet needs at least one node address");
        let mut sp = obs::span("fleet.round")
            .session(0)
            .tag(wire::TAG_META_REQ)
            .round(0)
            .u64("nodes", addrs.len() as u64);
        let mut conns = Vec::with_capacity(addrs.len());
        let mut n_total = 0usize;
        let mut p = 0usize;
        let mut name = String::new();
        for (j, addr) in addrs.iter().enumerate() {
            let transport =
                TcpTransport::connect_retry(addr, wire::ROLE_CENTER, CONNECT_TIMEOUT)?;
            let mut conn = NodeConn {
                addr: addr.clone(),
                transport,
                bytes_sent: 0,
                bytes_recv: 0,
                msgs_sent: 0,
                msgs_recv: 0,
                reply_tags: BTreeMap::new(),
                tag_flows: BTreeMap::new(),
                require_enc: false,
            };
            match conn.exchange(&WireMsg::MetaReq)? {
                WireMsg::Meta { n, p: node_p, name: node_name } => {
                    // Node metadata is wire-controlled: bound it before
                    // it drives allocations or arithmetic.
                    let node_p = node_p as usize;
                    anyhow::ensure!(
                        node_p >= 1,
                        "node {addr} reports a degenerate dimensionality p={node_p}"
                    );
                    let node_n = usize::try_from(n).map_err(|_| {
                        anyhow::anyhow!("node {addr} reports n={n}, beyond this platform")
                    })?;
                    anyhow::ensure!(
                        node_n >= 1,
                        "node {addr} reports an empty shard (n=0)"
                    );
                    if j == 0 {
                        p = node_p;
                        name = node_name;
                    } else {
                        anyhow::ensure!(
                            node_p == p,
                            "node {addr} serves p={node_p}, fleet expects p={p}"
                        );
                    }
                    n_total = n_total.checked_add(node_n).ok_or_else(|| {
                        anyhow::anyhow!("fleet sample total overflows adding node {addr}")
                    })?;
                }
                other => anyhow::bail!("node {addr} answered MetaReq with {other:?}"),
            }
            conns.push(conn);
        }
        if sp.active() {
            sp.record_u64("bytes_sent", conns.iter().map(|c| c.bytes_sent).sum());
            sp.record_u64("bytes_recv", conns.iter().map(|c| c.bytes_recv).sum());
        }
        sp.done();
        Ok(RemoteFleet {
            conns,
            n_total,
            p,
            name,
            encrypted: false,
            session: 0,
            round_ctr: BTreeMap::new(),
        })
    }

    /// Next round index for `tag` within this session (counted on both
    /// wire ends independently; see the field doc on `round_ctr`). The
    /// connect-time `MetaReq` exchange is round 0 by construction.
    fn next_round(&mut self, tag: u8) -> u64 {
        let ctr = self.round_ctr.entry(tag).or_insert(0);
        let round = if tag == wire::TAG_META_REQ { *ctr + 1 } else { *ctr };
        *ctr += 1;
        round
    }

    /// Run one broadcast round under a `fleet.round` span carrying the
    /// (session, round, tag) join key and framed byte deltas, with one
    /// `fleet.rpc` child span per node measuring request→reply latency.
    fn traced_round<T: Send>(
        &mut self,
        tag: u8,
        per_node: impl Fn(&mut NodeConn) -> io::Result<T> + Sync,
    ) -> anyhow::Result<Vec<T>> {
        let session = self.session;
        let round = self.next_round(tag);
        let mut sp = obs::span("fleet.round")
            .session(session)
            .tag(tag)
            .round(round)
            .u64("nodes", self.conns.len() as u64);
        let before = sp.active().then(|| self.net_stats());
        let out = self.round_with(|c| {
            let mut rpc = obs::span("fleet.rpc")
                .session(session)
                .tag(tag)
                .round(round)
                .str("node", &c.addr);
            let b0 = (c.bytes_sent, c.bytes_recv);
            let r = per_node(c);
            if rpc.active() {
                rpc.record_u64("bytes_sent", c.bytes_sent - b0.0);
                rpc.record_u64("bytes_recv", c.bytes_recv - b0.1);
                rpc.record_u64("ok", r.is_ok() as u64);
            }
            r
        });
        if let Some(b) = before {
            let after = self.net_stats();
            sp.record_u64("bytes_sent", after.bytes_sent - b.bytes_sent);
            sp.record_u64("bytes_recv", after.bytes_recv - b.bytes_recv);
        }
        out
    }

    /// Broadcast one request to every node concurrently and collect the
    /// per-node results in node order; any node's failure aborts the
    /// round with an error naming that node.
    fn round_with<T: Send>(
        &mut self,
        per_node: impl Fn(&mut NodeConn) -> io::Result<T> + Sync,
    ) -> anyhow::Result<Vec<T>> {
        let per_node = &per_node;
        let results: Vec<(String, io::Result<T>)> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .map(|c| s.spawn(move || (c.addr.clone(), per_node(c))))
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(pair) => pair,
                    Err(_) => (
                        "?".to_string(),
                        Err(io::Error::new(io::ErrorKind::Other, "node round worker panicked")),
                    ),
                })
                .collect()
        });
        results
            .into_iter()
            .map(|(addr, r)| {
                r.map_err(|e| anyhow::anyhow!("node server {addr} failed mid-protocol: {e}"))
            })
            .collect()
    }

    /// Census of reply tag bytes received from the nodes, merged across
    /// connections (tag byte → count). With node-side encryption
    /// installed, `wire::TAG_NODE_REPLY` must never appear — the
    /// assertion the ciphertext-only integration test makes.
    pub fn reply_tag_counts(&self) -> BTreeMap<u8, u64> {
        let mut out = BTreeMap::new();
        for c in &self.conns {
            for (&tag, &count) in &c.reply_tags {
                *out.entry(tag).or_insert(0) += count;
            }
        }
        out
    }
}

impl Fleet for RemoteFleet {
    fn orgs(&self) -> usize {
        self.conns.len()
    }
    fn n_total(&self) -> usize {
        self.n_total
    }
    fn p(&self) -> usize {
        self.p
    }
    fn dataset_name(&self) -> String {
        self.name.clone()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let req = WireMsg::StatsReq { beta: beta.to_vec(), scale };
        self.traced_round(wire::TAG_STATS_REQ, |c| c.expect_stat_reply(&req))
    }

    fn gram(&mut self, scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let req = WireMsg::GramReq { scale };
        self.traced_round(wire::TAG_GRAM_REQ, |c| c.expect_stat_reply(&req))
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let req = WireMsg::HessReq { beta: beta.to_vec(), scale };
        self.traced_round(wire::TAG_HESS_REQ, |c| c.expect_stat_reply(&req))
    }

    fn label(&self) -> String {
        let mode = if self.encrypted {
            "node-side encryption"
        } else {
            "plaintext statistics"
        };
        format!("remote fleet ({} node servers over tcp; {mode})", self.conns.len())
    }

    fn net_stats(&self) -> FleetNet {
        let mut net = FleetNet::default();
        for c in &self.conns {
            net.bytes_sent += c.bytes_sent;
            net.bytes_recv += c.bytes_recv;
            net.msgs_sent += c.msgs_sent;
            net.msgs_recv += c.msgs_recv;
        }
        net
    }

    fn install_key(&mut self, key: &FleetKey) -> anyhow::Result<bool> {
        // The installed modulus defines the session: adopt the id
        // before the round so the SetKey span already carries it (node
        // servers derive the same id when they process the install).
        self.session = obs::session_id(&key.n.to_bytes_le());
        let req = WireMsg::SetKey { n: key.n.clone(), w: key.w, f: key.f };
        self.traced_round(wire::TAG_SET_KEY, |c| {
            c.expect_ack(&req)?;
            c.require_enc = true;
            Ok(())
        })?;
        self.encrypted = true;
        Ok(true)
    }

    fn nodes_encrypt(&self) -> bool {
        self.encrypted
    }

    fn install_hinv(&mut self, hinv: &EncStat) -> anyhow::Result<()> {
        anyhow::ensure!(self.encrypted, "install the Paillier key before Enc(H̃⁻¹)");
        let req = WireMsg::SetHinv { scale: hinv.scale, cts: hinv.cts.clone() };
        self.traced_round(wire::TAG_SET_HINV, |c| c.expect_ack(&req))?;
        Ok(())
    }

    fn step(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<StepReply>> {
        anyhow::ensure!(self.encrypted, "step rounds need node-side encryption installed");
        let req = WireMsg::StepReq { beta: beta.to_vec(), scale };
        self.traced_round(wire::TAG_STEP_REQ, |c| c.expect_step_reply(&req))
    }

    fn tag_flows(&self) -> BTreeMap<u8, TagFlow> {
        let mut out: BTreeMap<u8, TagFlow> = BTreeMap::new();
        for c in &self.conns {
            for (&tag, flow) in &c.tag_flows {
                out.entry(tag).or_default().merge(flow);
            }
        }
        out
    }
}

impl Drop for RemoteFleet {
    fn drop(&mut self) {
        // Best-effort: let node servers exit their session loops cleanly.
        for c in &mut self.conns {
            let _ = c.transport.send_wire(&WireMsg::Shutdown);
        }
    }
}
