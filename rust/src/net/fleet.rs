//! The node fleet over real sockets: [`RemoteFleet`] is the Center's
//! view of organizations running as [`super::server::NodeServer`]
//! processes (or threads) reached over persistent TCP connections.
//!
//! Requests fan out concurrently — one scoped thread per connection per
//! round, matching the genuinely-parallel deployment of the paper's
//! Figure 1 — and every reply carries the *node-measured* compute
//! seconds, so the ledger's parallel-round accounting stays exact across
//! machine boundaries (network time is measured separately, from the
//! wire byte counters and round structure).

use std::io;
use std::time::Duration;

use super::tcp::TcpTransport;
use super::wire::{self, WireMsg};
use super::Transport;
use crate::coordinator::fleet::{Fleet, FleetNet, NodeReply};

/// One persistent connection to a node server, with wire counters.
struct NodeConn {
    addr: String,
    transport: TcpTransport,
    bytes_sent: u64,
    bytes_recv: u64,
    msgs_sent: u64,
    msgs_recv: u64,
}

/// Frame overhead per message: u32 length prefix + u32 CRC.
const FRAME_OVERHEAD: u64 = 8;

impl NodeConn {
    /// One request/reply exchange, counting framed bytes both directions.
    fn exchange(&mut self, req: &WireMsg) -> io::Result<WireMsg> {
        let body = req.encode();
        self.bytes_sent += body.len() as u64 + FRAME_OVERHEAD;
        self.msgs_sent += 1;
        self.transport.send_msg(body)?;
        let reply = self.transport.recv_msg()?;
        self.bytes_recv += reply.len() as u64 + FRAME_OVERHEAD;
        self.msgs_recv += 1;
        Ok(WireMsg::decode(&reply)?)
    }

    fn expect_node_reply(&mut self, req: &WireMsg) -> io::Result<NodeReply> {
        match self.exchange(req)? {
            WireMsg::NodeReply { values, loglik, secs } => {
                Ok(NodeReply { values, loglik, secs })
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where a statistic reply was expected"),
            )),
        }
    }
}

/// [`Fleet`] implementation over persistent TCP connections to node
/// servers.
pub struct RemoteFleet {
    conns: Vec<NodeConn>,
    n_total: usize,
    p: usize,
    name: String,
}

/// How long `connect` keeps retrying each node address before giving up
/// (covers start-up ordering between node and center processes).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

impl RemoteFleet {
    /// Connect to every node server, retrying each address for up to
    /// [`CONNECT_TIMEOUT`], and fetch shard metadata. All shards must
    /// agree on dimensionality.
    pub fn connect(addrs: &[String]) -> anyhow::Result<RemoteFleet> {
        anyhow::ensure!(!addrs.is_empty(), "remote fleet needs at least one node address");
        let mut conns = Vec::with_capacity(addrs.len());
        let mut n_total = 0usize;
        let mut p = 0usize;
        let mut name = String::new();
        for (j, addr) in addrs.iter().enumerate() {
            let transport =
                TcpTransport::connect_retry(addr, wire::ROLE_CENTER, CONNECT_TIMEOUT)?;
            let mut conn = NodeConn {
                addr: addr.clone(),
                transport,
                bytes_sent: 0,
                bytes_recv: 0,
                msgs_sent: 0,
                msgs_recv: 0,
            };
            match conn.exchange(&WireMsg::MetaReq)? {
                WireMsg::Meta { n, p: node_p, name: node_name } => {
                    let node_p = node_p as usize;
                    if j == 0 {
                        p = node_p;
                        name = node_name;
                    } else {
                        anyhow::ensure!(
                            node_p == p,
                            "node {addr} serves p={node_p}, fleet expects p={p}"
                        );
                    }
                    n_total += n as usize;
                }
                other => anyhow::bail!("node {addr} answered MetaReq with {other:?}"),
            }
            conns.push(conn);
        }
        Ok(RemoteFleet { conns, n_total, p, name })
    }

    /// Broadcast one request to every node concurrently and collect the
    /// replies in node order.
    ///
    /// A node that fails mid-protocol aborts the run with a message
    /// naming the node — the [`Fleet`] contract has no error channel
    /// (in-process fleets can only fail on program bugs), so a dropped
    /// TCP peer cannot yet be surfaced as a clean `Err`; threading
    /// `Result` through `Fleet` is on the roadmap.
    fn round(&mut self, req: WireMsg) -> Vec<NodeReply> {
        std::thread::scope(|s| {
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .map(|c| {
                    let req = req.clone();
                    s.spawn(move || (c.addr.clone(), c.expect_node_reply(&req)))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    let (addr, reply) = h.join().expect("node round thread");
                    reply.unwrap_or_else(|e| {
                        panic!("node server {addr} failed mid-protocol: {e}")
                    })
                })
                .collect()
        })
    }
}

impl Fleet for RemoteFleet {
    fn orgs(&self) -> usize {
        self.conns.len()
    }
    fn n_total(&self) -> usize {
        self.n_total
    }
    fn p(&self) -> usize {
        self.p
    }
    fn dataset_name(&self) -> String {
        self.name.clone()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply> {
        self.round(WireMsg::StatsReq { beta: beta.to_vec(), scale })
    }

    fn gram(&mut self, scale: f64) -> Vec<NodeReply> {
        self.round(WireMsg::GramReq { scale })
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> Vec<NodeReply> {
        self.round(WireMsg::HessReq { beta: beta.to_vec(), scale })
    }

    fn label(&self) -> String {
        format!("remote fleet ({} node servers over tcp)", self.conns.len())
    }

    fn net_stats(&self) -> FleetNet {
        let mut net = FleetNet::default();
        for c in &self.conns {
            net.bytes_sent += c.bytes_sent;
            net.bytes_recv += c.bytes_recv;
            net.msgs_sent += c.msgs_sent;
            net.msgs_recv += c.msgs_recv;
        }
        net
    }
}

impl Drop for RemoteFleet {
    fn drop(&mut self) {
        // Best-effort: let node servers exit their session loops cleanly.
        for c in &mut self.conns {
            let _ = c.transport.send_wire(&WireMsg::Shutdown);
        }
    }
}
