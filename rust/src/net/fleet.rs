//! The node fleet over real sockets: [`RemoteFleet`] is the Center's
//! view of organizations running as [`super::server::NodeServer`]
//! processes (or threads) reached over persistent TCP connections.
//!
//! Requests fan out concurrently — one scoped thread per connection per
//! round, matching the genuinely-parallel deployment of the paper's
//! Figure 1 — and every reply carries the *node-measured* compute
//! seconds, so the ledger's parallel-round accounting stays exact across
//! machine boundaries (network time is measured separately, from the
//! wire byte counters and round structure).
//!
//! After [`Fleet::install_key`] the node servers hold the Center's
//! Paillier public key and encrypt every statistic reply themselves:
//! only [`WireMsg::Ciphertexts`] payloads cross the fleet wire, matching
//! the paper's threat model (the Center never sees node plaintext). The
//! per-connection wire-tag census ([`RemoteFleet::reply_tag_counts`])
//! lets tests *prove* that no plaintext statistic reply ever crossed.
//!
//! **Fault tolerance.** Fleet rounds survive slow, dead and
//! byzantine-slow nodes ([`FleetOptions`]): every connection carries a
//! per-round socket deadline, connect attempts retry with capped
//! exponential backoff, and — when a `quorum` below the fleet size is
//! configured — a round succeeds once at least that many nodes reply.
//! A node that misses a round is *excluded* ([`ExcludedNode`]) and
//! `n_total` is recomputed from the live membership. Below quorum the
//! round fails with an error naming every dead node. The `fleet.round`
//! span records `replied`/`quorum`/`excluded`/`readmitted` and each
//! per-node `fleet.rpc` span records `outcome=ok|timeout|error`, so
//! the merged timeline shows exactly which org straggled in which
//! round.
//!
//! **Readmission.** Exclusion is no longer permanent: at every
//! statistic round boundary the fleet probes each excluded node over a
//! *fresh* connection (the old one's frame stream may be
//! desynchronized mid-frame) within a small [`READMIT_PROBE_TIMEOUT`]
//! budget. A node that answers `Ping` and still agrees on shard shape
//! gets its session state rebuilt — epoch-aware `SetKey`, then
//! `Enc(H̃⁻¹)` if installed — and rejoins the live membership, with
//! `n_total` restored and a `fleet.readmit` span attributing the
//! round it came back in ([`ReadmittedNode`]). The fresh connection is
//! what keeps the node-side replay guard sound: the node's new session
//! derives a new randomness stream, so nothing from the dead session
//! is ever replayed.

use std::collections::BTreeMap;
use std::io;
use std::time::Duration;

use super::tcp::TcpTransport;
use super::wire::{self, WireMsg};
use super::Transport;
use crate::coordinator::fleet::{
    EncStat, Fleet, FleetKey, FleetNet, NodePayload, NodeReply, StepReply,
};
use crate::obs::{self, TagFlow};

/// One persistent connection to a node server, with wire counters and a
/// census of reply tag bytes (used to assert the ciphertext-only wire).
struct NodeConn {
    /// 0-based org index at connect time (stable across exclusions, so
    /// ledger attribution keeps naming the same organization).
    index: usize,
    addr: String,
    /// Samples this node's shard holds (from its `Meta` reply) — what
    /// `n_total` is recomputed from when membership shrinks.
    node_n: usize,
    transport: TcpTransport,
    bytes_sent: u64,
    bytes_recv: u64,
    msgs_sent: u64,
    msgs_recv: u64,
    reply_tags: BTreeMap<u8, u64>,
    /// Per-tag byte/frame accounting, both directions.
    tag_flows: BTreeMap<u8, TagFlow>,
    /// Set once the key is installed: from then on a plaintext
    /// statistic reply is a protocol violation, not a fallback.
    require_enc: bool,
}

/// Frame overhead per message: u32 length prefix + u32 CRC.
const FRAME_OVERHEAD: u64 = 8;

impl NodeConn {
    fn new(index: usize, addr: String, transport: TcpTransport) -> NodeConn {
        NodeConn {
            index,
            addr,
            node_n: 0,
            transport,
            bytes_sent: 0,
            bytes_recv: 0,
            msgs_sent: 0,
            msgs_recv: 0,
            reply_tags: BTreeMap::new(),
            tag_flows: BTreeMap::new(),
            require_enc: false,
        }
    }

    fn send(&mut self, req: &WireMsg) -> io::Result<()> {
        let body = req.encode();
        let framed = body.len() as u64 + FRAME_OVERHEAD;
        self.bytes_sent += framed;
        self.msgs_sent += 1;
        let flow = self.tag_flows.entry(req.tag()).or_default();
        flow.sent_frames += 1;
        flow.sent_bytes += framed;
        self.transport.send_msg(body)
    }

    fn recv(&mut self) -> io::Result<WireMsg> {
        let reply = self.transport.recv_msg()?;
        let framed = reply.len() as u64 + FRAME_OVERHEAD;
        self.bytes_recv += framed;
        self.msgs_recv += 1;
        if let Some(&tag) = reply.first() {
            *self.reply_tags.entry(tag).or_insert(0) += 1;
            let flow = self.tag_flows.entry(tag).or_default();
            flow.recv_frames += 1;
            flow.recv_bytes += framed;
        }
        Ok(WireMsg::decode(&reply)?)
    }

    /// One request/reply exchange, counting framed bytes both directions.
    fn exchange(&mut self, req: &WireMsg) -> io::Result<WireMsg> {
        self.send(req)?;
        self.recv()
    }

    /// A statistic reply in either form: plaintext (no key installed) or
    /// node-encrypted ciphertexts. After the key install, a plaintext
    /// reply is rejected — the ciphertext-only wire is enforced, not
    /// just observed.
    fn expect_stat_reply(&mut self, req: &WireMsg) -> io::Result<NodeReply> {
        match self.exchange(req)? {
            WireMsg::NodeReply { .. } if self.require_enc => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "node downgraded to a plaintext statistic after the key install",
            )),
            WireMsg::NodeReply { values, loglik, secs } => Ok(NodeReply {
                payload: NodePayload::Plain { values, loglik },
                secs,
                org: self.index,
            }),
            WireMsg::Ciphertexts { scale, secs, cts } => Ok(NodeReply {
                payload: NodePayload::Enc(EncStat { scale, cts }),
                secs,
                org: self.index,
            }),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where a statistic reply was expected"),
            )),
        }
    }

    fn expect_ciphertexts(&mut self) -> io::Result<(EncStat, f64)> {
        match self.recv()? {
            WireMsg::Ciphertexts { scale, secs, cts } => Ok((EncStat { scale, cts }, secs)),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where ciphertexts were expected"),
            )),
        }
    }

    fn expect_ack(&mut self, req: &WireMsg) -> io::Result<()> {
        match self.exchange(req)? {
            WireMsg::Ack => Ok(()),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node sent {other:?} where an acknowledgement was expected"),
            )),
        }
    }

    /// One step round: `StepReq` out, two `Ciphertexts` frames back
    /// (partial step, then log-likelihood).
    fn expect_step_reply(&mut self, req: &WireMsg) -> io::Result<StepReply> {
        self.send(req)?;
        let (part, secs) = self.expect_ciphertexts()?;
        let (loglik, _) = self.expect_ciphertexts()?;
        Ok(StepReply { part, loglik, secs, org: self.index })
    }
}

/// How long `connect` keeps retrying each node address before giving up
/// (covers start-up ordering between node and center processes).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Default per-round socket deadline: generous enough for a
/// 2048-bit-modulus encryption round on slow hardware, small enough
/// that a hung org cannot stall a deployment forever.
pub const DEFAULT_ROUND_TIMEOUT: Duration = Duration::from_secs(120);

/// Budget for one readmission probe: TCP connect + hello + `Ping` +
/// `MetaReq` against a node that may well still be dead. Deliberately
/// small — probing dead nodes happens every round boundary, and must
/// not meaningfully stretch the round. A node that *answers* within
/// this budget then gets the full round deadline for its state
/// re-install (rebuilding Straus tables from `SetKey` is real work).
pub const READMIT_PROBE_TIMEOUT: Duration = Duration::from_millis(500);

/// Fault-tolerance knobs for a [`RemoteFleet`] (config keys
/// `round_timeout` / `quorum` / `connect_timeout`, environment
/// `PRIVLOGIT_ROUND_TIMEOUT`; see docs/DEPLOY.md §Failure behavior).
#[derive(Clone, Copy, Debug)]
pub struct FleetOptions {
    /// Per-round socket deadline applied to every fleet connection: a
    /// read or write stalled this long fails the node's round instead
    /// of blocking the center forever. `None` disables deadlines (the
    /// pre-v4 behavior).
    pub round_timeout: Option<Duration>,
    /// Minimum number of node replies for a round to succeed. `0`
    /// (default) means *every* live node must reply — the strict
    /// all-or-abort behavior. A value `q ≥ 1` lets rounds proceed with
    /// any `q` of the live nodes, excluding the others.
    pub quorum: usize,
    /// How long connect-time retries keep trying each address.
    pub connect_timeout: Duration,
    /// Session epoch announced in the wire handshake and carried on
    /// `SetKey`: `0` for a fresh session; a resuming center advances it
    /// so the node-side replay guard can tell a legitimate resume
    /// re-key from a DJN exponent-stream replay.
    pub epoch: u64,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            round_timeout: Some(DEFAULT_ROUND_TIMEOUT),
            quorum: 0,
            connect_timeout: CONNECT_TIMEOUT,
            epoch: 0,
        }
    }
}

impl FleetOptions {
    /// Defaults with `PRIVLOGIT_ROUND_TIMEOUT` applied (seconds, `f64`;
    /// a non-positive or non-finite value disables deadlines, an
    /// unparsable one is an error naming the variable). Explicit config
    /// keys take precedence over the environment — the CLI builds its
    /// options from config on top of this.
    pub fn from_env() -> anyhow::Result<FleetOptions> {
        FleetOptions::from_round_timeout_var(std::env::var("PRIVLOGIT_ROUND_TIMEOUT").ok())
    }

    /// [`FleetOptions::from_env`] with the variable's value passed in
    /// (`None` = unset) — the parse/validation seam, testable without
    /// mutating process-global environment.
    fn from_round_timeout_var(raw: Option<String>) -> anyhow::Result<FleetOptions> {
        let mut opts = FleetOptions::default();
        if let Some(raw) = raw {
            let secs: f64 = raw.trim().parse().map_err(|_| {
                anyhow::anyhow!(
                    "PRIVLOGIT_ROUND_TIMEOUT={raw:?} is not a round deadline in seconds \
                     (want an f64; non-positive disables deadlines)"
                )
            })?;
            opts.round_timeout = if secs > 0.0 && secs.is_finite() {
                Some(Duration::from_secs_f64(secs))
            } else {
                None
            };
        }
        Ok(opts)
    }
}

/// Record of a node excluded from the fleet after missing a round while
/// the remaining nodes met quorum. The dead connection is dropped — its
/// frame stream may be desynchronized mid-frame, and the node's
/// per-session encryption state cannot be rebuilt without replaying its
/// randomness stream — but exclusion is not permanent: every statistic
/// round boundary probes the node over a fresh connection and readmits
/// it if it answers (see [`ReadmittedNode`]). A record lives here only
/// while the node is *currently* out.
#[derive(Clone, Debug)]
pub struct ExcludedNode {
    /// The node server's address.
    pub addr: String,
    /// 0-based org index at connect time.
    pub org: usize,
    /// Wire tag of the round the node missed.
    pub tag: u8,
    /// Per-tag round index it missed.
    pub round: u64,
    /// Failure class: `"timeout"` (deadline fired) or `"error"`
    /// (disconnect, protocol violation) — same classification the
    /// `fleet.rpc` trace span carries as `outcome`.
    pub outcome: &'static str,
    /// The underlying error text.
    pub error: String,
}

/// Record of a previously-excluded node restored to live membership
/// after answering a round-boundary probe (event history — unlike
/// [`ExcludedNode`] records, these are never removed).
#[derive(Clone, Debug)]
pub struct ReadmittedNode {
    /// The node server's address.
    pub addr: String,
    /// 0-based org index at original connect time (restored on
    /// readmission, so ledger attribution is stable across the outage).
    pub org: usize,
    /// Wire tag of the round the node rejoined for.
    pub tag: u8,
    /// Per-tag round index it rejoined for.
    pub round: u64,
}

/// Classify a node failure for traces and exclusion records: deadline
/// expiries are `"timeout"`, everything else (EOF, CRC, protocol
/// violations) is `"error"`.
fn outcome_of(e: &io::Error) -> &'static str {
    match e.kind() {
        io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => "timeout",
        _ => "error",
    }
}

/// [`Fleet`] implementation over persistent TCP connections to node
/// servers.
pub struct RemoteFleet {
    conns: Vec<NodeConn>,
    n_total: usize,
    p: usize,
    name: String,
    encrypted: bool,
    /// Session id (hash of the installed Paillier modulus; 0 pre-key).
    session: u64,
    /// Per-tag round counters: the Nth broadcast of a tag is round N
    /// within this session. Node servers number the same occurrences
    /// independently, so cross-process traces join on (session, round,
    /// tag) without any wire change.
    round_ctr: BTreeMap<u8, u64>,
    opts: FleetOptions,
    /// Nodes currently out of the live membership (readmission removes
    /// a node's record when it comes back).
    excluded: Vec<ExcludedNode>,
    /// Readmission event history, in readmission order.
    readmitted: Vec<ReadmittedNode>,
    /// The installed Paillier key, kept for readmission re-installs.
    key: Option<FleetKey>,
    /// The installed `Enc(H̃⁻¹)`, kept for readmission re-installs.
    hinv: Option<EncStat>,
}

impl RemoteFleet {
    /// Connect to every node server with default fault-tolerance
    /// options (plus `PRIVLOGIT_ROUND_TIMEOUT` from the environment);
    /// see [`RemoteFleet::connect_with`].
    pub fn connect(addrs: &[String]) -> anyhow::Result<RemoteFleet> {
        RemoteFleet::connect_with(addrs, FleetOptions::from_env()?)
    }

    /// Connect to every node server concurrently, retrying each address
    /// with capped exponential backoff for up to
    /// [`FleetOptions::connect_timeout`], and fetch shard metadata. All
    /// shards must agree on dimensionality. Connect is strict — quorum
    /// applies to *rounds*, so a fleet never starts without every
    /// configured node — and when addresses stay unreachable the error
    /// names all of them, not just the first.
    pub fn connect_with(addrs: &[String], opts: FleetOptions) -> anyhow::Result<RemoteFleet> {
        anyhow::ensure!(!addrs.is_empty(), "remote fleet needs at least one node address");
        anyhow::ensure!(
            opts.quorum <= addrs.len(),
            "quorum {} exceeds the fleet size {}",
            opts.quorum,
            addrs.len()
        );
        let mut sp = obs::span("fleet.round")
            .session(0)
            .tag(wire::TAG_META_REQ)
            .round(0)
            .u64("nodes", addrs.len() as u64);
        let opts_ref = &opts;
        let results: Vec<anyhow::Result<(NodeConn, usize, String)>> = std::thread::scope(|s| {
            let handles: Vec<_> = addrs
                .iter()
                .enumerate()
                .map(|(j, addr)| s.spawn(move || connect_node(j, addr, opts_ref)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow::anyhow!("fleet connect worker panicked")))
                })
                .collect()
        });
        let mut conns = Vec::with_capacity(addrs.len());
        let mut p = 0usize;
        let mut name = String::new();
        let mut failures: Vec<String> = Vec::new();
        for (addr, result) in addrs.iter().zip(results) {
            match result {
                Ok((conn, node_p, node_name)) => {
                    if conns.is_empty() {
                        p = node_p;
                        name = node_name;
                    } else {
                        anyhow::ensure!(
                            node_p == p,
                            "node {addr} serves p={node_p}, fleet expects p={p}"
                        );
                    }
                    conns.push(conn);
                }
                Err(e) => failures.push(e.to_string()),
            }
        }
        if !failures.is_empty() {
            anyhow::bail!(
                "cannot connect the node fleet — {} of {} addresses failed: {}",
                failures.len(),
                addrs.len(),
                failures.join("; ")
            );
        }
        let mut n_total = 0usize;
        for c in &conns {
            n_total = n_total.checked_add(c.node_n).ok_or_else(|| {
                anyhow::anyhow!("fleet sample total overflows adding node {}", c.addr)
            })?;
        }
        if sp.active() {
            sp.record_u64("bytes_sent", conns.iter().map(|c| c.bytes_sent).sum());
            sp.record_u64("bytes_recv", conns.iter().map(|c| c.bytes_recv).sum());
        }
        sp.done();
        Ok(RemoteFleet {
            conns,
            n_total,
            p,
            name,
            encrypted: false,
            session: 0,
            round_ctr: BTreeMap::new(),
            opts,
            excluded: Vec::new(),
            readmitted: Vec::new(),
            key: None,
            hinv: None,
        })
    }

    /// Nodes *currently* excluded from rounds, in exclusion order
    /// (readmission removes a node's record).
    pub fn excluded(&self) -> &[ExcludedNode] {
        &self.excluded
    }

    /// Readmission events so far this session, in readmission order.
    pub fn readmitted(&self) -> &[ReadmittedNode] {
        &self.readmitted
    }

    /// Probe every live node with a [`WireMsg::Ping`] as one traced
    /// round. Nodes that fail to `Ack` within the deadline are excluded
    /// under the same quorum rules as a statistic round; returns the
    /// live connection count after the probe.
    pub fn ping(&mut self) -> anyhow::Result<usize> {
        self.traced_round(wire::TAG_PING, |c| c.expect_ack(&WireMsg::Ping))?;
        Ok(self.conns.len())
    }

    /// The round quorum currently in force: the configured `quorum`, or
    /// the full live membership when unset (strict mode).
    fn effective_quorum(&self) -> usize {
        if self.opts.quorum == 0 {
            self.conns.len()
        } else {
            self.opts.quorum
        }
    }

    /// Next round index for `tag` within this session (counted on both
    /// wire ends independently; see the field doc on `round_ctr`). The
    /// connect-time `MetaReq` exchange is round 0 by construction.
    fn next_round(&mut self, tag: u8) -> u64 {
        let ctr = self.round_ctr.entry(tag).or_insert(0);
        let round = if tag == wire::TAG_META_REQ { *ctr + 1 } else { *ctr };
        *ctr += 1;
        round
    }

    /// Run one broadcast round under a `fleet.round` span carrying the
    /// (session, round, tag) join key, quorum bookkeeping
    /// (`replied`/`quorum`/`excluded`) and framed byte deltas, with one
    /// `fleet.rpc` child span per node measuring request→reply latency
    /// and recording `outcome=ok|timeout|error`.
    ///
    /// Quorum semantics: with every live node replying the round is the
    /// plain barrier it always was. When some fail, the round still
    /// succeeds if at least [`Self::effective_quorum`] replied — the
    /// failed nodes are excluded from the session and `n_total` shrinks
    /// to the live membership — otherwise it fails with an error naming
    /// every failed node.
    fn traced_round<T: Send>(
        &mut self,
        tag: u8,
        per_node: impl Fn(&mut NodeConn) -> io::Result<T> + Sync,
    ) -> anyhow::Result<Vec<T>> {
        // Probe excluded nodes for readmission at statistic round
        // boundaries. Setup/install rounds are skipped: a node
        // readmitted mid-install would receive the same state twice.
        let readmitted_now =
            if matches!(tag, wire::TAG_META_REQ | wire::TAG_SET_KEY | wire::TAG_SET_HINV) {
                0
            } else {
                self.try_readmit(tag)
            };
        let session = self.session;
        let round = self.next_round(tag);
        let quorum = self.effective_quorum();
        let total = self.conns.len();
        let mut sp = obs::span("fleet.round")
            .session(session)
            .tag(tag)
            .round(round)
            .u64("nodes", total as u64)
            .u64("quorum", quorum as u64)
            .u64("readmitted", readmitted_now);
        let before = sp.active().then(|| self.net_stats());
        let results = self.round_with(|c| {
            let mut rpc = obs::span("fleet.rpc")
                .session(session)
                .tag(tag)
                .round(round)
                .str("node", &c.addr);
            let b0 = (c.bytes_sent, c.bytes_recv);
            let r = per_node(c);
            if rpc.active() {
                rpc.record_u64("bytes_sent", c.bytes_sent - b0.0);
                rpc.record_u64("bytes_recv", c.bytes_recv - b0.1);
                rpc.record_str(
                    "outcome",
                    match &r {
                        Ok(_) => "ok",
                        Err(e) => outcome_of(e),
                    },
                );
            }
            r
        });
        let mut ok = Vec::with_capacity(total);
        let mut failed: Vec<(usize, io::Error)> = Vec::new();
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Ok(v) => ok.push(v),
                Err(e) => failed.push((i, e)),
            }
        }
        if let Some(b) = before {
            let after = self.net_stats();
            sp.record_u64("bytes_sent", after.bytes_sent - b.bytes_sent);
            sp.record_u64("bytes_recv", after.bytes_recv - b.bytes_recv);
            sp.record_u64("replied", ok.len() as u64);
            sp.record_u64("excluded", failed.len() as u64);
        }
        sp.done();
        if failed.is_empty() {
            return Ok(ok);
        }
        if ok.len() < quorum {
            let names: Vec<String> = failed
                .iter()
                // audit:allow(panic-free): i enumerates the per-conn results, so i < conns.len()
                .map(|(i, e)| format!("{} ({e})", self.conns[*i].addr))
                .collect();
            anyhow::bail!(
                "node server(s) {} failed mid-protocol; {} of {total} nodes replied, \
                 quorum {quorum} not met",
                names.join(", "),
                ok.len(),
            );
        }
        // Quorum met: exclude the failed nodes for the rest of the
        // session (highest index removed first so the others stay put).
        for &(i, ref e) in &failed {
            // audit:allow(panic-free): i enumerates the per-conn results, so i < conns.len()
            let conn = &self.conns[i];
            obs::warn(format_args!(
                "excluding node server {} after {} round {round}: {e}",
                conn.addr,
                wire::tag_name(tag)
            ));
            self.excluded.push(ExcludedNode {
                addr: conn.addr.clone(),
                org: conn.index,
                tag,
                round,
                outcome: outcome_of(e),
                error: e.to_string(),
            });
        }
        for &(i, _) in failed.iter().rev() {
            drop(self.conns.remove(i));
        }
        self.n_total = self.conns.iter().map(|c| c.node_n).sum();
        Ok(ok)
    }

    /// Probe every currently-excluded node concurrently and readmit the
    /// ones that answer (see the module doc and [`readmit_node`]).
    /// Returns how many rejoined. Failures are silent by design — a
    /// dead node stays excluded and the next round boundary probes it
    /// again — but every probe emits a `fleet.readmit` span with
    /// `outcome=ok|timeout|error`, so the timeline shows the retry
    /// cadence as well as the successful readmission.
    fn try_readmit(&mut self, tag: u8) -> u64 {
        if self.excluded.is_empty() {
            return 0;
        }
        // The round index the readmitted node will first participate
        // in: `next_round` has not run yet for this tag.
        let round = self.round_ctr.get(&tag).copied().unwrap_or(0);
        let session = self.session;
        let opts = self.opts;
        let key = self.key.clone();
        let hinv = self.hinv.clone();
        let p_expect = self.p;
        let candidates: Vec<(usize, String)> =
            self.excluded.iter().map(|x| (x.org, x.addr.clone())).collect();
        let results: Vec<Option<NodeConn>> = std::thread::scope(|s| {
            let (key, hinv, opts) = (key.as_ref(), hinv.as_ref(), &opts);
            let handles: Vec<_> = candidates
                .iter()
                .map(|(org, addr)| {
                    s.spawn(move || {
                        let mut sp = obs::span("fleet.readmit")
                            .session(session)
                            .tag(tag)
                            .round(round)
                            .str("node", addr)
                            .u64("org", *org as u64);
                        let r = readmit_node(*org, addr, opts, key, hinv, p_expect);
                        if sp.active() {
                            sp.record_str(
                                "outcome",
                                match &r {
                                    Ok(_) => "ok",
                                    Err(e) => outcome_of(e),
                                },
                            );
                            if let Ok(c) = &r {
                                sp.record_u64("bytes_sent", c.bytes_sent);
                                sp.record_u64("bytes_recv", c.bytes_recv);
                            }
                        }
                        sp.done();
                        r.ok()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap_or(None)).collect()
        });
        let mut count = 0u64;
        for ((org, addr), conn) in candidates.into_iter().zip(results) {
            let Some(conn) = conn else { continue };
            obs::info(format_args!(
                "readmitting node server {addr} (org {org}) at {} round {round}",
                wire::tag_name(tag)
            ));
            self.excluded.retain(|x| x.org != org);
            self.readmitted.push(ReadmittedNode { addr, org, tag, round });
            // Reinsert in org order so reply attribution stays stable.
            let at = self.conns.iter().position(|c| c.index > org).unwrap_or(self.conns.len());
            self.conns.insert(at, conn);
            count += 1;
        }
        if count > 0 {
            self.n_total = self.conns.iter().map(|c| c.node_n).sum();
        }
        count
    }

    /// Fan one request out to every live node concurrently; per-node
    /// results come back in connection order (quorum policy is applied
    /// by the caller, [`Self::traced_round`]).
    fn round_with<T: Send>(
        &mut self,
        per_node: impl Fn(&mut NodeConn) -> io::Result<T> + Sync,
    ) -> Vec<io::Result<T>> {
        let per_node = &per_node;
        std::thread::scope(|s| {
            let handles: Vec<_> =
                self.conns.iter_mut().map(|c| s.spawn(move || per_node(c))).collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(io::Error::other("node round worker panicked")))
                })
                .collect()
        })
    }

    /// Census of reply tag bytes received from the nodes, merged across
    /// connections (tag byte → count). With node-side encryption
    /// installed, `wire::TAG_NODE_REPLY` must never appear — the
    /// assertion the ciphertext-only integration test makes.
    pub fn reply_tag_counts(&self) -> BTreeMap<u8, u64> {
        let mut out = BTreeMap::new();
        for c in &self.conns {
            for (&tag, &count) in &c.reply_tags {
                *out.entry(tag).or_insert(0) += count;
            }
        }
        out
    }
}

/// Connect one node: retry the address, apply the round deadline, and
/// validate the shard metadata (wire-controlled — bound it before it
/// drives allocations or arithmetic). Returns the connection plus the
/// node's dimensionality and dataset name for cross-node agreement
/// checks.
fn connect_node(
    index: usize,
    addr: &str,
    opts: &FleetOptions,
) -> anyhow::Result<(NodeConn, usize, String)> {
    let mut transport = TcpTransport::connect_retry_at_epoch(
        addr,
        wire::ROLE_CENTER,
        opts.connect_timeout,
        opts.epoch,
    )?;
    transport.set_deadline(opts.round_timeout)?;
    let mut conn = NodeConn::new(index, addr.to_string(), transport);
    let meta = conn.exchange(&WireMsg::MetaReq).map_err(|e| anyhow::anyhow!("node {addr}: {e}"))?;
    match meta {
        WireMsg::Meta { n, p: node_p, name: node_name } => {
            let node_p = node_p as usize;
            anyhow::ensure!(
                node_p >= 1,
                "node {addr} reports a degenerate dimensionality p={node_p}"
            );
            let node_n = usize::try_from(n)
                .map_err(|_| anyhow::anyhow!("node {addr} reports n={n}, beyond this platform"))?;
            anyhow::ensure!(node_n >= 1, "node {addr} reports an empty shard (n=0)");
            conn.node_n = node_n;
            Ok((conn, node_p, node_name))
        }
        other => anyhow::bail!("node {addr} answered MetaReq with {other:?}"),
    }
}

/// Probe one excluded node and rebuild its session state over a fresh
/// connection: connect at the session epoch within
/// [`READMIT_PROBE_TIMEOUT`], `Ping`, re-fetch `Meta` (the node may
/// have restarted — the shard must still agree with the fleet), then
/// re-install the Paillier key and `Enc(H̃⁻¹)` under the round
/// deadline. Any failure leaves the node excluded; the next round
/// boundary probes again.
fn readmit_node(
    org: usize,
    addr: &str,
    opts: &FleetOptions,
    key: Option<&FleetKey>,
    hinv: Option<&EncStat>,
    p_expect: usize,
) -> io::Result<NodeConn> {
    let mut transport = TcpTransport::connect_retry_at_epoch(
        addr,
        wire::ROLE_CENTER,
        READMIT_PROBE_TIMEOUT,
        opts.epoch,
    )?;
    transport.set_deadline(Some(READMIT_PROBE_TIMEOUT))?;
    let mut conn = NodeConn::new(org, addr.to_string(), transport);
    conn.expect_ack(&WireMsg::Ping)?;
    match conn.exchange(&WireMsg::MetaReq)? {
        WireMsg::Meta { n, p, .. } => {
            let node_p = p as usize;
            let node_n = usize::try_from(n).unwrap_or(0);
            if node_p != p_expect || node_n == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "node {addr} came back serving p={node_p}, n={n}; \
                         fleet expects p={p_expect} and a non-empty shard"
                    ),
                ));
            }
            conn.node_n = node_n;
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("node {addr} answered MetaReq with {other:?}"),
            ))
        }
    }
    // The node answered, so it earns the full round deadline for the
    // state re-install (rebuilding Straus tables is real work).
    conn.transport.set_deadline(opts.round_timeout)?;
    if let Some(key) = key {
        let (pack_k, pack_slot_bits, pack_max_parts) = pack_fields(key);
        conn.expect_ack(&WireMsg::SetKey {
            n: key.n.clone(),
            w: key.w,
            f: key.f,
            epoch: opts.epoch,
            pack_k,
            pack_slot_bits,
            pack_max_parts,
        })?;
        conn.require_enc = true;
    }
    if let Some(hinv) = hinv {
        conn.expect_ack(&WireMsg::SetHinv { scale: hinv.scale, cts: hinv.cts.clone() })?;
    }
    Ok(conn)
}

/// The wire v6 `SetKey` packing fields for a fleet key: the negotiated
/// slot layout, or all zeros for the legacy one-value-per-ciphertext
/// sessions (`--no-pack`, or a modulus too small to host two slots).
fn pack_fields(key: &FleetKey) -> (u32, u32, u64) {
    match key.packing {
        Some(p) => (p.k, p.slot_bits, p.max_parts),
        None => (0, 0, 0),
    }
}

impl Fleet for RemoteFleet {
    fn orgs(&self) -> usize {
        self.conns.len()
    }
    fn n_total(&self) -> usize {
        self.n_total
    }
    fn p(&self) -> usize {
        self.p
    }
    fn dataset_name(&self) -> String {
        self.name.clone()
    }

    fn stats(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let req = WireMsg::StatsReq { beta: beta.to_vec(), scale };
        self.traced_round(wire::TAG_STATS_REQ, |c| c.expect_stat_reply(&req))
    }

    fn gram(&mut self, scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let req = WireMsg::GramReq { scale };
        self.traced_round(wire::TAG_GRAM_REQ, |c| c.expect_stat_reply(&req))
    }

    fn hessian(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<NodeReply>> {
        let req = WireMsg::HessReq { beta: beta.to_vec(), scale };
        self.traced_round(wire::TAG_HESS_REQ, |c| c.expect_stat_reply(&req))
    }

    fn label(&self) -> String {
        let mode = if self.encrypted {
            "node-side encryption"
        } else {
            "plaintext statistics"
        };
        format!("remote fleet ({} node servers over tcp; {mode})", self.conns.len())
    }

    fn net_stats(&self) -> FleetNet {
        let mut net = FleetNet::default();
        for c in &self.conns {
            net.bytes_sent += c.bytes_sent;
            net.bytes_recv += c.bytes_recv;
            net.msgs_sent += c.msgs_sent;
            net.msgs_recv += c.msgs_recv;
        }
        net
    }

    fn install_key(&mut self, key: &FleetKey) -> anyhow::Result<bool> {
        // The installed modulus defines the session: adopt the id
        // before the round so the SetKey span already carries it (node
        // servers derive the same id when they process the install).
        self.session = obs::session_id(&key.n.to_bytes_le());
        let (pack_k, pack_slot_bits, pack_max_parts) = pack_fields(key);
        let req = WireMsg::SetKey {
            n: key.n.clone(),
            w: key.w,
            f: key.f,
            epoch: self.opts.epoch,
            pack_k,
            pack_slot_bits,
            pack_max_parts,
        };
        self.traced_round(wire::TAG_SET_KEY, |c| {
            c.expect_ack(&req)?;
            c.require_enc = true;
            Ok(())
        })?;
        self.encrypted = true;
        self.key = Some(key.clone());
        Ok(true)
    }

    fn nodes_encrypt(&self) -> bool {
        self.encrypted
    }

    fn install_hinv(&mut self, hinv: &EncStat) -> anyhow::Result<()> {
        anyhow::ensure!(self.encrypted, "install the Paillier key before Enc(H̃⁻¹)");
        let req = WireMsg::SetHinv { scale: hinv.scale, cts: hinv.cts.clone() };
        self.traced_round(wire::TAG_SET_HINV, |c| c.expect_ack(&req))?;
        self.hinv = Some(hinv.clone());
        Ok(())
    }

    fn step(&mut self, beta: &[f64], scale: f64) -> anyhow::Result<Vec<StepReply>> {
        anyhow::ensure!(self.encrypted, "step rounds need node-side encryption installed");
        let req = WireMsg::StepReq { beta: beta.to_vec(), scale };
        self.traced_round(wire::TAG_STEP_REQ, |c| c.expect_step_reply(&req))
    }

    fn tag_flows(&self) -> BTreeMap<u8, TagFlow> {
        let mut out: BTreeMap<u8, TagFlow> = BTreeMap::new();
        for c in &self.conns {
            for (&tag, flow) in &c.tag_flows {
                out.entry(tag).or_default().merge(flow);
            }
        }
        out
    }

    fn excluded_count(&self) -> u64 {
        self.excluded.len() as u64
    }

    fn readmitted_count(&self) -> u64 {
        self.readmitted.len() as u64
    }

    fn membership(&self) -> (Vec<String>, Vec<String>) {
        (
            self.conns.iter().map(|c| c.addr.clone()).collect(),
            self.excluded.iter().map(|x| x.addr.clone()).collect(),
        )
    }
}

impl Drop for RemoteFleet {
    fn drop(&mut self) {
        // Best-effort: let node servers exit their session loops cleanly
        // (excluded connections were already dropped, which closed them).
        for c in &mut self.conns {
            let _ = c.transport.send_wire(&WireMsg::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_options_env_parsing() {
        // Unset: defaults stand.
        let opts = FleetOptions::from_round_timeout_var(None).unwrap();
        assert_eq!(opts.round_timeout, Some(DEFAULT_ROUND_TIMEOUT));
        assert_eq!(opts.epoch, 0);
        // A positive value becomes the round deadline.
        let opts = FleetOptions::from_round_timeout_var(Some("2.5".into())).unwrap();
        assert_eq!(opts.round_timeout, Some(Duration::from_secs_f64(2.5)));
        // Non-positive and non-finite values disable deadlines.
        for raw in ["0", "-1", "-inf"] {
            let opts = FleetOptions::from_round_timeout_var(Some(raw.into())).unwrap();
            assert_eq!(opts.round_timeout, None, "{raw:?} should disable deadlines");
        }
        // Garbage is an error naming the variable and quoting the value.
        let err = FleetOptions::from_round_timeout_var(Some("2 minutes".into()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("PRIVLOGIT_ROUND_TIMEOUT"), "error should name the variable: {err}");
        assert!(err.contains("2 minutes"), "error should quote the value: {err}");
    }
}
