//! The organization side of the network: a node server owning one data
//! partition and answering the Center's statistic requests.
//!
//! This is the process behind `privlogit node --listen …`. It speaks the
//! [`super::wire`] protocol over TCP: `MetaReq` describes the shard,
//! `StatsReq`/`GramReq`/`HessReq` run the node-local plaintext compute
//! (the same [`crate::optim`] kernels the in-process fleets use) with
//! self-measured wall seconds in every reply, and `Shutdown` (or a
//! center disconnect) ends the session. The listener then accepts the
//! next center connection, so one long-lived node process can serve many
//! experiment runs.
//!
//! **Node-side encryption** (the paper's Figure 1 data flow): when the
//! center opens the session with [`WireMsg::SetKey`], this node builds
//! the Paillier public key from the modulus and from then on encrypts
//! every statistic itself — replies become [`WireMsg::Ciphertexts`] and
//! no plaintext statistic ever crosses the wire. [`WireMsg::SetHinv`]
//! additionally stores the broadcast `Enc(H̃⁻¹)`, enabling the
//! PrivLogit-Local step round ([`WireMsg::StepReq`]): gradient,
//! `Enc(H̃⁻¹)⊗g_j` via [`crate::mpc::fabric::apply_hinv_cts`], and the
//! encrypted log-likelihood share, all computed here at the node.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Instant;

use super::tcp::TcpTransport;
use super::wire::{self, WireMsg};
use super::Transport;
use crate::crypto::fixed::FixedCodec;
use crate::crypto::packed::PackedCodec;
use crate::obs;
use crate::crypto::paillier::{ChaChaSource, Ciphertext, PublicKey};
use crate::crypto::rng::ChaChaRng;
use crate::data::Dataset;
use crate::gc::word::FixedFmt;
use crate::mpc::fabric::PreparedHinv;
use crate::protocols::common::pack_tri;
use crate::runtime::{pool, CpuCompute, NodeCompute};

/// Hook producing the transport a session is served over, given the
/// freshly-handshaken TCP one — the fault-injection harness
/// ([`crate::testutil::faults`]) wraps it so the node misbehaves
/// deterministically without the server knowing.
pub type TransportWrapper = Box<dyn FnMut(Box<dyn Transport>) -> Box<dyn Transport> + Send>;

/// A listening node server bound to one data partition and one compute
/// engine (the same [`NodeCompute`] seam the in-process fleets use, so
/// all three fleet kinds share one implementation of the node math).
pub struct NodeServer {
    listener: TcpListener,
    data: Dataset,
    engine: Box<dyn NodeCompute>,
    seed: u64,
    /// Worker threads for batch encryption and `Enc(H̃⁻¹)⊗g` rows
    /// (default: `PRIVLOGIT_THREADS` / available parallelism). Replies
    /// are bit-identical for any value — randomness is drawn serially.
    threads: usize,
    // Test hooks (None in production): pre-handshake accept gate and
    // per-session transport wrapper.
    accept_gate: Option<Box<dyn FnMut() -> bool + Send>>,
    wrapper: Option<TransportWrapper>,
}

impl NodeServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) with the
    /// deterministic pure-rust engine.
    pub fn bind(addr: &str, data: Dataset) -> io::Result<NodeServer> {
        NodeServer::bind_with_engine(addr, data, Box::new(CpuCompute))
    }

    /// Bind with an explicit engine (e.g. `runtime::default_engine()` to
    /// pick up the PJRT/Pallas artifacts — what `privlogit node` does).
    pub fn bind_with_engine(
        addr: &str,
        data: Dataset,
        engine: Box<dyn NodeCompute>,
    ) -> io::Result<NodeServer> {
        Ok(NodeServer {
            listener: TcpListener::bind(addr)?,
            data,
            engine,
            seed: entropy_seed(),
            threads: pool::threads(),
            accept_gate: None,
            wrapper: None,
        })
    }

    /// Override this node's own randomness seed (Paillier encryption
    /// randomness; give each organization a distinct value).
    pub fn with_seed(mut self, seed: u64) -> NodeServer {
        self.seed = seed;
        self
    }

    /// Override the worker-thread count (tests pin 1 vs N to prove that
    /// parallel replies are byte-identical to single-threaded ones).
    pub fn with_threads(mut self, threads: usize) -> NodeServer {
        self.threads = threads.max(1);
        self
    }

    /// Install an accept gate, called once per accepted connection
    /// *before* the handshake: returning `false` drops the socket
    /// unanswered, so the connecting center sees an EOF during its hello
    /// (a retryable failure) and the server awaits the next connection.
    /// Test hook for "node refuses its first k connects".
    pub fn with_accept_gate(mut self, gate: Box<dyn FnMut() -> bool + Send>) -> NodeServer {
        self.accept_gate = Some(gate);
        self
    }

    /// Install a per-session transport wrapper, applied to every
    /// handshaken connection before serving it. Test hook: the
    /// fault-injection harness ([`crate::testutil::faults`]) uses it to
    /// delay, hang or cut replies deterministically.
    pub fn with_transport_wrapper(mut self, wrapper: TransportWrapper) -> NodeServer {
        self.wrapper = Some(wrapper);
        self
    }

    /// The bound address (useful with ephemeral ports).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept connections until the gate admits one (every connection is
    /// admitted when no gate is installed). Listener errors propagate.
    fn accept_gated(&mut self) -> io::Result<TcpStream> {
        loop {
            let (stream, _) = self.listener.accept()?;
            match self.accept_gate.as_mut() {
                Some(gate) if !gate() => continue, // dropped pre-handshake
                _ => return Ok(stream),
            }
        }
    }

    /// Handshake an admitted stream and apply the transport wrapper.
    /// Also returns the connecting center's claimed session epoch
    /// (wire v5 hello), which seeds the session's re-key guard.
    fn session_transport(&mut self, stream: TcpStream) -> io::Result<(Box<dyn Transport>, u64)> {
        let tcp = TcpTransport::accept(stream, wire::ROLE_NODE)?;
        let epoch = tcp.peer_epoch;
        let t: Box<dyn Transport> = Box::new(tcp);
        let t = match self.wrapper.as_mut() {
            Some(wrap) => wrap(t),
            None => t,
        };
        Ok((t, epoch))
    }

    /// Accept one center connection and serve it to completion.
    pub fn serve_once(&mut self) -> io::Result<()> {
        let stream = self.accept_gated()?;
        let (mut t, epoch) = self.session_transport(stream)?;
        self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let session = serve_session(
            t.as_mut(),
            &self.data,
            self.engine.as_mut(),
            self.seed,
            self.threads,
            epoch,
        );
        // Session boundary: persist buffered trace lines even if this
        // process is killed rather than exiting cleanly afterwards.
        obs::flush();
        session
    }

    /// Serve center connections forever (one at a time). A failed
    /// *session* (center vanished, protocol error) is logged and the
    /// next center is awaited; a failed *accept* means the listener
    /// itself is broken and is propagated.
    pub fn serve_forever(&mut self) -> io::Result<()> {
        loop {
            let stream = self.accept_gated()?;
            self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let seed = self.seed;
            let threads = self.threads;
            let session = match self.session_transport(stream) {
                Ok((mut t, epoch)) => serve_session(
                    t.as_mut(),
                    &self.data,
                    self.engine.as_mut(),
                    seed,
                    threads,
                    epoch,
                ),
                Err(e) => Err(e),
            };
            obs::flush();
            match session {
                Ok(()) => obs::info(format_args!("node session complete")),
                Err(e) => obs::warn(format_args!("node session ended with error: {e}")),
            }
        }
    }
}

/// A distinct-per-process default seed for this node's Paillier
/// encryption randomness. Co-deployed nodes must NOT share a randomness
/// stream: with DJN encryption `c = (1+mn)·hˢ`, two ciphertexts built
/// from the same short exponent `s` reveal the plaintext difference to
/// any wire observer (`c_A·c_B⁻¹ = 1+(m_A−m_B)·n`). Mixes OS entropy
/// (when readable) with the clock and pid; [`NodeServer::with_seed`]
/// overrides it for deterministic tests.
pub(crate) fn entropy_seed() -> u64 {
    use std::io::Read as _;
    let mut seed = 0x9A11u64;
    let mut buf = [0u8; 8];
    let urandom = std::fs::File::open("/dev/urandom")
        .and_then(|mut f| f.read_exact(&mut buf));
    if urandom.is_ok() {
        seed ^= u64::from_le_bytes(buf);
    }
    let clock = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    seed ^ clock.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((std::process::id() as u64) << 32)
}

/// Mix a session epoch into a per-connection randomness seed. Epoch 0
/// (a fresh session) leaves the seed unchanged, so pre-v5 behavior is
/// byte-identical; every strictly larger epoch yields a distinct DJN
/// exponent stream, which is what makes an epoch-advancing re-key safe
/// where a same-seed rebuild would replay randomness. Shared by the
/// node server and the center-b peer server.
pub(crate) fn epoch_seed(seed: u64, epoch: u64) -> u64 {
    seed ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Validate wire-controlled [`WireMsg::SetKey`] material at a trust
/// boundary: the fixed-point format must pass [`FixedFmt::try_new`]
/// (w ≤ 64 so the `u128` share masks cannot overflow) and the modulus
/// must look like a Paillier `n`. Shared by the node server and the
/// center-b peer server so the two boundaries cannot drift apart.
pub(crate) fn validate_set_key(
    n: &crate::bigint::BigUint,
    w: u32,
    f: u32,
) -> io::Result<FixedFmt> {
    let fmt = FixedFmt::try_new(w as usize, f).map_err(|e| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("SetKey carries a bad fixed-point format: {e}"),
        )
    })?;
    if n.bit_len() < 16 || n.is_even() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("SetKey modulus is not a plausible Paillier n ({} bits)", n.bit_len()),
        ));
    }
    Ok(fmt)
}

/// Per-session Paillier state, established by [`WireMsg::SetKey`].
struct SessionCrypto {
    pk: PublicKey,
    codec: FixedCodec,
    fmt: FixedFmt,
    rng: ChaChaRng,
    /// Broadcast `Enc(H̃⁻¹)` (scale, triangle prepared for repeated
    /// Straus application), once installed.
    hinv: Option<(u32, PreparedHinv)>,
    /// Slot-packing layout negotiated by [`WireMsg::SetKey`] (wire v6),
    /// re-validated at this trust boundary. `None` = one value per
    /// ciphertext (legacy / `--no-pack`).
    packing: Option<PackedCodec>,
    /// Worker threads for encryption/apply batches.
    threads: usize,
}

impl SessionCrypto {
    /// Encrypt a statistics vector at the session scale `f` (randomness
    /// drawn serially, modpows fanned across the session workers — the
    /// reply bytes are identical for any thread count). A non-encodable
    /// value (non-finite or out of the format's range) is a session
    /// error, not a node panic.
    fn encrypt_vec(&mut self, vals: &[f64]) -> io::Result<Vec<crate::bigint::BigUint>> {
        let f = self.codec.frac_bits;
        let ms: Vec<crate::bigint::BigUint> = vals
            .iter()
            .map(|&v| {
                self.codec.encode_scaled(v, f).map_err(|e| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("statistic does not encode: {e}"),
                    )
                })
            })
            .collect::<io::Result<_>>()?;
        Ok(self.encrypt_plaintexts(&ms))
    }

    /// Pack a statistics vector into radix-2^b slots (wire v6 layout
    /// from SetKey) and encrypt the packed plaintexts. Callers gate on
    /// `self.packing` being present.
    fn encrypt_packed_vec(
        &mut self,
        codec: &PackedCodec,
        vals: &[f64],
    ) -> io::Result<Vec<crate::bigint::BigUint>> {
        let ms = codec.pack(vals, self.fmt.f).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("statistic does not pack: {e}"),
            )
        })?;
        Ok(self.encrypt_plaintexts(&ms))
    }

    fn encrypt_plaintexts(&mut self, ms: &[crate::bigint::BigUint]) -> Vec<crate::bigint::BigUint> {
        self.pk
            .encrypt_batch(ms, &mut ChaChaSource(&mut self.rng), self.threads)
            .into_iter()
            .map(|ct| ct.0)
            .collect()
    }
}

/// Receive one framed [`WireMsg`] over any message transport.
fn recv_wire(t: &mut dyn Transport) -> io::Result<WireMsg> {
    Ok(WireMsg::decode(&t.recv_msg()?)?)
}

/// Send one framed [`WireMsg`] over any message transport.
fn send_wire(t: &mut dyn Transport, msg: &WireMsg) -> io::Result<()> {
    t.send_msg(msg.encode())
}

/// Answer requests on one established center connection until `Shutdown`
/// or disconnect.
fn serve_session(
    t: &mut dyn Transport,
    data: &Dataset,
    engine: &mut dyn NodeCompute,
    seed: u64,
    threads: usize,
    handshake_epoch: u64,
) -> io::Result<()> {
    let mut crypto: Option<SessionCrypto> = None;
    // The session epoch starts at the connector's handshake claim and
    // advances with every accepted SetKey; a re-key that does not
    // strictly advance it is rejected as a randomness replay.
    let mut session_epoch = handshake_epoch;
    // Trace join keys: the session id adopted at SetKey and this node's
    // own per-tag round numbering (the center numbers the same
    // occurrences independently, so the indices agree).
    let mut session_id = 0u64;
    let mut rounds: std::collections::BTreeMap<u8, u64> = std::collections::BTreeMap::new();
    loop {
        let msg = match recv_wire(t) {
            Ok(m) => m,
            // EOF without Shutdown: center process exited; treat as done.
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e),
        };
        let tag = msg.tag();
        let round = {
            let c = rounds.entry(tag).or_insert(0);
            let r = *c;
            *c += 1;
            r
        };
        let mut sp = obs::span("node.req").tag(tag).round(round);
        if tag != wire::TAG_SET_KEY {
            sp.record_session(session_id);
        }
        let reply = match msg {
            WireMsg::MetaReq => WireMsg::Meta {
                n: data.n() as u64,
                p: u32::try_from(data.p()).map_err(|_| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("shard dimensionality {} exceeds the wire's u32 range", data.p()),
                    )
                })?,
                name: data.name.split('#').next().unwrap_or("?").to_string(),
            },
            WireMsg::SetKey { n, w, f, epoch, pack_k, pack_slot_bits, pack_max_parts } => {
                // A second SetKey on one session would rebuild
                // SessionCrypto with the same per-session seed and
                // replay the identical DJN exponent stream — with
                // `c = (1+mn)·hˢ`, two ciphertexts on one exponent
                // reveal the plaintext difference to any wire observer.
                // The one legitimate re-key is a center resuming from a
                // checkpoint under a strictly larger session epoch
                // (wire v5): the epoch is mixed into the randomness
                // seed, so the new stream never overlaps the old one.
                if crypto.is_some() && epoch <= session_epoch {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!(
                            "center sent a second SetKey in one session; re-keying mid-session \
                             would replay this node's encryption-randomness stream \
                             (epoch {epoch} does not advance past {session_epoch})"
                        ),
                    ));
                }
                // Wire-controlled format and modulus: validate at the
                // trust boundary so a bad value is a session error, not
                // an overflow inside the share arithmetic.
                let fmt = validate_set_key(&n, w, f)?;
                // Packing layout (wire v6) is wire-controlled: re-derive
                // it through the full headroom validation rather than
                // trusting the center's arithmetic, so a hostile or
                // buggy layout is a session error here, never a silent
                // slot wrap in our statistic replies. `pack_k = 0`
                // keeps the legacy one-value-per-ciphertext path.
                let packing = if pack_k > 0 {
                    Some(
                        PackedCodec::from_wire(
                            n.bit_len() as u32,
                            fmt,
                            pack_k,
                            pack_slot_bits,
                            pack_max_parts,
                        )
                        .map_err(|e| {
                            io::Error::new(
                                io::ErrorKind::InvalidData,
                                format!("SetKey claims a bad packed layout: {e}"),
                            )
                        })?,
                    )
                } else {
                    None
                };
                session_id = obs::session_id(&n.to_bytes_le());
                sp.record_session(session_id);
                sp.record_u64("epoch", epoch);
                session_epoch = epoch;
                let n2 = n.mul(&n);
                crypto = Some(SessionCrypto {
                    pk: PublicKey::from_modulus(n.clone(), n2),
                    codec: FixedCodec::new(n, f),
                    fmt,
                    rng: ChaChaRng::from_u64_seed(epoch_seed(seed, epoch)),
                    hinv: None,
                    packing,
                    threads,
                });
                WireMsg::Ack
            }
            WireMsg::SetHinv { scale, cts } => match crypto.as_mut() {
                Some(c) => {
                    // Wire-controlled data: validate here so a malformed
                    // broadcast is a session error, not a node panic
                    // inside `apply_hinv_cts`'s assertions.
                    let need = crate::mpc::tri_len(data.p());
                    if cts.len() != need {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!(
                                "Enc(H̃⁻¹) broadcast has {} ciphertexts, p={} needs {need}",
                                cts.len(),
                                data.p()
                            ),
                        ));
                    }
                    // Every entry must be a unit of Z_{n²}: StepReq's
                    // multi-exp inverts entries paired with negative
                    // gradient coefficients, and a non-invertible value
                    // must be a session error here, not a worker panic
                    // there. (Honest ciphertexts are units by
                    // construction; this only rejects corrupt peers.)
                    if let Some(bad) = cts.iter().position(|ct| !ct.gcd(&c.pk.n2).is_one()) {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("Enc(H̃⁻¹) ciphertext {bad} is not invertible mod n²"),
                        ));
                    }
                    // Prepare once: Montgomery-resident triangle + Straus
                    // tables, reused by every StepReq of the session.
                    let cts: Vec<Ciphertext> = cts.into_iter().map(Ciphertext).collect();
                    let prepared = PreparedHinv::prepare(&c.pk, data.p(), &cts, c.threads);
                    c.hinv = Some((scale, prepared));
                    WireMsg::Ack
                }
                None => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "center sent Enc(H̃⁻¹) before the Paillier key",
                    ))
                }
            },
            WireMsg::StatsReq { beta, scale } => {
                let t0 = Instant::now();
                let (grad, loglik) = engine.stats(data, &beta, scale);
                match crypto.as_mut() {
                    Some(c) => {
                        // Gradient ciphertexts (slot-packed when the
                        // session negotiated a layout), encrypted loglik
                        // share last — always its own unpacked
                        // ciphertext, since the center folds logliks on
                        // a different fan-in path than the gradient.
                        let mut cts = match c.packing {
                            Some(codec) => c.encrypt_packed_vec(&codec, &grad)?,
                            None => c.encrypt_vec(&grad)?,
                        };
                        cts.extend(c.encrypt_vec(&[loglik])?);
                        WireMsg::Ciphertexts {
                            scale: c.fmt.f,
                            secs: t0.elapsed().as_secs_f64(),
                            cts,
                        }
                    }
                    None => WireMsg::NodeReply {
                        values: grad,
                        loglik,
                        secs: t0.elapsed().as_secs_f64(),
                    },
                }
            }
            WireMsg::GramReq { scale } => {
                let t0 = Instant::now();
                let h = engine.gram_quarter(data, scale);
                matrix_reply(pack_tri(&h), t0, crypto.as_mut())?
            }
            WireMsg::HessReq { beta, scale } => {
                let t0 = Instant::now();
                let h = engine.hessian(data, &beta, scale);
                matrix_reply(pack_tri(&h), t0, crypto.as_mut())?
            }
            WireMsg::StepReq { beta, scale } => {
                let t0 = Instant::now();
                let Some(c) = crypto.as_mut() else {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "center sent StepReq before the Paillier key",
                    ));
                };
                // Validate the ordering *before* the (expensive) full
                // statistics pass over the partition.
                if c.hinv.is_none() {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "center sent StepReq before Enc(H̃⁻¹)",
                    ));
                }
                let (grad, loglik) = engine.stats(data, &beta, scale);
                let (hinv_scale, part) = match c.hinv.as_ref() {
                    Some((s, prepared)) => (*s, prepared.apply(c.fmt, &grad, c.threads).0),
                    None => {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "center sent StepReq before Enc(H̃⁻¹)",
                        ))
                    }
                };
                let loglik_cts = c.encrypt_vec(&[loglik])?;
                let secs = t0.elapsed().as_secs_f64();
                // Two frames: the partial step (the broadcast's scale
                // plus f from the multiply-by-constant), then the
                // encrypted log-likelihood share (scale f).
                send_wire(
                    t,
                    &WireMsg::Ciphertexts {
                        scale: hinv_scale + c.fmt.f,
                        secs,
                        cts: part.into_iter().map(|ct| ct.0).collect(),
                    },
                )?;
                send_wire(
                    t,
                    &WireMsg::Ciphertexts { scale: c.fmt.f, secs: 0.0, cts: loglik_cts },
                )?;
                continue;
            }
            // Liveness probe: acknowledge without touching session state.
            WireMsg::Ping => WireMsg::Ack,
            WireMsg::Shutdown => return Ok(()),
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("center sent {other:?}, which a node does not serve"),
                ))
            }
        };
        send_wire(t, &reply)?;
        sp.done();
    }
}

/// Package a packed-triangle statistic as the session's reply form
/// (slot-packed into ⌈tri_len/k⌉ ciphertexts when the session
/// negotiated a packing layout).
fn matrix_reply(
    tri: Vec<f64>,
    t0: Instant,
    crypto: Option<&mut SessionCrypto>,
) -> io::Result<WireMsg> {
    Ok(match crypto {
        Some(c) => {
            let cts = match c.packing {
                Some(codec) => c.encrypt_packed_vec(&codec, &tri)?,
                None => c.encrypt_vec(&tri)?,
            };
            WireMsg::Ciphertexts { scale: c.fmt.f, secs: t0.elapsed().as_secs_f64(), cts }
        }
        None => WireMsg::NodeReply { values: tri, loglik: 0.0, secs: t0.elapsed().as_secs_f64() },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::{Fleet, LocalFleet};
    use crate::data::synthesize;
    use crate::net::RemoteFleet;
    use crate::runtime::CpuCompute;
    use crate::testutil::assert_all_close;

    /// Spawn one serving thread per partition; return the addresses.
    fn spawn_servers(parts: Vec<Dataset>) -> Vec<String> {
        parts
            .into_iter()
            .map(|d| {
                let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap();
                let addr = server.local_addr().unwrap().to_string();
                std::thread::spawn(move || server.serve_once().unwrap());
                addr
            })
            .collect()
    }

    /// RemoteFleet over real loopback sockets returns bit-identical
    /// statistics to LocalFleet on the same partitions (no key installed
    /// → plaintext replies), and measures traffic in both directions.
    #[test]
    fn remote_fleet_matches_local_fleet() {
        let d = synthesize("t", 900, 5, 41);
        let parts = d.partition(3);
        let addrs = spawn_servers(parts.clone());
        let mut local = LocalFleet::new(parts, Box::new(CpuCompute));
        let mut remote = RemoteFleet::connect(&addrs).unwrap();

        assert_eq!(remote.orgs(), 3);
        assert_eq!(remote.n_total(), 900);
        assert_eq!(remote.p(), 5);
        assert_eq!(remote.dataset_name(), "t");
        assert!(!remote.nodes_encrypt());

        let beta = vec![0.1, -0.2, 0.3, 0.0, 0.05];
        let scale = 1.0 / 900.0;
        let a = local.stats(&beta, scale).unwrap();
        let b = remote.stats(&beta, scale).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_all_close(x.values(), y.values(), 0.0, "stats parity over tcp");
            assert_eq!(x.loglik().to_bits(), y.loglik().to_bits(), "bit-exact loglik");
        }
        let ga = local.gram(scale).unwrap();
        let gb = remote.gram(scale).unwrap();
        for (x, y) in ga.iter().zip(&gb) {
            assert_all_close(x.values(), y.values(), 0.0, "gram parity over tcp");
        }
        let ha = local.hessian(&beta, scale).unwrap();
        let hb = remote.hessian(&beta, scale).unwrap();
        for (x, y) in ha.iter().zip(&hb) {
            assert_all_close(x.values(), y.values(), 0.0, "hessian parity over tcp");
        }

        let net = remote.net_stats();
        assert!(net.bytes_sent > 0, "requests crossed the wire");
        assert!(net.bytes_recv > net.bytes_sent, "replies outweigh requests");
        // connect meta + 3 rounds, 3 nodes each
        assert_eq!(net.msgs_sent, net.msgs_recv);
        assert_eq!(net.msgs_sent, 3 + 3 * 3);
        // All replies were plaintext statistics (or metadata).
        let tags = remote.reply_tag_counts();
        assert_eq!(tags.get(&wire::TAG_NODE_REPLY), Some(&9));
        assert_eq!(tags.get(&wire::TAG_CIPHERTEXTS), None);
        drop(remote); // sends Shutdown; server threads exit
    }

    /// A second `SetKey` on one session is rejected: rebuilding the
    /// session crypto from the same per-session seed would replay the
    /// node's DJN exponent stream (Paillier randomness reuse).
    #[test]
    fn repeated_set_key_is_session_error() {
        use crate::coordinator::fleet::FleetKey;
        let mut rng = crate::crypto::rng::ChaChaRng::from_u64_seed(21);
        let kp = crate::crypto::paillier::Keypair::generate(256, &mut rng);
        let d = synthesize("rekey", 60, 3, 2);
        let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap().with_seed(5);
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve_once());
        let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
        let key = FleetKey { n: kp.pk.n.clone(), w: 40, f: 24, packing: None };
        fleet.install_key(&key).unwrap();
        let second = fleet.install_key(&key);
        assert!(second.is_err(), "second SetKey must fail the round");
        drop(fleet);
        let session = handle.join().expect("node thread must not panic");
        let err = session.expect_err("session must surface the re-key error");
        assert!(err.to_string().contains("second SetKey"), "got: {err}");
    }

    /// A re-key under a strictly advancing session epoch (wire v5, a
    /// center resuming from a checkpoint) is accepted and yields a
    /// fresh encryption-randomness stream; a re-key that does not
    /// advance the epoch stays a session error (the PR 4 replay guard).
    #[test]
    fn rekey_with_advancing_epoch_is_accepted_same_epoch_rejected() {
        use crate::net::TcpTransport;
        let mut rng = crate::crypto::rng::ChaChaRng::from_u64_seed(23);
        let kp = crate::crypto::paillier::Keypair::generate(256, &mut rng);
        let d = synthesize("epoch", 60, 3, 4);
        let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap().with_seed(7);
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve_once());

        let mut t = TcpTransport::connect_at_epoch(&addr, wire::ROLE_CENTER, 1).unwrap();
        let set_key = |epoch: u64| WireMsg::SetKey {
            n: kp.pk.n.clone(),
            w: 40,
            f: 24,
            epoch,
            pack_k: 0,
            pack_slot_bits: 0,
            pack_max_parts: 0,
        };
        let exchange = |t: &mut TcpTransport, msg: &WireMsg| -> io::Result<WireMsg> {
            t.send_msg(msg.encode())?;
            Ok(WireMsg::decode(&t.recv_msg()?)?)
        };
        // First install at the handshake epoch.
        assert!(matches!(exchange(&mut t, &set_key(1)).unwrap(), WireMsg::Ack));
        let stats = WireMsg::StatsReq { beta: vec![0.0; 3], scale: 1.0 / 60.0 };
        let WireMsg::Ciphertexts { cts: cts_epoch1, .. } = exchange(&mut t, &stats).unwrap()
        else {
            panic!("keyed node must reply with ciphertexts");
        };
        // Re-key under an advancing epoch: accepted, and the identical
        // request now encrypts under a different randomness stream.
        assert!(matches!(exchange(&mut t, &set_key(2)).unwrap(), WireMsg::Ack));
        let WireMsg::Ciphertexts { cts: cts_epoch2, .. } = exchange(&mut t, &stats).unwrap()
        else {
            panic!("re-keyed node must reply with ciphertexts");
        };
        assert_ne!(
            cts_epoch1, cts_epoch2,
            "epoch re-key must rotate the DJN exponent stream"
        );
        // A repeated install at the same epoch is the replay case.
        let replay = exchange(&mut t, &set_key(2));
        assert!(replay.is_err(), "non-advancing re-key must fail the session");
        drop(t);
        let err = handle
            .join()
            .expect("node thread must not panic")
            .expect_err("session must surface the replay error");
        assert!(err.to_string().contains("second SetKey"), "got: {err}");
        assert!(err.to_string().contains("does not advance"), "got: {err}");
    }

    /// A `SetKey` carrying an out-of-range fixed-point format (w = 128
    /// would overflow the u128 share masks) or an implausible modulus is
    /// rejected at the trust boundary.
    #[test]
    fn set_key_validates_format_and_modulus() {
        use crate::bigint::BigUint;
        use crate::coordinator::fleet::FleetKey;
        let mut rng = crate::crypto::rng::ChaChaRng::from_u64_seed(22);
        let kp = crate::crypto::paillier::Keypair::generate(256, &mut rng);
        for (key, what) in [
            (FleetKey { n: kp.pk.n.clone(), w: 128, f: 24, packing: None }, "width 128"),
            (FleetKey { n: kp.pk.n.clone(), w: 40, f: 40, packing: None }, "f = w"),
            (FleetKey { n: BigUint::from_u64(77), w: 40, f: 24, packing: None }, "tiny modulus"),
        ] {
            let d = synthesize("badkey", 60, 3, 3);
            let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap().with_seed(6);
            let addr = server.local_addr().unwrap().to_string();
            let handle = std::thread::spawn(move || server.serve_once());
            let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
            assert!(fleet.install_key(&key).is_err(), "{what} must be rejected");
            drop(fleet);
            let session = handle.join().expect("node thread must not panic");
            assert!(session.is_err(), "{what}: session must end with the error");
        }
    }

    /// A node answers metadata for a workload-named shard without the
    /// partition suffix.
    #[test]
    fn node_meta_strips_partition_suffix() {
        let mut d = synthesize("Wine", 60, 3, 1);
        d.name = "Wine#2".to_string();
        let addrs = spawn_servers(vec![d]);
        let remote = RemoteFleet::connect(&addrs).unwrap();
        assert_eq!(remote.dataset_name(), "Wine");
        assert_eq!(remote.n_total(), 60);
    }
}
