//! The organization side of the network: a node server owning one data
//! partition and answering the Center's statistic requests.
//!
//! This is the process behind `privlogit node --listen …`. It speaks the
//! [`super::wire`] protocol over TCP: `MetaReq` describes the shard,
//! `StatsReq`/`GramReq`/`HessReq` run the node-local plaintext compute
//! (the same [`crate::optim`] kernels the in-process fleets use) with
//! self-measured wall seconds in every reply, and `Shutdown` (or a
//! center disconnect) ends the session. The listener then accepts the
//! next center connection, so one long-lived node process can serve many
//! experiment runs.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::time::Instant;

use super::tcp::TcpTransport;
use super::wire::{self, WireMsg};
use crate::data::Dataset;
use crate::protocols::common::pack_tri;
use crate::runtime::{CpuCompute, NodeCompute};

/// A listening node server bound to one data partition and one compute
/// engine (the same [`NodeCompute`] seam the in-process fleets use, so
/// all three fleet kinds share one implementation of the node math).
pub struct NodeServer {
    listener: TcpListener,
    data: Dataset,
    engine: Box<dyn NodeCompute>,
}

impl NodeServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) with the
    /// deterministic pure-rust engine.
    pub fn bind(addr: &str, data: Dataset) -> io::Result<NodeServer> {
        NodeServer::bind_with_engine(addr, data, Box::new(CpuCompute))
    }

    /// Bind with an explicit engine (e.g. `runtime::default_engine()` to
    /// pick up the PJRT/Pallas artifacts — what `privlogit node` does).
    pub fn bind_with_engine(
        addr: &str,
        data: Dataset,
        engine: Box<dyn NodeCompute>,
    ) -> io::Result<NodeServer> {
        Ok(NodeServer { listener: TcpListener::bind(addr)?, data, engine })
    }

    /// The bound address (useful with ephemeral ports).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept one center connection and serve it to completion.
    pub fn serve_once(&mut self) -> io::Result<()> {
        let (stream, _) = self.listener.accept()?;
        let mut t = TcpTransport::accept(stream, wire::ROLE_NODE)?;
        serve_session(&mut t, &self.data, self.engine.as_mut())
    }

    /// Serve center connections forever (one at a time). A failed
    /// *session* (center vanished, protocol error) is logged and the
    /// next center is awaited; a failed *accept* means the listener
    /// itself is broken and is propagated.
    pub fn serve_forever(&mut self) -> io::Result<()> {
        loop {
            let (stream, _) = self.listener.accept()?;
            let session = TcpTransport::accept(stream, wire::ROLE_NODE)
                .and_then(|mut t| serve_session(&mut t, &self.data, self.engine.as_mut()));
            if let Err(e) = session {
                eprintln!("node session ended with error: {e}");
            }
        }
    }
}

/// Answer requests on one established center connection until `Shutdown`
/// or disconnect.
fn serve_session(
    t: &mut TcpTransport,
    data: &Dataset,
    engine: &mut dyn NodeCompute,
) -> io::Result<()> {
    loop {
        let msg = match t.recv_wire() {
            Ok(m) => m,
            // EOF without Shutdown: center process exited; treat as done.
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e),
        };
        let reply = match msg {
            WireMsg::MetaReq => WireMsg::Meta {
                n: data.n() as u64,
                p: data.p() as u32,
                name: data.name.split('#').next().unwrap_or("?").to_string(),
            },
            WireMsg::StatsReq { beta, scale } => {
                let t0 = Instant::now();
                let (grad, loglik) = engine.stats(data, &beta, scale);
                WireMsg::NodeReply { values: grad, loglik, secs: t0.elapsed().as_secs_f64() }
            }
            WireMsg::GramReq { scale } => {
                let t0 = Instant::now();
                let h = engine.gram_quarter(data, scale);
                WireMsg::NodeReply {
                    values: pack_tri(&h),
                    loglik: 0.0,
                    secs: t0.elapsed().as_secs_f64(),
                }
            }
            WireMsg::HessReq { beta, scale } => {
                let t0 = Instant::now();
                let h = engine.hessian(data, &beta, scale);
                WireMsg::NodeReply {
                    values: pack_tri(&h),
                    loglik: 0.0,
                    secs: t0.elapsed().as_secs_f64(),
                }
            }
            WireMsg::Shutdown => return Ok(()),
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("center sent {other:?}, which a node does not serve"),
                ))
            }
        };
        t.send_wire(&reply)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::{Fleet, LocalFleet};
    use crate::data::synthesize;
    use crate::net::RemoteFleet;
    use crate::runtime::CpuCompute;
    use crate::testutil::assert_all_close;

    /// Spawn one serving thread per partition; return the addresses.
    fn spawn_servers(parts: Vec<Dataset>) -> Vec<String> {
        parts
            .into_iter()
            .map(|d| {
                let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap();
                let addr = server.local_addr().unwrap().to_string();
                std::thread::spawn(move || server.serve_once().unwrap());
                addr
            })
            .collect()
    }

    /// RemoteFleet over real loopback sockets returns bit-identical
    /// statistics to LocalFleet on the same partitions, and measures
    /// traffic in both directions.
    #[test]
    fn remote_fleet_matches_local_fleet() {
        let d = synthesize("t", 900, 5, 41);
        let parts = d.partition(3);
        let addrs = spawn_servers(parts.clone());
        let mut local = LocalFleet::new(parts, Box::new(CpuCompute));
        let mut remote = RemoteFleet::connect(&addrs).unwrap();

        assert_eq!(remote.orgs(), 3);
        assert_eq!(remote.n_total(), 900);
        assert_eq!(remote.p(), 5);
        assert_eq!(remote.dataset_name(), "t");

        let beta = vec![0.1, -0.2, 0.3, 0.0, 0.05];
        let scale = 1.0 / 900.0;
        let a = local.stats(&beta, scale);
        let b = remote.stats(&beta, scale);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_all_close(&x.values, &y.values, 0.0, "stats parity over tcp");
            assert_eq!(x.loglik.to_bits(), y.loglik.to_bits(), "bit-exact loglik");
        }
        let ga = local.gram(scale);
        let gb = remote.gram(scale);
        for (x, y) in ga.iter().zip(&gb) {
            assert_all_close(&x.values, &y.values, 0.0, "gram parity over tcp");
        }
        let ha = local.hessian(&beta, scale);
        let hb = remote.hessian(&beta, scale);
        for (x, y) in ha.iter().zip(&hb) {
            assert_all_close(&x.values, &y.values, 0.0, "hessian parity over tcp");
        }

        let net = remote.net_stats();
        assert!(net.bytes_sent > 0, "requests crossed the wire");
        assert!(net.bytes_recv > net.bytes_sent, "replies outweigh requests");
        // connect meta + 3 rounds, 3 nodes each
        assert_eq!(net.msgs_sent, net.msgs_recv);
        assert_eq!(net.msgs_sent, 3 + 3 * 3);
        drop(remote); // sends Shutdown; server threads exit
    }

    /// A node answers metadata for a workload-named shard without the
    /// partition suffix.
    #[test]
    fn node_meta_strips_partition_suffix() {
        let mut d = synthesize("Wine", 60, 3, 1);
        d.name = "Wine#2".to_string();
        let addrs = spawn_servers(vec![d]);
        let remote = RemoteFleet::connect(&addrs).unwrap();
        assert_eq!(remote.dataset_name(), "Wine");
        assert_eq!(remote.n_total(), 60);
    }
}
