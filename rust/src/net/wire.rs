//! The versioned binary wire format.
//!
//! Three layers, bottom-up:
//!
//! 1. **Handshake** — on connect, both sides exchange a 16-byte hello
//!    (`MAGIC` + protocol [`VERSION`] + role byte + session epoch).
//!    Anything else on the socket is rejected before a single payload
//!    byte is parsed.
//! 2. **Frames** — every message travels as
//!    `[u32 LE payload length][payload][u32 LE CRC-32 of payload]`.
//!    Length is bounded by [`MAX_FRAME`]; the CRC catches corruption and
//!    framing bugs loudly instead of desynchronizing the stream.
//! 3. **Messages** — [`WireMsg`]: a tagged codec for every payload that
//!    crosses a process boundary — fleet statistic requests/replies,
//!    bigints, Paillier ciphertext vectors, garbled-table and OT blobs.
//!    Decoding rejects unknown tags, truncated bodies and trailing bytes
//!    with descriptive [`WireError`]s.
//!
//! All integers are little-endian; `f64`s travel as their IEEE-754 bit
//! pattern (`to_bits`), so encode→decode is the identity on every value
//! including NaNs and signed zeros.

use std::fmt;
use std::io::{Read, Write};
use std::sync::OnceLock;

use crate::bigint::BigUint;

/// Wire magic: first bytes of every connection.
pub const MAGIC: [u8; 4] = *b"PLGT";

/// Wire protocol version. Bump on any incompatible format change.
///
/// v2: node-side encryption ([`WireMsg::SetKey`], [`WireMsg::SetHinv`],
/// [`WireMsg::StepReq`], [`WireMsg::Ack`]), node compute seconds on
/// [`WireMsg::Ciphertexts`], and the center-peer GC control messages
/// ([`WireMsg::GcExec`], [`WireMsg::GcOut`]).
///
/// v3: split share custody — center-b (S2) aggregates and blinds itself
/// ([`WireMsg::Aggregate`], [`WireMsg::Blind`], [`WireMsg::ShareInput`])
/// and [`WireMsg::GcExec`] now references S2-held share *handles* plus
/// an output mode instead of shipping evaluator input bits.
///
/// v4: fleet fault tolerance — the [`WireMsg::Ping`] liveness probe
/// (answered by a bare [`WireMsg::Ack`]), used by the center to check a
/// node's health without advancing any protocol state.
///
/// v5: durable sessions — the hello widens from 8 to 16 bytes to carry
/// a `u64` **session epoch**, and [`WireMsg::SetKey`] carries the same
/// epoch. A fresh session starts at epoch 0; a center resuming from a
/// checkpoint re-keys under a strictly larger epoch, which is how the
/// node-side replay guard distinguishes a legitimate resume re-key
/// (new epoch ⇒ new DJN exponent stream) from a randomness-replaying
/// repeat of the same `SetKey`.
///
/// v6: ciphertext packing — [`WireMsg::SetKey`] negotiates the slot
/// layout (`pack_k`/`pack_slot_bits`/`pack_max_parts`; `pack_k = 0`
/// keeps the session unpacked, so packed centers and `--no-pack` nodes
/// interoperate), and [`WireMsg::Blind`] describes its own payload's
/// packing (`packed_parts = 0` = unpacked) so the S2 share conversion
/// needs no session-level packing state.
pub const VERSION: u16 = 6;

/// Hard cap on a single frame's payload (1 GiB): a corrupt or hostile
/// length prefix must not drive allocation.
pub const MAX_FRAME: usize = 1 << 30;

/// Handshake role byte: the coordinating Center.
pub const ROLE_CENTER: u8 = b'C';
/// Handshake role byte: an organization's node server.
pub const ROLE_NODE: u8 = b'N';
/// Handshake role byte: the second Center server (GC peer link).
pub const ROLE_PEER: u8 = b'P';

/// Everything that can go wrong decoding wire data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Body ended before a field was complete.
    Truncated {
        /// Bytes the field needed.
        needed: usize,
        /// Bytes actually available.
        have: usize,
    },
    /// Decoding finished with unconsumed bytes.
    TrailingBytes {
        /// Leftover byte count.
        extra: usize,
    },
    /// The connection did not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// The peer speaks a different wire version.
    VersionMismatch {
        /// Peer's version.
        got: u16,
        /// Our version.
        want: u16,
    },
    /// Frame checksum mismatch (corruption or desync).
    BadCrc {
        /// Checksum computed over the received payload.
        got: u32,
        /// Checksum carried by the frame.
        want: u32,
    },
    /// Unrecognized message tag.
    UnknownTag(u8),
    /// A string field was not valid UTF-8.
    BadUtf8,
    /// Frame length prefix exceeds [`MAX_FRAME`].
    FrameTooLarge {
        /// Claimed payload length.
        len: usize,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "truncated wire data: field needs {needed} bytes, {have} available")
            }
            WireError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after message body")
            }
            WireError::BadMagic(m) => write!(f, "bad wire magic {m:02x?} (expected \"PLGT\")"),
            WireError::VersionMismatch { got, want } => {
                write!(f, "wire version mismatch: peer speaks v{got}, this build speaks v{want}")
            }
            WireError::BadCrc { got, want } => {
                write!(f, "frame CRC mismatch: computed {got:#010x}, frame carries {want:#010x}")
            }
            WireError::UnknownTag(t) => write!(f, "unknown wire message tag {t:#04x}"),
            WireError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
            WireError::FrameTooLarge { len } => {
                write!(f, "frame payload of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for std::io::Error {
    fn from(e: WireError) -> Self {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e)
    }
}

// ======================================================================
// CRC-32 (IEEE 802.3, reflected)
// ======================================================================

// audit:allow(panic-free): indices are the loop counter 0..256 over a [u32; 256]
fn crc32_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        let mut i = 0u32;
        while i < 256 {
            let mut c = i;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i as usize] = c;
            i += 1;
        }
        t
    })
}

/// CRC-32 (IEEE) of `data`.
// audit:allow(panic-free): index is masked to 0xFF over the 256-entry table
pub fn crc32(data: &[u8]) -> u32 {
    let t = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ======================================================================
// Handshake
// ======================================================================

/// Size of the hello exchanged on connect (v5: widened to carry the
/// session epoch).
pub const HELLO_LEN: usize = 16;

/// Build the 16-byte hello: magic, version, role, reserved zero byte,
/// and the sender's session epoch (`u64` LE — 0 for a fresh session,
/// strictly larger after each crash-resume re-key).
// audit:allow(panic-free): send path building a fixed [u8; 16] from fixed-size pieces
pub fn hello(role: u8, epoch: u64) -> [u8; HELLO_LEN] {
    let v = VERSION.to_le_bytes();
    let e = epoch.to_le_bytes();
    [
        MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], v[0], v[1], role, 0, e[0], e[1], e[2], e[3],
        e[4], e[5], e[6], e[7],
    ]
}

/// Validate a peer hello; returns the peer's role byte and session
/// epoch.
// audit:allow(panic-free): input is &[u8; HELLO_LEN]; every index is in range by type
pub fn check_hello(buf: &[u8; HELLO_LEN]) -> Result<(u8, u64), WireError> {
    if buf[..4] != MAGIC {
        return Err(WireError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
    }
    let got = u16::from_le_bytes([buf[4], buf[5]]);
    if got != VERSION {
        return Err(WireError::VersionMismatch { got, want: VERSION });
    }
    let epoch = u64::from_le_bytes([
        buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
    ]);
    Ok((buf[6], epoch))
}

// ======================================================================
// Frames
// ======================================================================

/// Write one frame (`len ‖ payload ‖ crc`) to `w` and flush it.
// audit:allow(panic-free): send-path invariant — local callers frame at most MAX_FRAME
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> std::io::Result<()> {
    assert!(payload.len() <= MAX_FRAME, "frame payload over MAX_FRAME");
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.flush()
}

/// Read one frame from `r`, verifying length bound and CRC.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Vec<u8>> {
    let mut lb = [0u8; 4];
    r.read_exact(&mut lb)?;
    let len = u32::from_le_bytes(lb) as usize;
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge { len }.into());
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut cb = [0u8; 4];
    r.read_exact(&mut cb)?;
    let want = u32::from_le_bytes(cb);
    let got = crc32(&payload);
    if got != want {
        return Err(WireError::BadCrc { got, want }.into());
    }
    Ok(payload)
}

// ======================================================================
// Primitive codecs
// ======================================================================

/// Append-only encoder for message bodies.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    /// Fresh empty writer.
    pub fn new() -> Self {
        WireWriter::default()
    }

    /// Finish, yielding the encoded bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Append a `u8`.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16` (LE).
    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32` (LE).
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (LE).
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u128` (LE) — share words cross the peer wire whole.
    pub fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` as its IEEE-754 bit pattern.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Append a length-prefixed byte string.
    // audit:allow(panic-free): send-path invariant on locally produced data
    pub fn put_bytes(&mut self, v: &[u8]) {
        assert!(v.len() <= u32::MAX as usize, "byte field too long");
        self.put_u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Append a count-prefixed `f64` vector.
    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u32(v.len() as u32);
        for &x in v {
            self.put_f64(x);
        }
    }

    /// Append a length-prefixed little-endian bigint.
    pub fn put_biguint(&mut self, v: &BigUint) {
        self.put_bytes(&v.to_bytes_le());
    }
}

/// Cursor-style decoder over a message body.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Require the body to be fully consumed.
    pub fn expect_end(&self) -> Result<(), WireError> {
        match self.remaining() {
            0 => Ok(()),
            extra => Err(WireError::TrailingBytes { extra }),
        }
    }

    // audit:allow(panic-free): the slice range is explicitly bounds-checked just above
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { needed: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    // audit:allow(panic-free): take(1) returned exactly one byte
    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u16` (LE).
    // audit:allow(panic-free): take(2) returned exactly two bytes
    pub fn get_u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a `u32` (LE).
    // audit:allow(panic-free): take(4) returned exactly four bytes
    pub fn get_u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64` (LE).
    // audit:allow(panic-free): take(8) returned exactly eight bytes
    pub fn get_u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a `u128` (LE).
    pub fn get_u128(&mut self) -> Result<u128, WireError> {
        let b = self.take(16)?;
        let mut buf = [0u8; 16];
        buf.copy_from_slice(b);
        Ok(u128::from_le_bytes(buf))
    }

    /// Read an `f64` from its bit pattern.
    pub fn get_f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Read a length-prefixed byte string.
    pub fn get_bytes(&mut self) -> Result<&'a [u8], WireError> {
        let len = self.get_u32()? as usize;
        self.take(len)
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn get_str(&mut self) -> Result<String, WireError> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    /// Read a count-prefixed `f64` vector.
    pub fn get_f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.get_u32()? as usize;
        // Bound the pre-allocation by what the body can actually hold.
        if self.remaining() < n.saturating_mul(8) {
            return Err(WireError::Truncated { needed: n * 8, have: self.remaining() });
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.get_f64()?);
        }
        Ok(v)
    }

    /// Read a length-prefixed little-endian bigint.
    pub fn get_biguint(&mut self) -> Result<BigUint, WireError> {
        Ok(BigUint::from_bytes_le(self.get_bytes()?))
    }
}

// ======================================================================
// Messages
// ======================================================================

/// Tag byte: [`WireMsg::StatsReq`].
pub const TAG_STATS_REQ: u8 = 0x01;
/// Tag byte: [`WireMsg::GramReq`].
pub const TAG_GRAM_REQ: u8 = 0x02;
/// Tag byte: [`WireMsg::HessReq`].
pub const TAG_HESS_REQ: u8 = 0x03;
/// Tag byte: [`WireMsg::MetaReq`].
pub const TAG_META_REQ: u8 = 0x04;
/// Tag byte: [`WireMsg::Shutdown`].
pub const TAG_SHUTDOWN: u8 = 0x05;
/// Tag byte: [`WireMsg::SetKey`].
pub const TAG_SET_KEY: u8 = 0x06;
/// Tag byte: [`WireMsg::SetHinv`].
pub const TAG_SET_HINV: u8 = 0x07;
/// Tag byte: [`WireMsg::StepReq`].
pub const TAG_STEP_REQ: u8 = 0x08;
/// Tag byte: [`WireMsg::Ping`].
pub const TAG_PING: u8 = 0x09;
/// Tag byte: [`WireMsg::NodeReply`] (plaintext statistics — only sent
/// when no [`WireMsg::SetKey`] arrived this session).
pub const TAG_NODE_REPLY: u8 = 0x11;
/// Tag byte: [`WireMsg::Meta`].
pub const TAG_META: u8 = 0x12;
/// Tag byte: [`WireMsg::Ack`].
pub const TAG_ACK: u8 = 0x13;
/// Tag byte: [`WireMsg::Bigint`].
pub const TAG_BIGINT: u8 = 0x21;
/// Tag byte: [`WireMsg::Ciphertexts`].
pub const TAG_CIPHERTEXTS: u8 = 0x22;
/// Tag byte: [`WireMsg::GarbledTables`].
pub const TAG_GARBLED: u8 = 0x23;
/// Tag byte: [`WireMsg::OtMsg`].
pub const TAG_OT: u8 = 0x24;
/// Tag byte: [`WireMsg::GcExec`].
pub const TAG_GC_EXEC: u8 = 0x31;
/// Tag byte: [`WireMsg::GcOut`].
pub const TAG_GC_OUT: u8 = 0x32;
/// Tag byte: [`WireMsg::Aggregate`].
pub const TAG_AGGREGATE: u8 = 0x35;
/// Tag byte: [`WireMsg::Blind`].
pub const TAG_BLIND: u8 = 0x36;
/// Tag byte: [`WireMsg::ShareInput`].
pub const TAG_SHARE_INPUT: u8 = 0x37;

/// Symbolic name of a wire tag, for reports and trace events (an
/// unknown byte renders as `"tag:0xNN"`-free `"unknown"` — decode
/// already rejected it, this is display-only).
pub fn tag_name(tag: u8) -> &'static str {
    match tag {
        TAG_STATS_REQ => "StatsReq",
        TAG_GRAM_REQ => "GramReq",
        TAG_HESS_REQ => "HessReq",
        TAG_META_REQ => "MetaReq",
        TAG_SHUTDOWN => "Shutdown",
        TAG_SET_KEY => "SetKey",
        TAG_SET_HINV => "SetHinv",
        TAG_STEP_REQ => "StepReq",
        TAG_PING => "Ping",
        TAG_NODE_REPLY => "NodeReply",
        TAG_META => "Meta",
        TAG_ACK => "Ack",
        TAG_BIGINT => "Bigint",
        TAG_CIPHERTEXTS => "Ciphertexts",
        TAG_GARBLED => "GarbledTables",
        TAG_OT => "OtMsg",
        TAG_GC_EXEC => "GcExec",
        TAG_GC_OUT => "GcOut",
        TAG_AGGREGATE => "Aggregate",
        TAG_BLIND => "Blind",
        TAG_SHARE_INPUT => "ShareInput",
        _ => "unknown",
    }
}

/// Pack bools LSB-first into bytes (zero-padded tail).
// audit:allow(panic-free): out is sized with div_ceil to hold every bit index
fn pack_bools(bits: &[bool]) -> Vec<u8> {
    let mut out = vec![0u8; bits.len().div_ceil(8)];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

// audit:allow(panic-free): byte length is checked against count before indexing
fn unpack_bools(bytes: &[u8], count: usize) -> Result<Vec<bool>, WireError> {
    if bytes.len() != count.div_ceil(8) {
        return Err(WireError::Truncated { needed: count.div_ceil(8), have: bytes.len() });
    }
    Ok((0..count).map(|i| (bytes[i / 8] >> (i % 8)) & 1 == 1).collect())
}

/// Every message that crosses a process boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// Center → node: fused gradient + log-likelihood at `beta`, × `scale`.
    StatsReq {
        /// Current public coefficients.
        beta: Vec<f64>,
        /// `1/n_total` scaling.
        scale: f64,
    },
    /// Center → node: `¼X_jᵀX_j · scale` (packed triangle).
    GramReq {
        /// `1/n_total` scaling.
        scale: f64,
    },
    /// Center → node: exact Hessian `X_jᵀAX_j · scale` (packed triangle).
    HessReq {
        /// Current public coefficients.
        beta: Vec<f64>,
        /// `1/n_total` scaling.
        scale: f64,
    },
    /// Center → node: describe your shard.
    MetaReq,
    /// Center → node: session over, exit cleanly.
    Shutdown,
    /// Center → node: the Center's Paillier public modulus and the
    /// fixed-point format. From here on the node encrypts every
    /// statistic reply itself ([`WireMsg::Ciphertexts`]) — plaintext
    /// statistics never cross the wire again this session.
    SetKey {
        /// Paillier modulus `n`.
        n: BigUint,
        /// Fixed-point word width (bits).
        w: u32,
        /// Fixed-point fractional bits.
        f: u32,
        /// Session epoch (0 for a fresh session). A re-key within one
        /// connection is only legal when this strictly advances — the
        /// node derives a fresh encryption-randomness stream per epoch,
        /// so an equal-or-lower epoch is rejected as a replay.
        epoch: u64,
        /// Slot count of the negotiated packing layout (wire v6); `0`
        /// keeps the session unpacked (the legacy one-value-per-
        /// ciphertext wire form).
        pack_k: u32,
        /// Slot width in bits of the negotiated packing layout (`0`
        /// when unpacked).
        pack_slot_bits: u32,
        /// Fan-in bound the packing layout was proven against (`0` when
        /// unpacked). The node re-validates the whole layout against
        /// its own headroom terms — a hostile center cannot negotiate
        /// an overflowing one.
        pack_max_parts: u64,
    },
    /// Center → node: the encrypted inverse Hessian bound `Enc(H̃⁻¹)`
    /// (packed lower triangle), broadcast once after PrivLogit-Local
    /// setup so nodes can run the multiply-by-constant step locally.
    SetHinv {
        /// Fixed-point scale (bits) of the encoded entries.
        scale: u32,
        /// Packed-triangle ciphertexts.
        cts: Vec<BigUint>,
    },
    /// Center → node: one PrivLogit-Local iteration — compute your local
    /// gradient at `beta`, apply the stored `Enc(H̃⁻¹)`, and reply with
    /// `Enc(H̃⁻¹ g_j)` followed by `Enc(l_sj)` (two
    /// [`WireMsg::Ciphertexts`] frames).
    StepReq {
        /// Current public coefficients.
        beta: Vec<f64>,
        /// `1/n_total` scaling.
        scale: f64,
    },
    /// Center → node: liveness probe. The node answers with a bare
    /// [`WireMsg::Ack`] and no protocol state changes on either side —
    /// the center's quorum layer uses this to check the health of a
    /// connection outside a statistic round.
    Ping,
    /// Node → center: bare acknowledgement (replies to [`WireMsg::SetKey`],
    /// [`WireMsg::SetHinv`] and [`WireMsg::Ping`]).
    Ack,
    /// Node → center: one statistic reply with node-measured seconds.
    NodeReply {
        /// Flat payload (gradient / packed triangle).
        values: Vec<f64>,
        /// Log-likelihood share (stats requests only).
        loglik: f64,
        /// Node compute seconds (ledger attribution).
        secs: f64,
    },
    /// Node → center: shard description.
    Meta {
        /// Samples held by this node.
        n: u64,
        /// Dimensionality.
        p: u32,
        /// Dataset display name.
        name: String,
    },
    /// An arbitrary-precision integer (Paillier plumbing).
    Bigint(BigUint),
    /// A vector of Paillier ciphertexts tagged with its fixed-point scale
    /// (the `EncVec` wire form). As a node statistic reply it also
    /// carries the node-measured compute seconds (encryption included),
    /// keeping the ledger's parallel-round attribution exact.
    Ciphertexts {
        /// Fixed-point scale (bits) of the encoded plaintexts.
        scale: u32,
        /// Node compute seconds (0 outside statistic replies).
        secs: f64,
        /// Ciphertext values (elements of `Z*_{n²}`).
        cts: Vec<BigUint>,
    },
    /// Garbled-table rows streamed between the two Center servers.
    GarbledTables(Vec<u8>),
    /// An OT-extension message between the two Center servers.
    OtMsg(Vec<u8>),
    /// Center-a → center-b: execute one garbled program. Center-a then
    /// plays the garbler on the same channel while center-b plays the
    /// evaluator. The evaluator's inputs are **not** in this frame:
    /// center-b assembles them from its own stored share vectors, named
    /// by `handles` in input order — S2's share halves never cross the
    /// peer wire. The reply depends on `out_mode` (see
    /// `mpc::peer::{OUT_REVEAL, OUT_SHARE, OUT_ENCRYPT}`): revealed
    /// output bits ([`WireMsg::GcOut`]), a bare [`WireMsg::Ack`] after
    /// storing the output as S2's new shares under `out_handle`, or a
    /// [`WireMsg::Ciphertexts`] frame after masked-wide encryption
    /// (center-a first sends its `Enc(C + r)` corrections as a
    /// [`WireMsg::Ciphertexts`] frame of its own).
    GcExec {
        /// Program kind byte (see `mpc::peer::ProgSpec`).
        prog: u8,
        /// Dimensionality parameter `p` (0 for the convergence check).
        p: u32,
        /// Fixed-point word width (bits).
        w: u32,
        /// Fixed-point fractional bits.
        f: u32,
        /// Convergence tolerance (convergence check only; 0 otherwise).
        tol: f64,
        /// Garbler/evaluator AND-gate counter at program start (hash
        /// tweak uniqueness across executions — both sides must agree).
        gate_ctr: u64,
        /// S2-held share vectors feeding the evaluator, in input order.
        handles: Vec<u64>,
        /// What center-b does with the program output.
        out_mode: u8,
        /// Handle the output shares are stored under (`OUT_SHARE` only).
        out_handle: u64,
    },
    /// Center-b → center-a: the output bits the evaluator learned.
    GcOut {
        /// Output bits in program order.
        bits: Vec<bool>,
    },
    /// Center-a → center-b: per-node ciphertext vectors relayed without
    /// decryption for S2 to `⊕`-aggregate (paper Alg. 1 step 8 — S2 is
    /// the aggregator). Center-b replies with the aggregated
    /// [`WireMsg::Ciphertexts`].
    Aggregate {
        /// Fixed-point scale (bits) shared by every part.
        scale: u32,
        /// One ciphertext vector per node, all the same length.
        parts: Vec<Vec<BigUint>>,
    },
    /// Center-a → center-b: blind-convert these ciphertexts to additive
    /// shares. Center-b draws its own blinds ρ, replies with the blinded
    /// ciphertexts ([`WireMsg::Ciphertexts`]) for S1 to decrypt into its
    /// halves, and **keeps** its own halves under `handle` — they never
    /// cross the wire.
    Blind {
        /// Handle the S2 halves are stored under.
        handle: u64,
        /// Scale-f ciphertexts to convert.
        cts: Vec<BigUint>,
        /// Slot count when the ciphertexts are packed (wire v6); `0`
        /// with `packed_parts = 0` means one value per ciphertext. The
        /// message is self-describing so S2 needs no session-level
        /// packing state (the peer key install happens before the
        /// center plans its layout).
        packed_k: u32,
        /// Slot width in bits (packed payloads only).
        packed_slot_bits: u32,
        /// Logical value count across the packed ciphertexts.
        packed_len: u64,
        /// Biased contributions per slot (`0` = unpacked payload). S2
        /// validates the claimed layout's headroom before drawing
        /// per-slot blinds.
        packed_parts: u64,
    },
    /// Install explicit S2 share values under a handle. This frame DOES
    /// carry share material across the wire — it exists for test drivers
    /// that legitimately hold both halves (plaintext-splitting harnesses)
    /// and must never appear in a protocol run; the custody census in
    /// `rust/tests/net_three_process.rs` asserts exactly that.
    ShareInput {
        /// Handle to store the values under.
        handle: u64,
        /// S2's share words.
        vals: Vec<u128>,
    },
}

impl WireMsg {
    /// The tag byte this message encodes with (wire-traffic census).
    pub fn tag(&self) -> u8 {
        match self {
            WireMsg::StatsReq { .. } => TAG_STATS_REQ,
            WireMsg::GramReq { .. } => TAG_GRAM_REQ,
            WireMsg::HessReq { .. } => TAG_HESS_REQ,
            WireMsg::MetaReq => TAG_META_REQ,
            WireMsg::Shutdown => TAG_SHUTDOWN,
            WireMsg::SetKey { .. } => TAG_SET_KEY,
            WireMsg::SetHinv { .. } => TAG_SET_HINV,
            WireMsg::StepReq { .. } => TAG_STEP_REQ,
            WireMsg::Ping => TAG_PING,
            WireMsg::NodeReply { .. } => TAG_NODE_REPLY,
            WireMsg::Meta { .. } => TAG_META,
            WireMsg::Ack => TAG_ACK,
            WireMsg::Bigint(_) => TAG_BIGINT,
            WireMsg::Ciphertexts { .. } => TAG_CIPHERTEXTS,
            WireMsg::GarbledTables(_) => TAG_GARBLED,
            WireMsg::OtMsg(_) => TAG_OT,
            WireMsg::GcExec { .. } => TAG_GC_EXEC,
            WireMsg::GcOut { .. } => TAG_GC_OUT,
            WireMsg::Aggregate { .. } => TAG_AGGREGATE,
            WireMsg::Blind { .. } => TAG_BLIND,
            WireMsg::ShareInput { .. } => TAG_SHARE_INPUT,
        }
    }

    /// Encode to a message body (frame it with [`write_frame`] to send).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        match self {
            WireMsg::StatsReq { beta, scale } => {
                w.put_u8(TAG_STATS_REQ);
                w.put_f64s(beta);
                w.put_f64(*scale);
            }
            WireMsg::GramReq { scale } => {
                w.put_u8(TAG_GRAM_REQ);
                w.put_f64(*scale);
            }
            WireMsg::HessReq { beta, scale } => {
                w.put_u8(TAG_HESS_REQ);
                w.put_f64s(beta);
                w.put_f64(*scale);
            }
            WireMsg::MetaReq => w.put_u8(TAG_META_REQ),
            WireMsg::Shutdown => w.put_u8(TAG_SHUTDOWN),
            WireMsg::SetKey { n, w: width, f, epoch, pack_k, pack_slot_bits, pack_max_parts } => {
                w.put_u8(TAG_SET_KEY);
                w.put_biguint(n);
                w.put_u32(*width);
                w.put_u32(*f);
                w.put_u64(*epoch);
                w.put_u32(*pack_k);
                w.put_u32(*pack_slot_bits);
                w.put_u64(*pack_max_parts);
            }
            WireMsg::SetHinv { scale, cts } => {
                w.put_u8(TAG_SET_HINV);
                w.put_u32(*scale);
                w.put_u32(cts.len() as u32);
                for c in cts {
                    w.put_biguint(c);
                }
            }
            WireMsg::StepReq { beta, scale } => {
                w.put_u8(TAG_STEP_REQ);
                w.put_f64s(beta);
                w.put_f64(*scale);
            }
            WireMsg::Ping => w.put_u8(TAG_PING),
            WireMsg::Ack => w.put_u8(TAG_ACK),
            WireMsg::NodeReply { values, loglik, secs } => {
                w.put_u8(TAG_NODE_REPLY);
                w.put_f64s(values);
                w.put_f64(*loglik);
                w.put_f64(*secs);
            }
            WireMsg::Meta { n, p, name } => {
                w.put_u8(TAG_META);
                w.put_u64(*n);
                w.put_u32(*p);
                w.put_str(name);
            }
            WireMsg::Bigint(v) => {
                w.put_u8(TAG_BIGINT);
                w.put_biguint(v);
            }
            WireMsg::Ciphertexts { scale, secs, cts } => {
                w.put_u8(TAG_CIPHERTEXTS);
                w.put_u32(*scale);
                w.put_f64(*secs);
                w.put_u32(cts.len() as u32);
                for c in cts {
                    w.put_biguint(c);
                }
            }
            WireMsg::GarbledTables(b) => {
                w.put_u8(TAG_GARBLED);
                w.put_bytes(b);
            }
            WireMsg::OtMsg(b) => {
                w.put_u8(TAG_OT);
                w.put_bytes(b);
            }
            WireMsg::GcExec {
                prog,
                p,
                w: width,
                f,
                tol,
                gate_ctr,
                handles,
                out_mode,
                out_handle,
            } => {
                w.put_u8(TAG_GC_EXEC);
                w.put_u8(*prog);
                w.put_u32(*p);
                w.put_u32(*width);
                w.put_u32(*f);
                w.put_f64(*tol);
                w.put_u64(*gate_ctr);
                w.put_u32(handles.len() as u32);
                for h in handles {
                    w.put_u64(*h);
                }
                w.put_u8(*out_mode);
                w.put_u64(*out_handle);
            }
            WireMsg::GcOut { bits } => {
                w.put_u8(TAG_GC_OUT);
                w.put_u32(bits.len() as u32);
                w.put_bytes(&pack_bools(bits));
            }
            WireMsg::Aggregate { scale, parts } => {
                w.put_u8(TAG_AGGREGATE);
                w.put_u32(*scale);
                w.put_u32(parts.len() as u32);
                for part in parts {
                    w.put_u32(part.len() as u32);
                    for c in part {
                        w.put_biguint(c);
                    }
                }
            }
            WireMsg::Blind {
                handle,
                cts,
                packed_k,
                packed_slot_bits,
                packed_len,
                packed_parts,
            } => {
                w.put_u8(TAG_BLIND);
                w.put_u64(*handle);
                w.put_u32(cts.len() as u32);
                for c in cts {
                    w.put_biguint(c);
                }
                w.put_u32(*packed_k);
                w.put_u32(*packed_slot_bits);
                w.put_u64(*packed_len);
                w.put_u64(*packed_parts);
            }
            WireMsg::ShareInput { handle, vals } => {
                w.put_u8(TAG_SHARE_INPUT);
                w.put_u64(*handle);
                w.put_u32(vals.len() as u32);
                for v in vals {
                    w.put_u128(*v);
                }
            }
        }
        w.finish()
    }

    /// Decode a message body, rejecting unknown tags, truncation and
    /// trailing bytes.
    pub fn decode(buf: &[u8]) -> Result<WireMsg, WireError> {
        let mut r = WireReader::new(buf);
        let tag = r.get_u8()?;
        let msg = match tag {
            TAG_STATS_REQ => {
                let beta = r.get_f64s()?;
                let scale = r.get_f64()?;
                WireMsg::StatsReq { beta, scale }
            }
            TAG_GRAM_REQ => WireMsg::GramReq { scale: r.get_f64()? },
            TAG_HESS_REQ => {
                let beta = r.get_f64s()?;
                let scale = r.get_f64()?;
                WireMsg::HessReq { beta, scale }
            }
            TAG_META_REQ => WireMsg::MetaReq,
            TAG_SHUTDOWN => WireMsg::Shutdown,
            TAG_SET_KEY => {
                let n = r.get_biguint()?;
                let w = r.get_u32()?;
                let f = r.get_u32()?;
                let epoch = r.get_u64()?;
                let pack_k = r.get_u32()?;
                let pack_slot_bits = r.get_u32()?;
                let pack_max_parts = r.get_u64()?;
                WireMsg::SetKey { n, w, f, epoch, pack_k, pack_slot_bits, pack_max_parts }
            }
            TAG_SET_HINV => {
                let scale = r.get_u32()?;
                let count = r.get_u32()? as usize;
                let mut cts = Vec::new();
                for _ in 0..count {
                    cts.push(r.get_biguint()?);
                }
                WireMsg::SetHinv { scale, cts }
            }
            TAG_STEP_REQ => {
                let beta = r.get_f64s()?;
                let scale = r.get_f64()?;
                WireMsg::StepReq { beta, scale }
            }
            TAG_PING => WireMsg::Ping,
            TAG_ACK => WireMsg::Ack,
            TAG_NODE_REPLY => {
                let values = r.get_f64s()?;
                let loglik = r.get_f64()?;
                let secs = r.get_f64()?;
                WireMsg::NodeReply { values, loglik, secs }
            }
            TAG_META => {
                let n = r.get_u64()?;
                let p = r.get_u32()?;
                let name = r.get_str()?;
                WireMsg::Meta { n, p, name }
            }
            TAG_BIGINT => WireMsg::Bigint(r.get_biguint()?),
            TAG_CIPHERTEXTS => {
                let scale = r.get_u32()?;
                let secs = r.get_f64()?;
                let count = r.get_u32()? as usize;
                let mut cts = Vec::new();
                for _ in 0..count {
                    cts.push(r.get_biguint()?);
                }
                WireMsg::Ciphertexts { scale, secs, cts }
            }
            TAG_GARBLED => WireMsg::GarbledTables(r.get_bytes()?.to_vec()),
            TAG_OT => WireMsg::OtMsg(r.get_bytes()?.to_vec()),
            TAG_GC_EXEC => {
                let prog = r.get_u8()?;
                let p = r.get_u32()?;
                let w = r.get_u32()?;
                let f = r.get_u32()?;
                let tol = r.get_f64()?;
                let gate_ctr = r.get_u64()?;
                let count = r.get_u32()? as usize;
                if r.remaining() < count.saturating_mul(8) {
                    return Err(WireError::Truncated { needed: count * 8, have: r.remaining() });
                }
                let mut handles = Vec::with_capacity(count);
                for _ in 0..count {
                    handles.push(r.get_u64()?);
                }
                let out_mode = r.get_u8()?;
                let out_handle = r.get_u64()?;
                WireMsg::GcExec { prog, p, w, f, tol, gate_ctr, handles, out_mode, out_handle }
            }
            TAG_GC_OUT => {
                let count = r.get_u32()? as usize;
                WireMsg::GcOut { bits: unpack_bools(r.get_bytes()?, count)? }
            }
            TAG_AGGREGATE => {
                let scale = r.get_u32()?;
                let part_count = r.get_u32()? as usize;
                // Each part needs at least its own count field; bound the
                // pre-allocation by what the body can actually hold.
                if r.remaining() < part_count.saturating_mul(4) {
                    return Err(WireError::Truncated {
                        needed: part_count * 4,
                        have: r.remaining(),
                    });
                }
                let mut parts = Vec::with_capacity(part_count);
                for _ in 0..part_count {
                    let count = r.get_u32()? as usize;
                    if r.remaining() < count.saturating_mul(4) {
                        return Err(WireError::Truncated {
                            needed: count * 4,
                            have: r.remaining(),
                        });
                    }
                    let mut cts = Vec::with_capacity(count);
                    for _ in 0..count {
                        cts.push(r.get_biguint()?);
                    }
                    parts.push(cts);
                }
                WireMsg::Aggregate { scale, parts }
            }
            TAG_BLIND => {
                let handle = r.get_u64()?;
                let count = r.get_u32()? as usize;
                if r.remaining() < count.saturating_mul(4) {
                    return Err(WireError::Truncated { needed: count * 4, have: r.remaining() });
                }
                let mut cts = Vec::with_capacity(count);
                for _ in 0..count {
                    cts.push(r.get_biguint()?);
                }
                let packed_k = r.get_u32()?;
                let packed_slot_bits = r.get_u32()?;
                let packed_len = r.get_u64()?;
                let packed_parts = r.get_u64()?;
                WireMsg::Blind { handle, cts, packed_k, packed_slot_bits, packed_len, packed_parts }
            }
            TAG_SHARE_INPUT => {
                let handle = r.get_u64()?;
                let count = r.get_u32()? as usize;
                if r.remaining() < count.saturating_mul(16) {
                    return Err(WireError::Truncated { needed: count * 16, have: r.remaining() });
                }
                let mut vals = Vec::with_capacity(count);
                for _ in 0..count {
                    vals.push(r.get_u128()?);
                }
                WireMsg::ShareInput { handle, vals }
            }
            t => return Err(WireError::UnknownTag(t)),
        };
        r.expect_end()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TestRng;

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn sample_msgs(rng: &mut TestRng) -> Vec<WireMsg> {
        let rand_vec = |rng: &mut TestRng, n: usize| -> Vec<f64> {
            (0..n).map(|_| rng.range_f64(-1e6, 1e6)).collect()
        };
        let rand_big = |rng: &mut TestRng| -> BigUint {
            let mut bytes = vec![0u8; 1 + rng.below_u64(64) as usize];
            for b in bytes.iter_mut() {
                *b = rng.below_u64(256) as u8;
            }
            BigUint::from_bytes_le(&bytes)
        };
        vec![
            WireMsg::StatsReq { beta: rand_vec(rng, 7), scale: rng.f64() },
            WireMsg::StatsReq { beta: vec![], scale: 0.0 },
            WireMsg::GramReq { scale: rng.f64() },
            WireMsg::HessReq { beta: rand_vec(rng, 12), scale: -0.0 },
            WireMsg::MetaReq,
            WireMsg::Shutdown,
            WireMsg::NodeReply {
                values: rand_vec(rng, 78),
                loglik: rng.range_f64(-1e9, 0.0),
                secs: rng.f64(),
            },
            WireMsg::Meta { n: rng.next_u64(), p: 33, name: "Loans — ωξ".to_string() },
            WireMsg::Bigint(rand_big(rng)),
            WireMsg::Bigint(BigUint::zero()),
            WireMsg::Ciphertexts {
                scale: 24,
                secs: rng.f64(),
                cts: (0..5).map(|_| rand_big(rng)).collect(),
            },
            WireMsg::Ciphertexts { scale: 0, secs: 0.0, cts: vec![] },
            WireMsg::GarbledTables((0..200u8).collect()),
            WireMsg::OtMsg(vec![]),
            WireMsg::SetKey {
                n: rand_big(rng),
                w: 40,
                f: 24,
                epoch: 0,
                pack_k: 0,
                pack_slot_bits: 0,
                pack_max_parts: 0,
            },
            WireMsg::SetKey {
                n: rand_big(rng),
                w: 40,
                f: 24,
                epoch: rng.next_u64(),
                pack_k: 23,
                pack_slot_bits: 87,
                pack_max_parts: 6,
            },
            WireMsg::SetHinv {
                scale: 24,
                cts: (0..6).map(|_| rand_big(rng)).collect(),
            },
            WireMsg::StepReq { beta: rand_vec(rng, 5), scale: rng.f64() },
            WireMsg::Ping,
            WireMsg::Ack,
            WireMsg::GcExec {
                prog: 3,
                p: 12,
                w: 40,
                f: 24,
                tol: 1e-6,
                gate_ctr: rng.next_u64(),
                handles: vec![rng.next_u64(), rng.next_u64()],
                out_mode: 0,
                out_handle: 0,
            },
            WireMsg::GcExec {
                prog: 2,
                p: 4,
                w: 40,
                f: 24,
                tol: 0.0,
                gate_ctr: 0,
                handles: vec![7],
                out_mode: 1,
                out_handle: 8,
            },
            WireMsg::GcExec {
                prog: 5,
                p: 0,
                w: 40,
                f: 24,
                tol: 0.0,
                gate_ctr: 0,
                handles: vec![],
                out_mode: 0,
                out_handle: 0,
            },
            WireMsg::GcOut { bits: (0..40).map(|_| rng.bernoulli(0.5)).collect() },
            WireMsg::Aggregate {
                scale: 24,
                parts: (0..3).map(|_| (0..4).map(|_| rand_big(rng)).collect()).collect(),
            },
            WireMsg::Aggregate { scale: 0, parts: vec![] },
            WireMsg::Blind {
                handle: rng.next_u64(),
                cts: (0..5).map(|_| rand_big(rng)).collect(),
                packed_k: 0,
                packed_slot_bits: 0,
                packed_len: 0,
                packed_parts: 0,
            },
            WireMsg::Blind {
                handle: rng.next_u64(),
                cts: (0..3).map(|_| rand_big(rng)).collect(),
                packed_k: 2,
                packed_slot_bits: 86,
                packed_len: 6,
                packed_parts: 4,
            },
            WireMsg::ShareInput {
                handle: rng.next_u64(),
                vals: (0..7)
                    .map(|_| (rng.next_u64() as u128) << 64 | rng.next_u64() as u128)
                    .collect(),
            },
            WireMsg::ShareInput { handle: 0, vals: vec![] },
        ]
    }

    /// Round-trip property: encode→decode is the identity for every
    /// message type over many random payloads.
    #[test]
    fn roundtrip_all_message_types() {
        let mut rng = TestRng::new(0xA11CE);
        for trial in 0..50 {
            for msg in sample_msgs(&mut rng) {
                let enc = msg.encode();
                let dec = WireMsg::decode(&enc)
                    .unwrap_or_else(|e| panic!("trial {trial}: {e} on {msg:?}"));
                assert_eq!(dec, msg, "trial {trial}");
            }
        }
    }

    /// Every strict prefix of a valid encoding must be rejected as
    /// truncated (never panic, never succeed).
    #[test]
    fn truncated_bodies_rejected() {
        let mut rng = TestRng::new(0xBEE);
        for msg in sample_msgs(&mut rng) {
            let enc = msg.encode();
            for cut in 0..enc.len() {
                match WireMsg::decode(&enc[..cut]) {
                    Err(_) => {}
                    Ok(other) => {
                        panic!("prefix {cut}/{} of {msg:?} decoded as {other:?}", enc.len())
                    }
                }
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut enc = WireMsg::MetaReq.encode();
        enc.push(0);
        assert_eq!(WireMsg::decode(&enc), Err(WireError::TrailingBytes { extra: 1 }));
    }

    #[test]
    fn unknown_tag_rejected() {
        assert_eq!(WireMsg::decode(&[0xEE]), Err(WireError::UnknownTag(0xEE)));
        assert!(matches!(WireMsg::decode(&[]), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn oversized_f64_count_rejected_without_allocation() {
        // Tag + count claiming u32::MAX values with an empty body must be
        // caught by the remaining-bytes bound, not by allocating 32 GiB.
        let mut w = WireWriter::new();
        w.put_u8(0x01); // StatsReq
        w.put_u32(u32::MAX);
        let enc = w.finish();
        assert!(matches!(WireMsg::decode(&enc), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn hello_roundtrip_and_rejection() {
        let h = hello(ROLE_NODE, 0);
        assert_eq!(check_hello(&h), Ok((ROLE_NODE, 0)));

        // The epoch travels through the hello intact (resume re-key).
        let h = hello(ROLE_CENTER, u64::MAX - 3);
        assert_eq!(check_hello(&h), Ok((ROLE_CENTER, u64::MAX - 3)));

        let mut bad_magic = h;
        bad_magic[0] = b'X';
        assert!(matches!(check_hello(&bad_magic), Err(WireError::BadMagic(_))));

        let mut bad_version = hello(ROLE_CENTER, 0);
        bad_version[4] = 0xFF;
        bad_version[5] = 0xFF;
        assert_eq!(
            check_hello(&bad_version),
            Err(WireError::VersionMismatch { got: 0xFFFF, want: VERSION })
        );
    }

    #[test]
    fn frame_roundtrip_and_crc_rejection() {
        let payload = WireMsg::GramReq { scale: 0.25 }.encode();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        assert_eq!(buf.len(), 4 + payload.len() + 4);

        let mut cur = std::io::Cursor::new(buf.clone());
        assert_eq!(read_frame(&mut cur).unwrap(), payload);

        // Flip one payload bit: the CRC must catch it.
        let mut corrupt = buf.clone();
        corrupt[5] ^= 0x40;
        let mut cur = std::io::Cursor::new(corrupt);
        let err = read_frame(&mut cur).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        // Truncated frame: report an error, never hang or panic.
        let mut cur = std::io::Cursor::new(buf[..buf.len() - 2].to_vec());
        assert!(read_frame(&mut cur).is_err());

        // Hostile length prefix.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = std::io::Cursor::new(huge);
        assert!(read_frame(&mut cur).is_err());
    }

    /// f64 bit-pattern transport must preserve every value exactly.
    #[test]
    fn f64_bit_exact() {
        let specials = [0.0, -0.0, f64::INFINITY, f64::NEG_INFINITY, f64::MIN_POSITIVE, 1e-308];
        let msg = WireMsg::NodeReply { values: specials.to_vec(), loglik: f64::NAN, secs: 0.0 };
        let dec = WireMsg::decode(&msg.encode()).unwrap();
        match dec {
            WireMsg::NodeReply { values, loglik, .. } => {
                for (a, b) in values.iter().zip(&specials) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                assert!(loglik.is_nan());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }
}
