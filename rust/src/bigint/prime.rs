//! Probabilistic primality testing and random prime generation for
//! Paillier key generation.

use super::{BigUint, RandomSource};

/// Small primes for fast trial division before Miller–Rabin.
const SMALL_PRIMES: [u64; 60] = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89,
    97, 101, 103, 107, 109, 113, 127, 131, 137, 139, 149, 151, 157, 163, 167, 173, 179, 181, 191,
    193, 197, 199, 211, 223, 227, 229, 233, 239, 241, 251, 257, 263, 269, 271, 277, 281,
];

/// Miller–Rabin probabilistic primality test with `rounds` random bases.
///
/// Error probability ≤ 4^-rounds for composites. 2^-80 at 40 rounds.
pub fn is_probable_prime(n: &BigUint, rounds: usize, rng: &mut dyn RandomSource) -> bool {
    if n.limbs.len() <= 1 {
        let v = n.low_u64();
        if v <= *SMALL_PRIMES.last().unwrap() {
            return SMALL_PRIMES.contains(&v);
        }
    }
    if n.is_even() {
        return false;
    }
    for &p in &SMALL_PRIMES {
        if n.divrem_u64(p).1 == 0 {
            // n is a proper multiple of a small prime (n > 281 here).
            return false;
        }
    }
    // Write n-1 = d * 2^s with d odd.
    let n1 = n.sub_u64(1);
    let s = n1.trailing_zeros();
    let d = n1.shr(s);
    let two = BigUint::from_u64(2);
    let bound = n.sub_u64(3); // bases in [2, n-2]
    'witness: for _ in 0..rounds {
        let a = rng.below(&bound).add(&two);
        let mut x = a.modpow(&d, n);
        if x.is_one() || x == n1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = x.mul_mod(&x, n);
            if x == n1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Generate a random prime of exactly `bits` bits (top two bits set so the
/// product of two such primes has exactly `2·bits` bits — the standard RSA
/// modulus construction Paillier reuses).
pub fn gen_prime(bits: usize, rng: &mut dyn RandomSource) -> BigUint {
    assert!(bits >= 16, "prime too small to be useful");
    let rounds = 28; // 4^-28 < 2^-56 per candidate; fine for experiments
    loop {
        let mut bytes = vec![0u8; bits.div_ceil(8)];
        rng.fill_bytes(&mut bytes);
        let mut cand = BigUint::from_bytes_le(&bytes);
        // Trim to exactly `bits` bits, set the top two bits and make odd.
        cand = cand.shr(cand.bit_len().saturating_sub(bits));
        cand.set_bit(bits - 1);
        cand.set_bit(bits - 2);
        cand.set_bit(0);
        if is_probable_prime(&cand, rounds, rng) {
            return cand;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TestRng;

    #[test]
    fn known_primes_and_composites() {
        let mut rng = TestRng::new(1);
        let primes = ["2", "3", "5", "101", "1000000007", "18446744073709551557"];
        for p in primes {
            let n = BigUint::from_dec_str(p).unwrap();
            assert!(is_probable_prime(&n, 20, &mut rng), "{p} is prime");
        }
        let composites = ["1", "4", "100", "1000000008", "561", "41041", "825265"];
        // 561, 41041, 825265 are Carmichael numbers — MR must still reject.
        for c in composites {
            let n = BigUint::from_dec_str(c).unwrap();
            assert!(!is_probable_prime(&n, 20, &mut rng), "{c} is composite");
        }
    }

    #[test]
    fn mersenne_prime_2_127() {
        let mut rng = TestRng::new(2);
        // 2^127 - 1 is prime
        let p = BigUint::one().shl(127).sub_u64(1);
        assert!(is_probable_prime(&p, 20, &mut rng));
        // 2^128 - 1 is not
        let c = BigUint::one().shl(128).sub_u64(1);
        assert!(!is_probable_prime(&c, 20, &mut rng));
    }

    #[test]
    fn gen_prime_properties() {
        let mut rng = TestRng::new(5);
        for bits in [64, 128, 256] {
            let p = gen_prime(bits, &mut rng);
            assert_eq!(p.bit_len(), bits, "exact bit length");
            assert!(p.bit(bits - 2), "second-top bit set");
            assert!(!p.is_even());
            assert!(is_probable_prime(&p, 20, &mut rng));
        }
    }

    #[test]
    fn distinct_primes() {
        let mut rng = TestRng::new(6);
        let p = gen_prime(96, &mut rng);
        let q = gen_prime(96, &mut rng);
        assert_ne!(p, q);
    }
}
