//! Arbitrary-precision unsigned integer arithmetic.
//!
//! This is the numeric substrate for the Paillier cryptosystem
//! ([`crate::crypto::paillier`]). `num-bigint` is unavailable in the build
//! image, so the whole stack — schoolbook/Karatsuba multiplication,
//! Knuth Algorithm-D division, Montgomery exponentiation, extended GCD,
//! Miller–Rabin primality and prime generation — is implemented here.
//!
//! Representation: little-endian `u64` limbs, always *normalized* (no
//! trailing zero limbs; zero is the empty limb vector).

mod monty;
mod prime;
mod signed;

pub use monty::{FixedBase, MontElem, Montgomery, StrausTable};
pub use prime::{gen_prime, is_probable_prime};
pub use signed::BigInt;

use std::cmp::Ordering;

/// A random byte source, implemented by [`crate::crypto::rng::ChaChaRng`].
///
/// Defined here (rather than in `crypto`) so prime generation has no
/// dependency on the crypto layer above it.
pub trait RandomSource {
    /// Fill `buf` with uniformly random bytes.
    fn fill_bytes(&mut self, buf: &mut [u8]);

    /// A uniformly random integer in `[0, bound)`. `bound` must be nonzero.
    fn below(&mut self, bound: &BigUint) -> BigUint {
        assert!(!bound.is_zero(), "below(0)");
        let bits = bound.bit_len();
        let bytes = bits.div_ceil(8);
        let top_mask: u8 = if bits % 8 == 0 { 0xff } else { (1u8 << (bits % 8)) - 1 };
        let mut buf = vec![0u8; bytes];
        // Rejection sampling: each draw succeeds with probability > 1/2.
        loop {
            self.fill_bytes(&mut buf);
            buf[bytes - 1] &= top_mask; // buf is little-endian
            let candidate = BigUint::from_bytes_le(&buf);
            if candidate < *bound {
                return candidate;
            }
        }
    }
}

/// Arbitrary-precision unsigned integer (little-endian `u64` limbs).
#[derive(Clone, PartialEq, Eq, Hash, Default)]
pub struct BigUint {
    pub(crate) limbs: Vec<u64>,
}

/// Karatsuba recursion cut-off, in limbs. Below this, schoolbook wins.
const KARATSUBA_THRESHOLD: usize = 24;

impl BigUint {
    /// The value 0.
    pub fn zero() -> Self {
        BigUint { limbs: Vec::new() }
    }

    /// The value 1.
    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    /// Construct from a `u64`.
    pub fn from_u64(v: u64) -> Self {
        if v == 0 { Self::zero() } else { BigUint { limbs: vec![v] } }
    }

    /// Construct from a `u128`.
    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        let mut n = BigUint { limbs: vec![lo, hi] };
        n.normalize();
        n
    }

    /// Construct from little-endian limbs (normalizing).
    pub fn from_limbs(limbs: Vec<u64>) -> Self {
        let mut n = BigUint { limbs };
        n.normalize();
        n
    }

    /// Construct from little-endian bytes.
    pub fn from_bytes_le(bytes: &[u8]) -> Self {
        let mut limbs = Vec::with_capacity(bytes.len().div_ceil(8));
        for chunk in bytes.chunks(8) {
            let mut limb = [0u8; 8];
            limb[..chunk.len()].copy_from_slice(chunk);
            limbs.push(u64::from_le_bytes(limb));
        }
        Self::from_limbs(limbs)
    }

    /// Construct from big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut le = bytes.to_vec();
        le.reverse();
        Self::from_bytes_le(&le)
    }

    /// Little-endian byte serialization (minimal length; empty for zero).
    pub fn to_bytes_le(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.limbs.len() * 8);
        for &l in &self.limbs {
            out.extend_from_slice(&l.to_le_bytes());
        }
        while out.last() == Some(&0) {
            out.pop();
        }
        out
    }

    /// Big-endian byte serialization (minimal length; empty for zero).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        let mut v = self.to_bytes_le();
        v.reverse();
        v
    }

    /// Parse a decimal string.
    pub fn from_dec_str(s: &str) -> Option<Self> {
        if s.is_empty() {
            return None;
        }
        let mut acc = BigUint::zero();
        let ten = BigUint::from_u64(10);
        for c in s.bytes() {
            if !c.is_ascii_digit() {
                return None;
            }
            acc = acc.mul(&ten).add(&BigUint::from_u64((c - b'0') as u64));
        }
        Some(acc)
    }

    /// Decimal string rendering.
    pub fn to_dec_string(&self) -> String {
        if self.is_zero() {
            return "0".to_string();
        }
        // Repeated division by 10^19 (largest power of ten in u64).
        const CHUNK: u64 = 10_000_000_000_000_000_000;
        let mut digits: Vec<String> = Vec::new();
        let mut cur = self.clone();
        while !cur.is_zero() {
            let (q, r) = cur.divrem_u64(CHUNK);
            digits.push(r.to_string());
            cur = q;
        }
        let mut out = String::new();
        for (i, d) in digits.iter().rev().enumerate() {
            if i == 0 {
                out.push_str(d);
            } else {
                out.push_str(&format!("{:0>19}", d));
            }
        }
        out
    }

    /// True iff the value is zero.
    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    /// True iff the value is one.
    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    /// True iff the value is even.
    pub fn is_even(&self) -> bool {
        self.limbs.first().map_or(true, |l| l & 1 == 0)
    }

    /// Low 64 bits of the value.
    pub fn low_u64(&self) -> u64 {
        self.limbs.first().copied().unwrap_or(0)
    }

    /// Number of significant bits (0 for zero).
    pub fn bit_len(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => self.limbs.len() * 64 - top.leading_zeros() as usize,
        }
    }

    /// Value of bit `i` (0 = least significant).
    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).map_or(false, |l| (l >> off) & 1 == 1)
    }

    /// Set bit `i` to 1, growing as needed.
    pub fn set_bit(&mut self, i: usize) {
        let (limb, off) = (i / 64, i % 64);
        if limb >= self.limbs.len() {
            self.limbs.resize(limb + 1, 0);
        }
        self.limbs[limb] |= 1 << off;
    }

    fn normalize(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &BigUint) -> BigUint {
        let (a, b) = if self.limbs.len() >= other.limbs.len() {
            (&self.limbs, &other.limbs)
        } else {
            (&other.limbs, &self.limbs)
        };
        let mut out = Vec::with_capacity(a.len() + 1);
        let mut carry = 0u64;
        for i in 0..a.len() {
            let bi = b.get(i).copied().unwrap_or(0);
            let (s1, c1) = a[i].overflowing_add(bi);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry != 0 {
            out.push(carry);
        }
        BigUint::from_limbs(out)
    }

    /// `self + v` for a `u64`.
    pub fn add_u64(&self, v: u64) -> BigUint {
        self.add(&BigUint::from_u64(v))
    }

    /// `self - other`; panics if `other > self`.
    pub fn sub(&self, other: &BigUint) -> BigUint {
        assert!(self >= other, "BigUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let bi = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(bi);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        BigUint::from_limbs(out)
    }

    /// `self - v` for a `u64`; panics on underflow.
    pub fn sub_u64(&self, v: u64) -> BigUint {
        self.sub(&BigUint::from_u64(v))
    }

    /// `self * other` (schoolbook below [`KARATSUBA_THRESHOLD`] limbs,
    /// Karatsuba above).
    pub fn mul(&self, other: &BigUint) -> BigUint {
        if self.is_zero() || other.is_zero() {
            return BigUint::zero();
        }
        let n = self.limbs.len().min(other.limbs.len());
        if n < KARATSUBA_THRESHOLD {
            self.mul_schoolbook(other)
        } else {
            self.mul_karatsuba(other)
        }
    }

    fn mul_schoolbook(&self, other: &BigUint) -> BigUint {
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            if a == 0 {
                continue;
            }
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let t = out[i + j] as u128 + a as u128 * b as u128 + carry;
                out[i + j] = t as u64;
                carry = t >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry != 0 {
                let t = out[k] as u128 + carry;
                out[k] = t as u64;
                carry = t >> 64;
                k += 1;
            }
        }
        BigUint::from_limbs(out)
    }

    fn mul_karatsuba(&self, other: &BigUint) -> BigUint {
        let half = self.limbs.len().max(other.limbs.len()) / 2;
        let (a0, a1) = self.split_at(half);
        let (b0, b1) = other.split_at(half);
        let z0 = a0.mul(&b0);
        let z2 = a1.mul(&b1);
        let z1 = a0.add(&a1).mul(&b0.add(&b1)).sub(&z0).sub(&z2);
        z0.add(&z1.shl_limbs(half)).add(&z2.shl_limbs(2 * half))
    }

    fn split_at(&self, k: usize) -> (BigUint, BigUint) {
        if k >= self.limbs.len() {
            (self.clone(), BigUint::zero())
        } else {
            (
                BigUint::from_limbs(self.limbs[..k].to_vec()),
                BigUint::from_limbs(self.limbs[k..].to_vec()),
            )
        }
    }

    fn shl_limbs(&self, k: usize) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        let mut limbs = vec![0u64; k];
        limbs.extend_from_slice(&self.limbs);
        BigUint { limbs }
    }

    /// `self * v` for a `u64`.
    pub fn mul_u64(&self, v: u64) -> BigUint {
        if v == 0 || self.is_zero() {
            return BigUint::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() + 1);
        let mut carry = 0u128;
        for &a in &self.limbs {
            let t = a as u128 * v as u128 + carry;
            out.push(t as u64);
            carry = t >> 64;
        }
        if carry != 0 {
            out.push(carry as u64);
        }
        BigUint::from_limbs(out)
    }

    /// `self << bits`.
    pub fn shl(&self, bits: usize) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        let (limb_shift, bit_shift) = (bits / 64, bits % 64);
        let mut out = vec![0u64; limb_shift];
        if bit_shift == 0 {
            out.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &l in &self.limbs {
                out.push((l << bit_shift) | carry);
                carry = l >> (64 - bit_shift);
            }
            if carry != 0 {
                out.push(carry);
            }
        }
        BigUint::from_limbs(out)
    }

    /// `self >> bits`.
    pub fn shr(&self, bits: usize) -> BigUint {
        let (limb_shift, bit_shift) = (bits / 64, bits % 64);
        if limb_shift >= self.limbs.len() {
            return BigUint::zero();
        }
        let src = &self.limbs[limb_shift..];
        let mut out = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            out.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let hi = src.get(i + 1).copied().unwrap_or(0);
                out.push((src[i] >> bit_shift) | (hi << (64 - bit_shift)));
            }
        }
        BigUint::from_limbs(out)
    }

    /// Quotient and remainder `(self / d, self % d)`; panics if `d == 0`.
    pub fn divrem(&self, d: &BigUint) -> (BigUint, BigUint) {
        assert!(!d.is_zero(), "division by zero");
        match self.cmp(d) {
            Ordering::Less => return (BigUint::zero(), self.clone()),
            Ordering::Equal => return (BigUint::one(), BigUint::zero()),
            Ordering::Greater => {}
        }
        if d.limbs.len() == 1 {
            let (q, r) = self.divrem_u64(d.limbs[0]);
            return (q, BigUint::from_u64(r));
        }
        self.divrem_knuth(d)
    }

    /// Quotient and `u64` remainder for a single-limb divisor.
    pub fn divrem_u64(&self, d: u64) -> (BigUint, u64) {
        assert!(d != 0, "division by zero");
        let mut q = vec![0u64; self.limbs.len()];
        let mut rem = 0u128;
        for i in (0..self.limbs.len()).rev() {
            let cur = (rem << 64) | self.limbs[i] as u128;
            q[i] = (cur / d as u128) as u64;
            rem = cur % d as u128;
        }
        (BigUint::from_limbs(q), rem as u64)
    }

    /// Knuth Algorithm D (TAOCP 4.3.1) for multi-limb divisors.
    fn divrem_knuth(&self, d: &BigUint) -> (BigUint, BigUint) {
        let shift = d.limbs.last().unwrap().leading_zeros() as usize;
        let u = self.shl(shift); // dividend, normalized
        let v = d.shl(shift); // divisor, top bit set
        let n = v.limbs.len();
        let m = u.limbs.len() - n;
        let mut un = u.limbs.clone();
        un.push(0); // u has m+n+1 limbs
        let vn = &v.limbs;
        let mut q = vec![0u64; m + 1];
        let vtop = vn[n - 1];
        let vsec = vn[n - 2];
        for j in (0..=m).rev() {
            // D3: estimate q̂ from top two dividend limbs / top divisor limb.
            let num = ((un[j + n] as u128) << 64) | un[j + n - 1] as u128;
            let mut qhat = num / vtop as u128;
            let mut rhat = num % vtop as u128;
            while qhat >> 64 != 0
                || qhat * vsec as u128 > ((rhat << 64) | un[j + n - 2] as u128)
            {
                qhat -= 1;
                rhat += vtop as u128;
                if rhat >> 64 != 0 {
                    break;
                }
            }
            // D4: multiply-subtract u[j..j+n] -= qhat * v.
            let mut borrow = 0i128;
            let mut carry = 0u128;
            for i in 0..n {
                let p = qhat * vn[i] as u128 + carry;
                carry = p >> 64;
                let t = un[i + j] as i128 - (p as u64) as i128 - borrow;
                un[i + j] = t as u64;
                borrow = if t < 0 { 1 } else { 0 };
            }
            let t = un[j + n] as i128 - carry as i128 - borrow;
            un[j + n] = t as u64;
            if t < 0 {
                // D6: estimate was one too large; add back.
                qhat -= 1;
                let mut c = 0u128;
                for i in 0..n {
                    let s = un[i + j] as u128 + vn[i] as u128 + c;
                    un[i + j] = s as u64;
                    c = s >> 64;
                }
                un[j + n] = (un[j + n] as u128).wrapping_add(c) as u64;
            }
            q[j] = qhat as u64;
        }
        let rem = BigUint::from_limbs(un[..n].to_vec()).shr(shift);
        (BigUint::from_limbs(q), rem)
    }

    /// `self mod m`.
    pub fn rem(&self, m: &BigUint) -> BigUint {
        self.divrem(m).1
    }

    /// `(self + other) mod m`, assuming both operands are `< m`.
    pub fn add_mod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        let s = self.add(other);
        if s >= *m { s.sub(m) } else { s }
    }

    /// `(self - other) mod m`, assuming both operands are `< m`.
    pub fn sub_mod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        if self >= other {
            self.sub(other)
        } else {
            m.sub(other).add(self)
        }
    }

    /// `(self * other) mod m`.
    pub fn mul_mod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        self.mul(other).rem(m)
    }

    /// Modular exponentiation `self^exp mod m`.
    ///
    /// Uses Montgomery multiplication when `m` is odd (the Paillier case),
    /// falling back to square-and-multiply with division-based reduction.
    pub fn modpow(&self, exp: &BigUint, m: &BigUint) -> BigUint {
        assert!(!m.is_zero(), "modpow modulus zero");
        if m.is_one() {
            return BigUint::zero();
        }
        if !m.is_even() {
            return Montgomery::new(m).pow(self, exp);
        }
        // Even modulus: plain left-to-right square-and-multiply.
        let base = self.rem(m);
        let mut acc = BigUint::one();
        for i in (0..exp.bit_len()).rev() {
            acc = acc.mul_mod(&acc, m);
            if exp.bit(i) {
                acc = acc.mul_mod(&base, m);
            }
        }
        acc
    }

    /// Greatest common divisor (binary GCD).
    pub fn gcd(&self, other: &BigUint) -> BigUint {
        let mut a = self.clone();
        let mut b = other.clone();
        if a.is_zero() {
            return b;
        }
        if b.is_zero() {
            return a;
        }
        let az = a.trailing_zeros();
        let bz = b.trailing_zeros();
        let common = az.min(bz);
        a = a.shr(az);
        b = b.shr(bz);
        loop {
            if a > b {
                std::mem::swap(&mut a, &mut b);
            }
            b = b.sub(&a);
            if b.is_zero() {
                return a.shl(common);
            }
            b = b.shr(b.trailing_zeros());
        }
    }

    /// Least common multiple.
    pub fn lcm(&self, other: &BigUint) -> BigUint {
        if self.is_zero() || other.is_zero() {
            return BigUint::zero();
        }
        self.divrem(&self.gcd(other)).0.mul(other)
    }

    /// Number of trailing zero bits (0 for zero).
    pub fn trailing_zeros(&self) -> usize {
        for (i, &l) in self.limbs.iter().enumerate() {
            if l != 0 {
                return i * 64 + l.trailing_zeros() as usize;
            }
        }
        0
    }

    /// Modular inverse `self^-1 mod m`, or `None` if `gcd(self, m) != 1`.
    pub fn modinv(&self, m: &BigUint) -> Option<BigUint> {
        let a = BigInt::from_biguint(self.rem(m));
        let (g, x, _) = BigInt::ext_gcd(&a, &BigInt::from_biguint(m.clone()));
        if !g.magnitude().is_one() {
            return None;
        }
        Some(x.rem_euclid(m))
    }

    /// Integer square root (floor).
    pub fn isqrt(&self) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        // Newton's method with a power-of-two seed.
        let mut x = BigUint::one().shl(self.bit_len().div_ceil(2));
        loop {
            // x' = (x + self/x) / 2
            let y = x.add(&self.divrem(&x).0).shr(1);
            if y >= x {
                return x;
            }
            x = y;
        }
    }
}

impl PartialOrd for BigUint {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for BigUint {
    fn cmp(&self, other: &Self) -> Ordering {
        match self.limbs.len().cmp(&other.limbs.len()) {
            Ordering::Equal => {}
            o => return o,
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => {}
                o => return o,
            }
        }
        Ordering::Equal
    }
}

impl std::fmt::Debug for BigUint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BigUint({})", self.to_dec_string())
    }
}

impl std::fmt::Display for BigUint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_dec_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TestRng;

    fn n(s: &str) -> BigUint {
        BigUint::from_dec_str(s).unwrap()
    }

    #[test]
    fn construct_and_render() {
        assert_eq!(BigUint::zero().to_dec_string(), "0");
        assert_eq!(BigUint::from_u64(42).to_dec_string(), "42");
        assert_eq!(
            BigUint::from_u128(u128::MAX).to_dec_string(),
            "340282366920938463463374607431768211455"
        );
        let big = n("123456789012345678901234567890123456789012345678901234567890");
        assert_eq!(
            big.to_dec_string(),
            "123456789012345678901234567890123456789012345678901234567890"
        );
    }

    #[test]
    fn bytes_roundtrip() {
        let x = n("98765432109876543210987654321098765432109876543210");
        assert_eq!(BigUint::from_bytes_le(&x.to_bytes_le()), x);
        assert_eq!(BigUint::from_bytes_be(&x.to_bytes_be()), x);
        assert!(BigUint::zero().to_bytes_le().is_empty());
    }

    #[test]
    fn add_sub_basic() {
        let a = n("340282366920938463463374607431768211455"); // 2^128-1
        let b = BigUint::one();
        let s = a.add(&b);
        assert_eq!(s.to_dec_string(), "340282366920938463463374607431768211456");
        assert_eq!(s.sub(&b), a);
        assert_eq!(s.sub(&a), b);
        assert_eq!(a.sub(&a), BigUint::zero());
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        BigUint::from_u64(1).sub(&BigUint::from_u64(2));
    }

    #[test]
    fn mul_matches_u128() {
        let cases: [(u64, u64); 4] =
            [(0, 5), (u64::MAX, u64::MAX), (12345, 67890), (1 << 63, 2)];
        for (a, b) in cases {
            let got = BigUint::from_u64(a).mul(&BigUint::from_u64(b));
            assert_eq!(got, BigUint::from_u128(a as u128 * b as u128));
        }
    }

    #[test]
    fn karatsuba_matches_schoolbook() {
        let mut rng = TestRng::new(7);
        for _ in 0..10 {
            let a = random_biguint(&mut rng, 40 * 64);
            let b = random_biguint(&mut rng, 40 * 64);
            assert_eq!(a.mul_karatsuba(&b), a.mul_schoolbook(&b));
        }
    }

    #[test]
    fn shifts() {
        let x = n("123456789123456789123456789");
        assert_eq!(x.shl(0), x);
        assert_eq!(x.shl(64).shr(64), x);
        assert_eq!(x.shl(67).shr(67), x);
        assert_eq!(x.shr(1000), BigUint::zero());
        assert_eq!(x.shl(3), x.mul_u64(8));
    }

    #[test]
    fn divrem_small() {
        let (q, r) = n("1000").divrem(&n("7"));
        assert_eq!((q.to_dec_string(), r.to_dec_string()), ("142".into(), "6".into()));
        let (q, r) = n("7").divrem(&n("1000"));
        assert!(q.is_zero());
        assert_eq!(r, n("7"));
    }

    /// Property: for random a, d — a = q*d + r with r < d.
    #[test]
    fn divrem_property() {
        let mut rng = TestRng::new(42);
        for i in 0..60usize {
            let abits = 64 + (i * 37) % 1500;
            let dbits = 1 + (i * 53) % abits;
            let a = random_biguint(&mut rng, abits);
            let mut d = random_biguint(&mut rng, dbits);
            if d.is_zero() {
                d = BigUint::one();
            }
            let (q, r) = a.divrem(&d);
            assert!(r < d, "remainder must be < divisor");
            assert_eq!(q.mul(&d).add(&r), a, "a == q*d + r");
        }
    }

    /// Regression for the Knuth-D add-back branch (rare; forced divisor).
    #[test]
    fn divrem_knuth_addback() {
        // Dividend/divisor crafted so qhat overestimates: v = 2^128 - 1,
        // u = v * (2^64 - 1) + small.
        let v = BigUint::from_u128(u128::MAX);
        let u = v.mul(&BigUint::from_u64(u64::MAX)).add(&BigUint::from_u64(3));
        let (q, r) = u.divrem(&v);
        assert_eq!(q.mul(&v).add(&r), u);
        assert!(r < v);
    }

    #[test]
    fn modpow_fermat() {
        // Fermat: a^(p-1) = 1 mod p for prime p not dividing a.
        let p = n("1000000007");
        let a = n("123456789");
        assert_eq!(a.modpow(&p.sub_u64(1), &p), BigUint::one());
        // Even modulus path.
        let m = n("1000000006");
        let got = a.modpow(&n("12345"), &m);
        // cross-check with iterated multiplication
        let mut acc = BigUint::one();
        for _ in 0..12345u32 {
            acc = acc.mul_mod(&a, &m);
        }
        assert_eq!(got, acc);
    }

    #[test]
    fn gcd_lcm() {
        assert_eq!(n("48").gcd(&n("36")), n("12"));
        assert_eq!(n("48").lcm(&n("36")), n("144"));
        assert_eq!(n("17").gcd(&n("13")), BigUint::one());
        assert_eq!(BigUint::zero().gcd(&n("5")), n("5"));
        let a = n("123456789123456789");
        let b = n("987654321987654321");
        let g = a.gcd(&b);
        assert!(a.rem(&g).is_zero() && b.rem(&g).is_zero());
    }

    #[test]
    fn modinv_property() {
        let mut rng = TestRng::new(9);
        let m = n("115792089237316195423570985008687907853269984665640564039457584007913129639747");
        for _ in 0..20 {
            let a = random_biguint(&mut rng, 200).rem(&m);
            if a.is_zero() {
                continue;
            }
            if let Some(inv) = a.modinv(&m) {
                assert_eq!(a.mul_mod(&inv, &m), BigUint::one());
            }
        }
        assert!(n("6").modinv(&n("9")).is_none(), "gcd != 1 has no inverse");
    }

    #[test]
    fn isqrt_property() {
        let mut rng = TestRng::new(3);
        for _ in 0..30 {
            let x = random_biguint(&mut rng, 300);
            let s = x.isqrt();
            assert!(s.mul(&s) <= x);
            let s1 = s.add_u64(1);
            assert!(s1.mul(&s1) > x);
        }
    }

    #[test]
    fn cmp_ordering() {
        assert!(n("100") < n("101"));
        assert!(n("18446744073709551616") > n("18446744073709551615"));
        assert_eq!(n("5").cmp(&n("5")), Ordering::Equal);
    }

    pub(crate) fn random_biguint(rng: &mut TestRng, bits: usize) -> BigUint {
        let mut bytes = vec![0u8; bits.div_ceil(8)];
        rng.fill_bytes(&mut bytes);
        if bits % 8 != 0 {
            let last = bytes.len() - 1;
            bytes[last] &= (1u8 << (bits % 8)) - 1;
        }
        BigUint::from_bytes_le(&bytes)
    }
}
