//! Montgomery-form modular arithmetic for odd moduli.
//!
//! Paillier spends virtually all of its time in `modpow` over the (odd)
//! moduli `n` and `n²`, so this is the crate's number-theoretic hot path.
//! The implementation is CIOS (coarsely integrated operand scanning)
//! Montgomery multiplication with a 4-bit fixed window exponentiation.

use super::BigUint;

/// Precomputed Montgomery context for an odd modulus `m`.
pub struct Montgomery {
    m: Vec<u64>,
    /// `-m^-1 mod 2^64`
    n0inv: u64,
    /// `R mod m` where `R = 2^(64·k)`
    r: BigUint,
    /// `R² mod m` (used to enter Montgomery form)
    r2: BigUint,
    k: usize,
}

impl Montgomery {
    /// Build a context; panics if `m` is even or zero.
    pub fn new(m: &BigUint) -> Self {
        assert!(!m.is_zero() && !m.is_even(), "Montgomery requires odd modulus");
        let k = m.limbs.len();
        let n0inv = inv64(m.limbs[0]).wrapping_neg();
        let r = BigUint::one().shl(64 * k).rem(m);
        let r2 = r.mul(&r).rem(m);
        Montgomery { m: m.limbs.clone(), n0inv, r, r2, k }
    }

    /// The modulus.
    pub fn modulus(&self) -> BigUint {
        BigUint::from_limbs(self.m.clone())
    }

    /// Montgomery product `a·b·R⁻¹ mod m` over fixed-width limb slices.
    fn mont_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let k = self.k;
        // CIOS: t has k+2 limbs.
        let mut t = vec![0u64; k + 2];
        for i in 0..k {
            // t += a[i] * b
            let ai = a[i];
            let mut carry = 0u128;
            for j in 0..k {
                let s = t[j] as u128 + ai as u128 * b[j] as u128 + carry;
                t[j] = s as u64;
                carry = s >> 64;
            }
            let s = t[k] as u128 + carry;
            t[k] = s as u64;
            t[k + 1] = (s >> 64) as u64;
            // m-reduction: u = t[0] * n0inv; t += u * m; t >>= 64
            let u = t[0].wrapping_mul(self.n0inv);
            let s = t[0] as u128 + u as u128 * self.m[0] as u128;
            let mut carry = s >> 64;
            for j in 1..k {
                let s = t[j] as u128 + u as u128 * self.m[j] as u128 + carry;
                t[j - 1] = s as u64;
                carry = s >> 64;
            }
            let s = t[k] as u128 + carry;
            t[k - 1] = s as u64;
            t[k] = t[k + 1] + ((s >> 64) as u64);
            t[k + 1] = 0;
        }
        // Conditional final subtraction: t in [0, 2m).
        t.truncate(k + 1);
        if t[k] != 0 || ge(&t[..k], &self.m) {
            sub_in_place(&mut t, &self.m);
        }
        t.truncate(k);
        t
    }

    fn to_mont(&self, a: &BigUint) -> Vec<u64> {
        let a = a.rem(&self.modulus());
        let mut al = a.limbs.clone();
        al.resize(self.k, 0);
        let mut r2 = self.r2.limbs.clone();
        r2.resize(self.k, 0);
        self.mont_mul(&al, &r2)
    }

    fn from_mont(&self, a: &[u64]) -> BigUint {
        let mut one = vec![0u64; self.k];
        one[0] = 1;
        BigUint::from_limbs(self.mont_mul(a, &one))
    }

    /// `base^exp mod m` using 4-bit fixed windows.
    pub fn pow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        if exp.is_zero() {
            return BigUint::one().rem(&self.modulus());
        }
        let bm = self.to_mont(base);
        // Precompute bm^0..bm^15 (bm^0 = R mod m).
        let mut table = Vec::with_capacity(16);
        let mut one_m = self.r.limbs.clone();
        one_m.resize(self.k, 0);
        table.push(one_m);
        for i in 1..16 {
            table.push(self.mont_mul(&table[i - 1], &bm));
        }
        let bits = exp.bit_len();
        let windows = bits.div_ceil(4);
        let mut acc = table[0].clone(); // R mod m == 1 in Montgomery form
        let mut started = false;
        for w in (0..windows).rev() {
            if started {
                for _ in 0..4 {
                    acc = self.mont_mul(&acc, &acc);
                }
            }
            let mut idx = 0usize;
            for b in 0..4 {
                let bit = w * 4 + b;
                if bit < bits && exp.bit(bit) {
                    idx |= 1 << b;
                }
            }
            if idx != 0 {
                acc = self.mont_mul(&acc, &table[idx]);
                started = true;
            } else if started {
                // window of zeros: squarings already applied
            } else {
                // leading zero windows: nothing yet
            }
        }
        if !started {
            return BigUint::one().rem(&self.modulus());
        }
        self.from_mont(&acc)
    }

    /// Montgomery-accelerated modular multiplication `a·b mod m`.
    pub fn mul(&self, a: &BigUint, b: &BigUint) -> BigUint {
        let am = self.to_mont(a);
        let mut bl = b.rem(&self.modulus()).limbs.clone();
        bl.resize(self.k, 0);
        // a·R · b · R⁻¹ = a·b
        BigUint::from_limbs(self.mont_mul(&am, &bl))
    }
}

/// Inverse of an odd `x` modulo 2^64 (Newton–Hensel lifting).
fn inv64(x: u64) -> u64 {
    debug_assert!(x & 1 == 1);
    let mut inv = x; // correct to 3 bits
    for _ in 0..5 {
        inv = inv.wrapping_mul(2u64.wrapping_sub(x.wrapping_mul(inv)));
    }
    debug_assert_eq!(x.wrapping_mul(inv), 1);
    inv
}

fn ge(a: &[u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    for i in (0..a.len()).rev() {
        if a[i] > b[i] {
            return true;
        }
        if a[i] < b[i] {
            return false;
        }
    }
    true
}

fn sub_in_place(a: &mut [u64], b: &[u64]) {
    let mut borrow = 0u64;
    for i in 0..b.len() {
        let (d1, b1) = a[i].overflowing_sub(b[i]);
        let (d2, b2) = d1.overflowing_sub(borrow);
        a[i] = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    if a.len() > b.len() {
        a[b.len()] = a[b.len()].wrapping_sub(borrow);
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::random_biguint;
    use super::*;
    use crate::testutil::TestRng;

    #[test]
    fn inv64_is_inverse() {
        for x in [1u64, 3, 5, 0xdead_beef_dead_beef | 1, u64::MAX] {
            assert_eq!(x.wrapping_mul(inv64(x)), 1);
        }
    }

    #[test]
    fn pow_matches_naive() {
        let m = BigUint::from_dec_str("1000003").unwrap();
        let mont = Montgomery::new(&m);
        let base = BigUint::from_u64(98765);
        let mut expect = BigUint::one();
        for e in 0..50u64 {
            let got = mont.pow(&base, &BigUint::from_u64(e));
            assert_eq!(got, expect, "exp={e}");
            expect = expect.mul_mod(&base, &m);
        }
    }

    #[test]
    fn pow_zero_exponent() {
        let m = BigUint::from_dec_str("999999999989").unwrap();
        let mont = Montgomery::new(&m);
        assert_eq!(mont.pow(&BigUint::from_u64(7), &BigUint::zero()), BigUint::one());
        assert_eq!(mont.pow(&BigUint::zero(), &BigUint::from_u64(5)), BigUint::zero());
    }

    /// Property: Montgomery pow == division-based square-and-multiply.
    #[test]
    fn pow_property_random() {
        let mut rng = TestRng::new(11);
        for _ in 0..8 {
            let mut m = random_biguint(&mut rng, 512);
            m.set_bit(0); // force odd
            m.set_bit(511);
            let mont = Montgomery::new(&m);
            let base = random_biguint(&mut rng, 512);
            let exp = random_biguint(&mut rng, 64);
            // reference: square-and-multiply with divrem reduction
            let b = base.rem(&m);
            let mut acc = BigUint::one();
            for i in (0..exp.bit_len()).rev() {
                acc = acc.mul_mod(&acc, &m);
                if exp.bit(i) {
                    acc = acc.mul_mod(&b, &m);
                }
            }
            assert_eq!(mont.pow(&base, &exp), acc);
        }
    }

    #[test]
    fn mul_matches_mul_mod() {
        let mut rng = TestRng::new(13);
        let mut m = random_biguint(&mut rng, 256);
        m.set_bit(0);
        m.set_bit(255);
        let mont = Montgomery::new(&m);
        for _ in 0..20 {
            let a = random_biguint(&mut rng, 256);
            let b = random_biguint(&mut rng, 256);
            assert_eq!(mont.mul(&a, &b), a.mul_mod(&b, &m));
        }
    }
}
