//! Montgomery-form modular arithmetic for odd moduli.
//!
//! Paillier spends virtually all of its time in `modpow` over the (odd)
//! moduli `n` and `n²`, so this is the crate's number-theoretic hot path.
//! The implementation is CIOS (coarsely integrated operand scanning)
//! Montgomery multiplication with four exponentiation strategies layered
//! on top:
//!
//! * [`Montgomery::pow`] / [`Montgomery::pow_elem`] — 4-bit fixed-window
//!   exponentiation for general (base, exponent) pairs, with a plain
//!   square-and-multiply fast path for short exponents (≤ 16 bits) that
//!   skips building the window table — the common case for
//!   PrivLogit-Local's small signed multiply-by-constant exponents.
//! * [`Montgomery::fixed_base`] / [`Montgomery::pow_fixed`] — one-time
//!   radix-2^w precomputation for a base that is reused across many
//!   exponentiations (Paillier's `h_n` under one public key), turning
//!   each exponentiation into ~`bits/w` multiplications with **zero**
//!   squarings.
//! * [`Montgomery::multi_pow`] — Straus/Shamir simultaneous
//!   multi-exponentiation `∏ bᵢ^eᵢ` with 2-bit windows per term
//!   ([`StrausTable`]), sharing one squaring chain across all terms of a
//!   product — the `Enc(H̃⁻¹) ⊗ g` row primitive.
//! * [`MontElem`] — values resident in Montgomery form, so batch
//!   algebra (ciphertext aggregation folds, precomputed tables) enters
//!   and leaves the Montgomery domain exactly once instead of on every
//!   multiplication.

use super::BigUint;

/// Exponent bit-length at or below which [`Montgomery::pow_elem`] uses
/// plain square-and-multiply instead of building the 16-entry window
/// table (the table's 15 setup multiplications dominate short chains).
const SMALL_EXP_BITS: usize = 16;

/// Window width (bits) of [`Montgomery::fixed_base`] tables. Each
/// exponentiation costs ~`bits/FIXED_BASE_WINDOW` multiplications; the
/// table holds `⌈bits/w⌉·(2^w − 1)` residues (≈ 700 KB for a 256-bit
/// exponent range over a 2048-bit modulus at w = 6).
const FIXED_BASE_WINDOW: usize = 6;

/// A value of `Z_m` held in Montgomery form (`a·R mod m`, fixed-width
/// limbs). Produced by [`Montgomery::enter`]; all element operations are
/// methods on the owning [`Montgomery`] context, and mixing elements
/// across contexts is a logic error the type system does not catch.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct MontElem {
    limbs: Vec<u64>,
}

/// 2-bit window table for one base of a [`Montgomery::multi_pow`]:
/// `b, b², b³`, Montgomery-resident. Build once per base with
/// [`Montgomery::straus_table`]; reusable across any number of
/// multi-exponentiations (e.g. every row and every iteration that
/// touches one `Enc(H̃⁻¹)` triangle entry).
pub struct StrausTable {
    pw: [MontElem; 3],
}

impl StrausTable {
    /// The base `b` itself (Montgomery-resident) — e.g. to recover the
    /// plain value via [`Montgomery::exit`] when building an
    /// inverse-base table.
    pub fn base(&self) -> &MontElem {
        &self.pw[0]
    }
}

/// Fixed-base exponentiation table: `table[w][d−1] = b^(d·2^(w·W))` in
/// Montgomery form, for window digits `d ∈ 1..2^W`. See
/// [`Montgomery::fixed_base`].
pub struct FixedBase {
    table: Vec<Vec<MontElem>>,
    max_bits: usize,
}

impl FixedBase {
    /// Largest exponent bit-length this table covers.
    pub fn max_bits(&self) -> usize {
        self.max_bits
    }
}

/// Precomputed Montgomery context for an odd modulus `m`.
pub struct Montgomery {
    m: Vec<u64>,
    /// `-m^-1 mod 2^64`
    n0inv: u64,
    /// `R mod m` where `R = 2^(64·k)`
    r: BigUint,
    /// `R² mod m` (used to enter Montgomery form)
    r2: BigUint,
    k: usize,
}

impl Montgomery {
    /// Build a context; panics if `m` is even or zero.
    pub fn new(m: &BigUint) -> Self {
        assert!(!m.is_zero() && !m.is_even(), "Montgomery requires odd modulus");
        let k = m.limbs.len();
        let n0inv = inv64(m.limbs[0]).wrapping_neg();
        let r = BigUint::one().shl(64 * k).rem(m);
        let r2 = r.mul(&r).rem(m);
        Montgomery { m: m.limbs.clone(), n0inv, r, r2, k }
    }

    /// The modulus.
    pub fn modulus(&self) -> BigUint {
        BigUint::from_limbs(self.m.clone())
    }

    /// Montgomery product `a·b·R⁻¹ mod m` over fixed-width limb slices.
    fn mont_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let k = self.k;
        // CIOS: t has k+2 limbs.
        let mut t = vec![0u64; k + 2];
        for i in 0..k {
            // t += a[i] * b
            let ai = a[i];
            let mut carry = 0u128;
            for j in 0..k {
                let s = t[j] as u128 + ai as u128 * b[j] as u128 + carry;
                t[j] = s as u64;
                carry = s >> 64;
            }
            let s = t[k] as u128 + carry;
            t[k] = s as u64;
            t[k + 1] = (s >> 64) as u64;
            // m-reduction: u = t[0] * n0inv; t += u * m; t >>= 64
            let u = t[0].wrapping_mul(self.n0inv);
            let s = t[0] as u128 + u as u128 * self.m[0] as u128;
            let mut carry = s >> 64;
            for j in 1..k {
                let s = t[j] as u128 + u as u128 * self.m[j] as u128 + carry;
                t[j - 1] = s as u64;
                carry = s >> 64;
            }
            let s = t[k] as u128 + carry;
            t[k - 1] = s as u64;
            t[k] = t[k + 1] + ((s >> 64) as u64);
            t[k + 1] = 0;
        }
        // Conditional final subtraction: t in [0, 2m).
        t.truncate(k + 1);
        if t[k] != 0 || ge(&t[..k], &self.m) {
            sub_in_place(&mut t, &self.m);
        }
        t.truncate(k);
        t
    }

    fn to_mont(&self, a: &BigUint) -> Vec<u64> {
        let a = a.rem(&self.modulus());
        let mut al = a.limbs.clone();
        al.resize(self.k, 0);
        let mut r2 = self.r2.limbs.clone();
        r2.resize(self.k, 0);
        self.mont_mul(&al, &r2)
    }

    fn from_mont(&self, a: &[u64]) -> BigUint {
        let mut one = vec![0u64; self.k];
        one[0] = 1;
        BigUint::from_limbs(self.mont_mul(a, &one))
    }

    /// Bring a value into Montgomery form (one reduction + one
    /// Montgomery multiplication). The inverse is [`Montgomery::exit`].
    pub fn enter(&self, a: &BigUint) -> MontElem {
        MontElem { limbs: self.to_mont(a) }
    }

    /// Leave Montgomery form, returning the canonical residue `< m`.
    pub fn exit(&self, a: &MontElem) -> BigUint {
        self.from_mont(&a.limbs)
    }

    /// The multiplicative identity in Montgomery form (`R mod m`).
    pub fn one_elem(&self) -> MontElem {
        let mut limbs = self.r.limbs.clone();
        limbs.resize(self.k, 0);
        MontElem { limbs }
    }

    /// Montgomery-domain product: both operands and the result stay
    /// resident (`aR · bR · R⁻¹ = abR`). One CIOS pass, no divisions.
    pub fn mul_elem(&self, a: &MontElem, b: &MontElem) -> MontElem {
        MontElem { limbs: self.mont_mul(&a.limbs, &b.limbs) }
    }

    /// Mixed product `a·b mod m` of a resident element and a plain
    /// value: the `R` factors cancel (`aR · b · R⁻¹ = ab`), so this is
    /// the natural *exit* multiplication at a batch boundary — one CIOS
    /// pass replaces an `exit` plus a plain multiplication.
    pub fn mul_elem_plain(&self, a: &MontElem, b: &BigUint) -> BigUint {
        let mut bl = b.rem(&self.modulus()).limbs;
        bl.resize(self.k, 0);
        BigUint::from_limbs(self.mont_mul(&a.limbs, &bl))
    }

    /// `base^exp mod m` (general path: 4-bit fixed windows, with the
    /// short-exponent fast path of [`Montgomery::pow_elem`]).
    pub fn pow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        if exp.is_zero() {
            return BigUint::one().rem(&self.modulus());
        }
        self.exit(&self.pow_elem(&self.enter(base), exp))
    }

    /// `base^exp` over a Montgomery-resident base, resident result.
    ///
    /// Exponents of ≤ [`SMALL_EXP_BITS`] bits take a table-free plain
    /// square-and-multiply (the 15 setup multiplications of the window
    /// table would dominate such short chains); longer exponents use
    /// 4-bit fixed windows.
    pub fn pow_elem(&self, base: &MontElem, exp: &BigUint) -> MontElem {
        let bits = exp.bit_len();
        if bits == 0 {
            return self.one_elem();
        }
        if bits <= SMALL_EXP_BITS {
            // Top bit is always set: start from the base itself.
            let mut acc = base.clone();
            for i in (0..bits - 1).rev() {
                acc = self.mul_elem(&acc, &acc);
                if exp.bit(i) {
                    acc = self.mul_elem(&acc, base);
                }
            }
            return acc;
        }
        // Precompute base^0..base^15 (base^0 = R mod m).
        let mut table = Vec::with_capacity(16);
        table.push(self.one_elem());
        for i in 1..16 {
            let next = self.mul_elem(&table[i - 1], base);
            table.push(next);
        }
        let windows = bits.div_ceil(4);
        let mut acc: Option<MontElem> = None;
        for w in (0..windows).rev() {
            if let Some(a) = &mut acc {
                for _ in 0..4 {
                    *a = self.mul_elem(a, a);
                }
            }
            let mut idx = 0usize;
            for b in 0..4 {
                let bit = w * 4 + b;
                if bit < bits && exp.bit(bit) {
                    idx |= 1 << b;
                }
            }
            if idx != 0 {
                acc = Some(match acc.take() {
                    None => table[idx].clone(),
                    Some(a) => self.mul_elem(&a, &table[idx]),
                });
            }
        }
        acc.unwrap_or_else(|| self.one_elem())
    }

    /// Build a fixed-base table covering exponents up to `max_bits`
    /// bits: `table[w][d−1] = base^(d·2^(w·W))` for every window `w` and
    /// digit `d`. One-time cost ≈ `⌈max_bits/W⌉·2^W` multiplications;
    /// afterwards [`Montgomery::pow_fixed`] needs **no squarings**.
    pub fn fixed_base(&self, base: &BigUint, max_bits: usize) -> FixedBase {
        let d_max = (1usize << FIXED_BASE_WINDOW) - 1;
        let nwin = max_bits.div_ceil(FIXED_BASE_WINDOW).max(1);
        let mut table = Vec::with_capacity(nwin);
        let mut g = self.enter(base);
        for w in 0..nwin {
            let mut row = Vec::with_capacity(d_max);
            row.push(g.clone());
            for _ in 2..=d_max {
                let next = self.mul_elem(row.last().expect("row nonempty"), &g);
                row.push(next);
            }
            if w + 1 < nwin {
                // g^(2^W) = g^(2^W − 1) · g — one multiply, no squarings.
                g = self.mul_elem(row.last().expect("row nonempty"), &g);
            }
            table.push(row);
        }
        FixedBase { table, max_bits: nwin * FIXED_BASE_WINDOW }
    }

    /// Fixed-base exponentiation: `∏_w table[w][digit_w]`, i.e. one
    /// multiplication per nonzero radix-2^W digit of `exp` and nothing
    /// else. Panics if `exp` exceeds the table's range.
    pub fn pow_fixed(&self, fb: &FixedBase, exp: &BigUint) -> MontElem {
        assert!(
            exp.bit_len() <= fb.max_bits,
            "fixed-base exponent of {} bits exceeds table range {}",
            exp.bit_len(),
            fb.max_bits
        );
        let mut acc: Option<MontElem> = None;
        for (w, row) in fb.table.iter().enumerate() {
            let mut d = 0usize;
            for b in 0..FIXED_BASE_WINDOW {
                if exp.bit(w * FIXED_BASE_WINDOW + b) {
                    d |= 1 << b;
                }
            }
            if d != 0 {
                acc = Some(match acc.take() {
                    None => row[d - 1].clone(),
                    Some(a) => self.mul_elem(&a, &row[d - 1]),
                });
            }
        }
        acc.unwrap_or_else(|| self.one_elem())
    }

    /// 2-bit window table `b, b², b³` for one [`Montgomery::multi_pow`]
    /// base (two multiplications).
    pub fn straus_table(&self, b: &MontElem) -> StrausTable {
        let b2 = self.mul_elem(b, b);
        let b3 = self.mul_elem(&b2, b);
        StrausTable { pw: [b.clone(), b2, b3] }
    }

    /// Straus/Shamir simultaneous multi-exponentiation `∏ᵢ bᵢ^eᵢ`
    /// (resident result): one shared squaring chain over the longest
    /// exponent, plus per-term window multiplications — versus one full
    /// squaring chain *per term* for repeated [`Montgomery::pow`]. The
    /// small-constant exponents of `Enc(H̃⁻¹) ⊗ g` fit easily in `u128`;
    /// zero-exponent terms are skipped.
    pub fn multi_pow(&self, terms: &[(&StrausTable, u128)]) -> MontElem {
        let maxbits =
            terms.iter().map(|&(_, e)| 128 - e.leading_zeros() as usize).max().unwrap_or(0);
        if maxbits == 0 {
            return self.one_elem();
        }
        let windows = maxbits.div_ceil(2);
        let mut acc: Option<MontElem> = None;
        for w in (0..windows).rev() {
            if let Some(a) = &mut acc {
                let s = self.mul_elem(a, a);
                *a = self.mul_elem(&s, &s);
            }
            for &(tab, e) in terms {
                let d = ((e >> (2 * w)) & 3) as usize;
                if d != 0 {
                    acc = Some(match acc.take() {
                        None => tab.pw[d - 1].clone(),
                        Some(a) => self.mul_elem(&a, &tab.pw[d - 1]),
                    });
                }
            }
        }
        acc.unwrap_or_else(|| self.one_elem())
    }

    /// Montgomery-accelerated modular multiplication `a·b mod m`.
    pub fn mul(&self, a: &BigUint, b: &BigUint) -> BigUint {
        self.mul_elem_plain(&self.enter(a), b)
    }
}

/// Inverse of an odd `x` modulo 2^64 (Newton–Hensel lifting).
fn inv64(x: u64) -> u64 {
    debug_assert!(x & 1 == 1);
    let mut inv = x; // correct to 3 bits
    for _ in 0..5 {
        inv = inv.wrapping_mul(2u64.wrapping_sub(x.wrapping_mul(inv)));
    }
    debug_assert_eq!(x.wrapping_mul(inv), 1);
    inv
}

fn ge(a: &[u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    for i in (0..a.len()).rev() {
        if a[i] > b[i] {
            return true;
        }
        if a[i] < b[i] {
            return false;
        }
    }
    true
}

fn sub_in_place(a: &mut [u64], b: &[u64]) {
    let mut borrow = 0u64;
    for i in 0..b.len() {
        let (d1, b1) = a[i].overflowing_sub(b[i]);
        let (d2, b2) = d1.overflowing_sub(borrow);
        a[i] = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    if a.len() > b.len() {
        a[b.len()] = a[b.len()].wrapping_sub(borrow);
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::random_biguint;
    use super::*;
    use crate::testutil::TestRng;

    #[test]
    fn inv64_is_inverse() {
        for x in [1u64, 3, 5, 0xdead_beef_dead_beef | 1, u64::MAX] {
            assert_eq!(x.wrapping_mul(inv64(x)), 1);
        }
    }

    #[test]
    fn pow_matches_naive() {
        let m = BigUint::from_dec_str("1000003").unwrap();
        let mont = Montgomery::new(&m);
        let base = BigUint::from_u64(98765);
        let mut expect = BigUint::one();
        for e in 0..50u64 {
            let got = mont.pow(&base, &BigUint::from_u64(e));
            assert_eq!(got, expect, "exp={e}");
            expect = expect.mul_mod(&base, &m);
        }
    }

    #[test]
    fn pow_zero_exponent() {
        let m = BigUint::from_dec_str("999999999989").unwrap();
        let mont = Montgomery::new(&m);
        assert_eq!(mont.pow(&BigUint::from_u64(7), &BigUint::zero()), BigUint::one());
        assert_eq!(mont.pow(&BigUint::zero(), &BigUint::from_u64(5)), BigUint::zero());
    }

    /// Property: Montgomery pow == division-based square-and-multiply,
    /// across the small-exponent fast path (< 16 bits) and the windowed
    /// path.
    #[test]
    fn pow_property_random() {
        let mut rng = TestRng::new(11);
        for round in 0..12 {
            let mut m = random_biguint(&mut rng, 512);
            m.set_bit(0); // force odd
            m.set_bit(511);
            let mont = Montgomery::new(&m);
            let base = random_biguint(&mut rng, 512);
            let exp_bits = [3, 8, 15, 16, 17, 64][round % 6];
            let exp = random_biguint(&mut rng, exp_bits);
            // reference: square-and-multiply with divrem reduction
            let b = base.rem(&m);
            let mut acc = BigUint::one();
            for i in (0..exp.bit_len()).rev() {
                acc = acc.mul_mod(&acc, &m);
                if exp.bit(i) {
                    acc = acc.mul_mod(&b, &m);
                }
            }
            assert_eq!(mont.pow(&base, &exp), acc, "exp_bits={exp_bits}");
        }
    }

    #[test]
    fn mul_matches_mul_mod() {
        let mut rng = TestRng::new(13);
        let mut m = random_biguint(&mut rng, 256);
        m.set_bit(0);
        m.set_bit(255);
        let mont = Montgomery::new(&m);
        for _ in 0..20 {
            let a = random_biguint(&mut rng, 256);
            let b = random_biguint(&mut rng, 256);
            assert_eq!(mont.mul(&a, &b), a.mul_mod(&b, &m));
        }
    }

    #[test]
    fn enter_exit_roundtrip() {
        let mut rng = TestRng::new(17);
        let mut m = random_biguint(&mut rng, 320);
        m.set_bit(0);
        m.set_bit(319);
        let mont = Montgomery::new(&m);
        for _ in 0..10 {
            let a = random_biguint(&mut rng, 400);
            assert_eq!(mont.exit(&mont.enter(&a)), a.rem(&m));
        }
        assert_eq!(mont.exit(&mont.one_elem()), BigUint::one());
    }

    #[test]
    fn mul_elem_stays_resident() {
        let mut rng = TestRng::new(19);
        let mut m = random_biguint(&mut rng, 256);
        m.set_bit(0);
        m.set_bit(255);
        let mont = Montgomery::new(&m);
        let a = random_biguint(&mut rng, 256);
        let b = random_biguint(&mut rng, 256);
        let c = random_biguint(&mut rng, 256);
        // (a·b)·c through resident chain == plain mul_mod chain.
        let ab = mont.mul_elem(&mont.enter(&a), &mont.enter(&b));
        let abc = mont.mul_elem_plain(&ab, &c);
        assert_eq!(abc, a.mul_mod(&b, &m).mul_mod(&c, &m));
    }

    /// Fixed-base exponentiation must agree with the general path for
    /// every exponent in range, including zero and the table edge.
    #[test]
    fn fixed_base_matches_pow() {
        let mut rng = TestRng::new(23);
        let mut m = random_biguint(&mut rng, 512);
        m.set_bit(0);
        m.set_bit(511);
        let mont = Montgomery::new(&m);
        let base = random_biguint(&mut rng, 512).rem(&m);
        let fb = mont.fixed_base(&base, 128);
        assert!(fb.max_bits() >= 128);
        assert_eq!(mont.exit(&mont.pow_fixed(&fb, &BigUint::zero())), BigUint::one());
        for bits in [1usize, 5, 13, 40, 127] {
            let e = random_biguint(&mut rng, bits);
            assert_eq!(
                mont.exit(&mont.pow_fixed(&fb, &e)),
                mont.pow(&base, &e),
                "bits={bits}"
            );
        }
        // All-ones exponent exercises every table row.
        let mut e = BigUint::zero();
        for i in 0..128 {
            e.set_bit(i);
        }
        assert_eq!(mont.exit(&mont.pow_fixed(&fb, &e)), mont.pow(&base, &e));
    }

    /// Straus multi-exponentiation == product of independent pows.
    #[test]
    fn multi_pow_matches_pow_product() {
        let mut rng = TestRng::new(29);
        let mut m = random_biguint(&mut rng, 384);
        m.set_bit(0);
        m.set_bit(383);
        let mont = Montgomery::new(&m);
        for terms_n in [0usize, 1, 3, 7] {
            let bases: Vec<BigUint> =
                (0..terms_n).map(|_| random_biguint(&mut rng, 384).rem(&m)).collect();
            let exps: Vec<u128> = (0..terms_n)
                .map(|i| {
                    if i == 0 {
                        0 // zero-exponent terms must be skipped
                    } else {
                        (rng.next_u64() >> (i * 7)) as u128
                    }
                })
                .collect();
            let tabs: Vec<StrausTable> =
                bases.iter().map(|b| mont.straus_table(&mont.enter(b))).collect();
            let term_refs: Vec<(&StrausTable, u128)> =
                tabs.iter().zip(&exps).map(|(t, &e)| (t, e)).collect();
            let got = mont.exit(&mont.multi_pow(&term_refs));
            let mut expect = BigUint::one();
            for (b, &e) in bases.iter().zip(&exps) {
                expect = expect.mul_mod(&mont.pow(b, &BigUint::from_u128(e)), &m);
            }
            assert_eq!(got, expect, "terms={terms_n}");
        }
    }
}
