//! Minimal signed big integer — just enough for the extended Euclidean
//! algorithm ([`BigInt::ext_gcd`]) behind [`super::BigUint::modinv`], and
//! for signed fixed-point plumbing in the crypto layer.

use super::BigUint;
use std::cmp::Ordering;

/// Sign-magnitude arbitrary-precision integer.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct BigInt {
    /// `false` = non-negative. Zero is always non-negative.
    negative: bool,
    mag: BigUint,
}

impl BigInt {
    /// Zero.
    pub fn zero() -> Self {
        BigInt { negative: false, mag: BigUint::zero() }
    }

    /// One.
    pub fn one() -> Self {
        BigInt { negative: false, mag: BigUint::one() }
    }

    /// Non-negative integer from a magnitude.
    pub fn from_biguint(mag: BigUint) -> Self {
        BigInt { negative: false, mag }
    }

    /// From an `i64`.
    pub fn from_i64(v: i64) -> Self {
        BigInt { negative: v < 0, mag: BigUint::from_u64(v.unsigned_abs()) }
    }

    /// Magnitude (absolute value).
    pub fn magnitude(&self) -> &BigUint {
        &self.mag
    }

    /// True iff negative (zero is non-negative).
    pub fn is_negative(&self) -> bool {
        self.negative && !self.mag.is_zero()
    }

    /// True iff zero.
    pub fn is_zero(&self) -> bool {
        self.mag.is_zero()
    }

    fn normalized(negative: bool, mag: BigUint) -> Self {
        BigInt { negative: negative && !mag.is_zero(), mag }
    }

    /// Negation.
    pub fn neg(&self) -> Self {
        Self::normalized(!self.negative, self.mag.clone())
    }

    /// Addition.
    pub fn add(&self, other: &BigInt) -> BigInt {
        if self.negative == other.negative {
            Self::normalized(self.negative, self.mag.add(&other.mag))
        } else {
            match self.mag.cmp(&other.mag) {
                Ordering::Greater => Self::normalized(self.negative, self.mag.sub(&other.mag)),
                Ordering::Less => Self::normalized(other.negative, other.mag.sub(&self.mag)),
                Ordering::Equal => BigInt::zero(),
            }
        }
    }

    /// Subtraction.
    pub fn sub(&self, other: &BigInt) -> BigInt {
        self.add(&other.neg())
    }

    /// Multiplication.
    pub fn mul(&self, other: &BigInt) -> BigInt {
        Self::normalized(self.negative != other.negative, self.mag.mul(&other.mag))
    }

    /// Extended GCD: returns `(g, x, y)` with `a·x + b·y = g = gcd(a, b)`,
    /// `g ≥ 0`.
    pub fn ext_gcd(a: &BigInt, b: &BigInt) -> (BigInt, BigInt, BigInt) {
        let (mut old_r, mut r) = (a.clone(), b.clone());
        let (mut old_s, mut s) = (BigInt::one(), BigInt::zero());
        let (mut old_t, mut t) = (BigInt::zero(), BigInt::one());
        while !r.is_zero() {
            let (q, rem) = old_r.divrem(&r);
            old_r = std::mem::replace(&mut r, rem);
            let ns = old_s.sub(&q.mul(&s));
            old_s = std::mem::replace(&mut s, ns);
            let nt = old_t.sub(&q.mul(&t));
            old_t = std::mem::replace(&mut t, nt);
        }
        // gcd sign: make non-negative, flipping coefficients accordingly.
        if old_r.is_negative() {
            (old_r.neg(), old_s.neg(), old_t.neg())
        } else {
            (old_r, old_s, old_t)
        }
    }

    /// Truncated division (quotient rounds toward zero), like Rust `i64`.
    pub fn divrem(&self, other: &BigInt) -> (BigInt, BigInt) {
        let (q, r) = self.mag.divrem(&other.mag);
        (
            Self::normalized(self.negative != other.negative, q),
            Self::normalized(self.negative, r),
        )
    }

    /// Euclidean remainder in `[0, m)` for a positive modulus `m`.
    pub fn rem_euclid(&self, m: &BigUint) -> BigUint {
        let r = self.mag.rem(m);
        if self.is_negative() && !r.is_zero() {
            m.sub(&r)
        } else {
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn i(v: i64) -> BigInt {
        BigInt::from_i64(v)
    }

    #[test]
    fn signed_arith_matches_i64() {
        let cases = [(5i64, 3i64), (-5, 3), (5, -3), (-5, -3), (0, 7), (7, 0), (-7, 7)];
        for (a, b) in cases {
            assert_eq!(i(a).add(&i(b)), i(a + b), "{a}+{b}");
            assert_eq!(i(a).sub(&i(b)), i(a - b), "{a}-{b}");
            assert_eq!(i(a).mul(&i(b)), i(a * b), "{a}*{b}");
        }
    }

    #[test]
    fn divrem_truncates_toward_zero() {
        for (a, b) in [(7i64, 3i64), (-7, 3), (7, -3), (-7, -3)] {
            let (q, r) = i(a).divrem(&i(b));
            assert_eq!(q, i(a / b), "{a}/{b}");
            assert_eq!(r, i(a % b), "{a}%{b}");
        }
    }

    #[test]
    fn ext_gcd_bezout() {
        for (a, b) in [(240i64, 46i64), (46, 240), (-240, 46), (17, 0), (0, 17), (12, 18)] {
            let (g, x, y) = BigInt::ext_gcd(&i(a), &i(b));
            let lhs = i(a).mul(&x).add(&i(b).mul(&y));
            assert_eq!(lhs, g, "bezout for ({a},{b})");
            assert!(!g.is_negative());
        }
    }

    #[test]
    fn rem_euclid_in_range() {
        let m = BigUint::from_u64(7);
        assert_eq!(i(-1).rem_euclid(&m), BigUint::from_u64(6));
        assert_eq!(i(-14).rem_euclid(&m), BigUint::zero());
        assert_eq!(i(10).rem_euclid(&m), BigUint::from_u64(3));
    }
}
