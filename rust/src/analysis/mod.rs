//! Machine-checked secrecy and protocol-invariant audit.
//!
//! `privlogit audit [--json] [SRC_DIR]` runs four lexical rules over
//! the crate's Rust sources (no `syn`, no proc-macro — a hand-rolled
//! lexer in [`lexer`] feeds the rule engine in [`rules`]):
//!
//! * `secret-flow` — secret types ([`rules::BASE_SECRETS`] plus any
//!   type tagged `// audit:secret`) must not derive or hand-roll a
//!   field-dumping `Debug`/`Display`, and must never be named on a
//!   line that feeds a log, trace-span or wire-codec sink.
//! * `panic-free` — no `unwrap`/`expect`/panicking macro/assert/
//!   unchecked indexing in non-test code of the remote-input files
//!   ([`rules::PANIC_SCOPE`]): a malformed frame must fail the
//!   session, not the process.
//! * `wire-tags` — every `TAG_*` constant has a `tag_name()` arm, an
//!   arm in `fn tag()`, round-trip test coverage, and a documented
//!   value in docs/ARCHITECTURE.md.
//! * `span-schema` — every `span("…")` name is in the timeline's
//!   `KNOWN_SPANS` vocabulary and the docs taxonomy; every
//!   `privlogit-*/vN` schema string is version-consistent and
//!   documented.
//!
//! A finding is suppressed by a plain comment `// audit:allow(RULE):
//! reason` on (or directly above) the offending line; attached to an
//! `fn` signature it covers the whole body. The reason is mandatory,
//! and a malformed or unknown-rule allow is itself a finding (rule
//! `audit-allow`) — a suppression that fails open would defeat the
//! audit. `#[cfg(test)]` regions and files under `tests/` are exempt
//! from `secret-flow`/`panic-free`, but their string literals still
//! feed the schema census so tests cannot bake in undocumented
//! schemas.

use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

pub mod lexer;
pub mod report;
pub mod rules;

/// Schema tag of the `--json` report document.
pub const AUDIT_SCHEMA: &str = "privlogit-audit/v1";

/// Every rule name, including the meta-rule that polices the allow
/// grammar itself. `audit:allow(RULE)` must name one of these.
pub const RULES: &[&str] =
    &["audit-allow", "panic-free", "secret-flow", "span-schema", "wire-tags"];

/// One audit finding. Field order gives the sort order: by file, then
/// line, then rule, so reports are deterministic and diffable.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Path relative to the audit root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: usize,
    /// The rule that fired (one of [`RULES`]).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

/// The result of auditing one source tree.
#[derive(Clone, Debug)]
pub struct AuditReport {
    /// Sorted findings (empty means the tree is clean).
    pub findings: Vec<Finding>,
    /// How many `.rs` files were scanned.
    pub files_scanned: usize,
    /// Whether a docs/ARCHITECTURE.md was found for the doc checks
    /// (absent for fixture trees — those checks are skipped).
    pub doc_found: bool,
}

impl AuditReport {
    /// Compiler-style `file:line: rule: message` text plus a summary.
    pub fn render_human(&self) -> String {
        report::render_human(self)
    }

    /// The `privlogit-audit/v1` JSON document.
    pub fn render_json(&self) -> String {
        report::render_json(self)
    }
}

/// Run every rule over in-memory sources (`(relpath, text)` pairs).
/// Disk-free core of [`audit`], used directly by the unit tests.
pub fn audit_sources(files: &[(String, String)], doc: Option<&str>) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut lexed: Vec<(String, lexer::Lexed)> = Vec::new();
    for (rel, src) in files {
        let mut lx = lexer::lex(src);
        lexer::mark_cfg_test(&mut lx);
        if rel.starts_with("tests/") || rel.contains("/tests/") {
            for ln in 1..=lx.blanked.len() {
                lx.is_test.insert(ln);
            }
        }
        lexer::attach_allows(&mut lx, rel, &mut findings);
        lexed.push((rel.clone(), lx));
    }
    // Secrets are a tree-wide set: a type tagged in one file stays
    // secret when another file names it on a sink line.
    let mut secrets: BTreeSet<String> = BTreeSet::new();
    for s in rules::BASE_SECRETS {
        secrets.insert(s.to_string());
    }
    for (_, lx) in &lexed {
        secrets.extend(lx.secrets.iter().cloned());
    }
    let mut acc = rules::SpanAcc::default();
    for (rel, lx) in &lexed {
        rules::secret_flow(rel, lx, &secrets, &mut findings);
        rules::panic_free(rel, lx, &mut findings);
        rules::wire_tags(rel, lx, doc, &mut findings);
        rules::collect_spans_schemas(rel, lx, &mut acc);
    }
    rules::span_schema(&acc, doc, &mut findings);
    findings.sort();
    findings
}

fn collect_rs(dir: &Path, base: &Path, out: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let path = entry.path();
        if path.is_dir() {
            // Fixture trees are deliberately dirty; `target/` and dot
            // dirs are build products.
            if name.starts_with('.') || name == "target" || name == "audit_fixtures" {
                continue;
            }
            collect_rs(&path, base, out)?;
        } else if name.ends_with(".rs") {
            let rel = path.strip_prefix(base).unwrap_or(&path).to_string_lossy().to_string();
            out.push((rel, path));
        }
    }
    Ok(())
}

/// Locate docs/ARCHITECTURE.md: beside the audit root, or up to two
/// directories above it (the crate lives one level below the repo
/// root). Fixture roots find none, which skips the doc checks there.
fn find_doc(root: &Path) -> Option<String> {
    let mut dir = root.canonicalize().ok()?;
    for _ in 0..3 {
        let cand = dir.join("docs").join("ARCHITECTURE.md");
        if cand.is_file() {
            return fs::read_to_string(cand).ok();
        }
        dir = dir.parent()?.to_path_buf();
    }
    None
}

/// Audit the source tree at `root`. When `root/Cargo.toml` exists the
/// scan covers `src/`, `benches/` and `tests/`; otherwise every `.rs`
/// file under `root` recursively.
pub fn audit(root: &Path) -> anyhow::Result<AuditReport> {
    let mut paths: Vec<(String, PathBuf)> = Vec::new();
    if root.join("Cargo.toml").is_file() {
        for sub in ["src", "benches", "tests"] {
            let dir = root.join(sub);
            if dir.is_dir() {
                collect_rs(&dir, root, &mut paths)
                    .with_context(|| format!("scanning {}", dir.display()))?;
            }
        }
    } else {
        collect_rs(root, root, &mut paths)
            .with_context(|| format!("scanning {}", root.display()))?;
    }
    let mut sources: Vec<(String, String)> = Vec::new();
    for (rel, path) in paths {
        let src = fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        sources.push((rel, src));
    }
    let doc = find_doc(root);
    let findings = audit_sources(&sources, doc.as_deref());
    Ok(AuditReport { findings, files_scanned: sources.len(), doc_found: doc.is_some() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(relpath: &str, src: &str) -> Vec<Finding> {
        audit_sources(&[(relpath.to_string(), src.to_string())], None)
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "fn f() -> String {\n    let s = \"call .unwrap() now\"; // .unwrap() too\n    s.to_string()\n}\n";
        assert!(run_one("net/wire.rs", src).is_empty());
    }

    #[test]
    fn panic_free_catches_each_category() {
        let src = "fn f(b: &[u8]) {\n    let v = b.first().unwrap();\n    let w = b.first().expect(\"w\");\n    panic!(\"no\");\n    assert!(b.is_empty());\n    let x = b[0];\n}\n";
        let found = run_one("net/wire.rs", src);
        assert_eq!(found.len(), 5, "{found:?}");
        assert!(found.iter().all(|f| f.rule == "panic-free"));
        assert_eq!(found.iter().map(|f| f.line).collect::<Vec<_>>(), vec![2, 3, 4, 5, 6]);
    }

    #[test]
    fn panic_free_only_applies_in_scope() {
        let src = "fn f(b: &[u8]) -> u8 {\n    b[0]\n}\n";
        assert!(run_one("protocols/newton.rs", src).is_empty());
        assert_eq!(run_one("net/tcp.rs", src).len(), 1);
    }

    #[test]
    fn cfg_test_region_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(b: &[u8]) -> u8 {\n        b[0]\n    }\n}\n";
        assert!(run_one("net/wire.rs", src).is_empty());
    }

    #[test]
    fn allow_suppresses_line_and_fn_block() {
        let line = "fn f(b: &[u8]) -> u8 {\n    // audit:allow(panic-free): caller checked\n    b[0]\n}\n";
        assert!(run_one("net/wire.rs", line).is_empty());
        let block = "// audit:allow(panic-free): whole fn is send-path\nfn f(b: &[u8]) -> u8 {\n    let x = b[0];\n    x\n}\n";
        assert!(run_one("net/wire.rs", block).is_empty());
    }

    #[test]
    fn allow_without_reason_is_a_finding() {
        let src = "fn f(b: &[u8]) -> u8 {\n    // audit:allow(panic-free)\n    b[0]\n}\n";
        let found = run_one("net/wire.rs", src);
        assert_eq!(found.len(), 2, "{found:?}");
        assert_eq!(found[0].rule, "audit-allow");
        assert_eq!(found[1].rule, "panic-free");
    }

    #[test]
    fn doc_comments_do_not_arm_the_allow_grammar() {
        let src = "//! Mentions audit:allow(RULE): reason in docs.\nfn f() {}\n";
        assert!(run_one("net/wire.rs", src).is_empty());
    }

    #[test]
    fn secret_flow_catches_derive_and_sink() {
        let src = "#[derive(Clone, Debug)]\npub struct PrivateKey {\n    pub lambda: u64,\n}\nfn log_it(k: &PrivateKey) { crate::obs::info(format_args!(\"{}\", k.lambda)); }\n";
        let found = run_one("crypto/keys.rs", src);
        assert_eq!(found.len(), 2, "{found:?}");
        assert!(found.iter().all(|f| f.rule == "secret-flow"));
    }

    #[test]
    fn audit_secret_tag_extends_the_secret_set() {
        let src = "// audit:secret\npub struct ShareHalf {\n    pub v: u64,\n}\nfn leak(s: &ShareHalf) { crate::obs::debug(format_args!(\"{}\", s.v)); }\n";
        let found = run_one("mpc/shares.rs", src);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "secret-flow");
        assert_eq!(found[0].line, 5);
    }

    #[test]
    fn opaque_debug_impl_is_accepted() {
        let src = "pub struct PrivateKey;\nimpl std::fmt::Debug for PrivateKey {\n    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {\n        f.write_str(\"PrivateKey(<redacted>)\")\n    }\n}\n";
        assert!(run_one("crypto/keys.rs", src).is_empty());
    }

    #[test]
    fn wire_tags_missing_arm_is_found() {
        let src = "pub const TAG_PING: u8 = 0x01;\npub const TAG_GONE: u8 = 0x02;\npub fn tag_name(t: u8) -> &'static str {\n    match t {\n        TAG_PING => \"Ping\",\n        _ => \"?\",\n    }\n}\n#[cfg(test)]\nmod tests {\n    fn roundtrip() {\n        let _ = Msg::Ping;\n    }\n}\n";
        let found = run_one("net/wire.rs", src);
        assert_eq!(found.len(), 1, "{found:?}");
        assert_eq!(found[0].rule, "wire-tags");
        assert_eq!(found[0].line, 2);
    }

    #[test]
    fn span_schema_flags_unknown_span_and_version_conflict() {
        let known = "pub const KNOWN_SPANS: &[&str] = &[\"proto.step\"];\n";
        let schema_a = format!("pub const A: &str = \"privlogit-{}\";\n", "demo/v1");
        let schema_b = format!("pub const B: &str = \"privlogit-{}\";\n", "demo/v2");
        let caller = format!(
            "{schema_a}{schema_b}fn go() {{\n    let _s = crate::obs::span(\"proto.mystery\");\n}}\n"
        );
        let files = vec![
            ("obs/timeline.rs".to_string(), known.to_string()),
            ("obs/caller.rs".to_string(), caller),
        ];
        let found = audit_sources(&files, None);
        assert_eq!(found.len(), 2, "{found:?}");
        assert!(
            found.iter().any(|f| f.rule == "span-schema" && f.message.contains("proto.mystery")),
            "{found:?}"
        );
        assert!(found.iter().any(|f| f.message.contains("conflicting versions")));
    }

    #[test]
    fn report_renders_both_shapes() {
        let rep = AuditReport {
            findings: vec![Finding {
                file: "net/wire.rs".to_string(),
                line: 7,
                rule: "panic-free",
                message: "unwrap() on a remote-input path".to_string(),
            }],
            files_scanned: 3,
            doc_found: false,
        };
        let human = rep.render_human();
        assert!(human.contains("net/wire.rs:7: panic-free:"));
        assert!(human.contains("1 finding(s) across 3 files"));
        let parsed = crate::obs::json::parse(&rep.render_json()).expect("valid json");
        assert_eq!(parsed.get("schema").and_then(|v| v.as_str()), Some(AUDIT_SCHEMA));
        let arr = parsed.get("findings").and_then(|v| v.as_arr()).expect("findings array");
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("line").and_then(|v| v.as_u64()), Some(7));
    }
}
