//! Renderers for audit results: compiler-style human text and the
//! `privlogit-audit/v1` JSON document CI archives as an artifact.

use crate::obs::json::{JsonObj, JsonValue};

use super::{AuditReport, Finding, AUDIT_SCHEMA};

/// Render findings as `file:line: rule: message` lines plus a summary
/// tail — the shape editors and CI log scrapers already understand.
pub fn render_human(report: &AuditReport) -> String {
    let mut out = String::new();
    for f in &report.findings {
        out.push_str(&format!("{}:{}: {}: {}\n", f.file, f.line, f.rule, f.message));
    }
    if report.findings.is_empty() {
        out.push_str(&format!("audit: clean — 0 findings across {} files\n", report.files_scanned));
    } else {
        out.push_str(&format!(
            "audit: {} finding(s) across {} files\n",
            report.findings.len(),
            report.files_scanned
        ));
    }
    out
}

fn finding_json(f: &Finding) -> JsonValue {
    JsonObj::new()
        .str("file", &f.file)
        .u64("line", f.line as u64)
        .str("rule", f.rule)
        .str("message", &f.message)
        .build()
}

/// Render the `privlogit-audit/v1` document (single line, key order
/// fixed, findings pre-sorted) so reports diff cleanly across runs.
pub fn render_json(report: &AuditReport) -> String {
    let findings: Vec<JsonValue> = report.findings.iter().map(finding_json).collect();
    JsonObj::new()
        .str("schema", AUDIT_SCHEMA)
        .u64("files_scanned", report.files_scanned as u64)
        .bool("doc_found", report.doc_found)
        .push("findings", JsonValue::Arr(findings))
        .build()
        .render()
}
