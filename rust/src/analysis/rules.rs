//! The audit's rule engine: four lexical rules over [`Lexed`] files.
//!
//! Every rule is deliberately *lexical* — it scans blanked code lines
//! and string literals, not an AST — so the whole subsystem stays
//! dependency-free. The cost is approximation: the rules are tuned to
//! be exhaustive on the idioms this codebase actually uses (see
//! docs/ARCHITECTURE.md §Static analysis for the honest scope notes),
//! and anything genuinely safe that still trips a rule carries an
//! `audit:allow` with its justification.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{brace_block_end, Lexed, StrLit};
use super::Finding;

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Byte offsets of `needle` in `hay` at identifier-word boundaries.
pub(crate) fn word_positions(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for (pos, _) in hay.match_indices(needle) {
        let before = hay[..pos].chars().next_back();
        let after = hay[pos + needle.len()..].chars().next();
        if before.is_none_or(|c| !is_ident_char(c)) && after.is_none_or(|c| !is_ident_char(c)) {
            out.push(pos);
        }
    }
    out
}

fn contains_word(hay: &str, needle: &str) -> bool {
    !word_positions(hay, needle).is_empty()
}

// ======================================================================
// Rule 1: secret-flow
// ======================================================================

/// Types that are secret by construction, before any `audit:secret`
/// tags: the Paillier private key and the keypair that embeds it.
pub const BASE_SECRETS: &[&str] = &["PrivateKey", "Keypair"];

/// Sink tokens: a secret type named on the same line as one of these is
/// flowing toward a log line, a trace-span string field, or the wire
/// codec — none of which may ever carry secret material.
const SINKS: &[&str] = &[
    "obs::warn(",
    "obs::info(",
    "obs::debug(",
    ".record_str(",
    ".str(",
    "put_biguint(",
    "put_bytes(",
    "put_str(",
];

/// Parse the type name declared on `code`, if any.
fn type_decl_name(code: &str) -> Option<String> {
    for kw in ["struct", "enum"] {
        for pos in word_positions(code, kw) {
            let rest = code[pos + kw.len()..].trim_start();
            let name: String = rest.chars().take_while(|c| is_ident_char(*c)).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
    }
    None
}

fn type_decl_in_next_lines(lx: &Lexed, from: usize) -> Option<String> {
    for l in from..=(from + 2).min(lx.blanked.len()) {
        if let Some(name) = type_decl_name(lx.code(l)) {
            return Some(name);
        }
    }
    None
}

/// Parse `impl … (Debug|Display) for Type` on one line.
fn impl_fmt_trait(code: &str) -> Option<(&'static str, String)> {
    let impl_pos = *word_positions(code, "impl").first()?;
    let for_pos = word_positions(code, "for").into_iter().find(|p| *p > impl_pos)?;
    let between = &code[impl_pos..for_pos];
    let trait_name = if contains_word(between, "Debug") {
        "Debug"
    } else if contains_word(between, "Display") {
        "Display"
    } else {
        return None;
    };
    let after = code[for_pos + 3..].trim_start();
    let path: String = after.chars().take_while(|c| is_ident_char(*c) || *c == ':').collect();
    let ty = path.rsplit("::").next().unwrap_or("").to_string();
    if ty.is_empty() {
        return None;
    }
    Some((trait_name, ty))
}

/// Rule `secret-flow`: secret types must not derive or hand-roll a
/// field-dumping `Debug`/`Display`, and must not be named on a line
/// that feeds a log/span/codec sink.
pub fn secret_flow(
    relpath: &str,
    lx: &Lexed,
    secrets: &BTreeSet<String>,
    findings: &mut Vec<Finding>,
) {
    for ln in 1..=lx.blanked.len() {
        if lx.is_test.contains(&ln) || lx.allowed("secret-flow", ln) {
            continue;
        }
        let code = lx.code(ln);
        // (a) #[derive(.. Debug ..)] on a secret type declaration.
        if code.contains("#[derive(") && contains_word(code, "Debug") {
            if let Some(name) = type_decl_in_next_lines(lx, ln + 1) {
                if secrets.contains(&name) {
                    findings.push(Finding {
                        file: relpath.to_string(),
                        line: ln,
                        rule: "secret-flow",
                        message: format!("secret type {name} derives Debug"),
                    });
                }
            }
        }
        // (b) impl Debug/Display for a secret type must be opaque.
        if let Some((trait_name, ty)) = impl_fmt_trait(code) {
            if secrets.contains(&ty) {
                let end = brace_block_end(lx, ln);
                let mut redacted = false;
                for s in &lx.strings {
                    if s.line >= ln && s.line <= end && s.text.contains("<redacted>") {
                        redacted = true;
                    }
                }
                let dumps_fields = (ln..=end).any(|l| lx.code(l).contains(".field("));
                if !redacted || dumps_fields {
                    findings.push(Finding {
                        file: relpath.to_string(),
                        line: ln,
                        rule: "secret-flow",
                        message: format!(
                            "non-opaque {trait_name} impl for secret type {ty} \
                             (want a \"<redacted>\" body with no .field() calls)"
                        ),
                    });
                }
            }
        }
        // (c) secret type named on a sink line.
        if secrets.iter().any(|s| contains_word(code, s))
            && SINKS.iter().any(|s| code.contains(s))
        {
            findings.push(Finding {
                file: relpath.to_string(),
                line: ln,
                rule: "secret-flow",
                message: "secret type on a log/span/codec sink line".to_string(),
            });
        }
    }
}

// ======================================================================
// Rule 2: panic-free
// ======================================================================

/// The remote-input files: everything that decodes or dispatches bytes
/// a peer process controls. A panic reachable from here lets a
/// malformed frame take the process down instead of failing the
/// session.
pub const PANIC_SCOPE: &[&str] = &[
    "net/wire.rs",
    "net/server.rs",
    "net/fleet.rs",
    "net/tcp.rs",
    "net/mod.rs",
    "mpc/peer.rs",
    "coordinator/checkpoint.rs",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
const ASSERT_MACROS: &[&str] = &[
    "assert",
    "assert_eq",
    "assert_ne",
    "debug_assert",
    "debug_assert_eq",
    "debug_assert_ne",
];

fn has_unwrap_call(code: &str) -> bool {
    for (pos, _) in code.match_indices(".unwrap") {
        let rest = code[pos + ".unwrap".len()..].trim_start();
        if let Some(after_paren) = rest.strip_prefix('(') {
            if after_paren.trim_start().starts_with(')') {
                return true;
            }
        }
    }
    false
}

fn has_expect_call(code: &str) -> bool {
    for (pos, _) in code.match_indices(".expect") {
        if code[pos + ".expect".len()..].trim_start().starts_with('(') {
            return true;
        }
    }
    false
}

fn has_macro_call(code: &str, names: &[&str], openers: &[char]) -> bool {
    for name in names {
        for pos in word_positions(code, name) {
            let rest = &code[pos + name.len()..];
            if let Some(after_bang) = rest.strip_prefix('!') {
                let after_bang = after_bang.trim_start();
                if after_bang.starts_with(openers) {
                    return true;
                }
            }
        }
    }
    false
}

fn has_indexing(code: &str) -> bool {
    let mut prev = ' ';
    for c in code.chars() {
        if c == '[' && (is_ident_char(prev) || prev == ')' || prev == ']' || prev == '?') {
            return true;
        }
        prev = c;
    }
    false
}

/// Rule `panic-free`: no `unwrap`/`expect`/panicking macro/assert/
/// unchecked indexing in non-test code of the remote-input files.
pub fn panic_free(relpath: &str, lx: &Lexed, findings: &mut Vec<Finding>) {
    if !PANIC_SCOPE.iter().any(|p| relpath.ends_with(p)) {
        return;
    }
    for ln in 1..=lx.blanked.len() {
        if lx.is_test.contains(&ln) || lx.allowed("panic-free", ln) {
            continue;
        }
        let code = lx.code(ln);
        let mut hits: Vec<&str> = Vec::new();
        if has_unwrap_call(code) {
            hits.push("unwrap() on a remote-input path");
        }
        if has_expect_call(code) {
            hits.push("expect() on a remote-input path");
        }
        if has_macro_call(code, PANIC_MACROS, &['(', '[', '{']) {
            hits.push("panicking macro on a remote-input path");
        }
        if has_macro_call(code, ASSERT_MACROS, &['(']) {
            hits.push("assert on a remote-input path");
        }
        if has_indexing(code) {
            hits.push("unchecked indexing on a remote-input path");
        }
        for msg in hits {
            findings.push(Finding {
                file: relpath.to_string(),
                line: ln,
                rule: "panic-free",
                message: msg.to_string(),
            });
        }
    }
}

// ======================================================================
// Rule 3: wire-tags
// ======================================================================

fn parse_tag_const(code: &str) -> Option<(String, String)> {
    let rest = code.trim_start().strip_prefix("pub const TAG_")?;
    let ident: String = rest.chars().take_while(|c| is_ident_char(*c)).collect();
    let rest = rest[ident.len()..].trim_start().strip_prefix(':')?;
    let rest = rest.trim_start().strip_prefix("u8")?;
    let rest = rest.trim_start().strip_prefix('=')?;
    let rest = rest.trim_start();
    if !rest.starts_with("0x") {
        return None;
    }
    let hex: String = rest.chars().take_while(|c| c.is_ascii_alphanumeric()).collect();
    Some((format!("TAG_{ident}"), hex))
}

fn find_fn_block(lx: &Lexed, marker: &str) -> Option<(usize, usize)> {
    for ln in 1..=lx.blanked.len() {
        if lx.code(ln).contains(marker) {
            return Some((ln, brace_block_end(lx, ln)));
        }
    }
    None
}

/// Find the line in `a..=b` carrying a `NAME =>` match arm.
fn find_arm(lx: &Lexed, a: usize, b: usize, name: &str) -> Option<usize> {
    for l in a..=b {
        let code = lx.code(l);
        for pos in word_positions(code, name) {
            if code[pos + name.len()..].trim_start().starts_with("=>") {
                return Some(l);
            }
        }
    }
    None
}

/// Rule `wire-tags`: every `TAG_*` constant must have a `tag_name()`
/// arm, an arm in `fn tag()`, round-trip coverage of its variant name
/// in the file's test region, and (when docs are found) its hex value
/// in the ARCHITECTURE.md wire table. Per-tag flow accounting is
/// structural (the counters are keyed by the tag byte itself), so it
/// needs no lexical check.
pub fn wire_tags(relpath: &str, lx: &Lexed, doc: Option<&str>, findings: &mut Vec<Finding>) {
    let mut consts: Vec<(usize, String, String)> = Vec::new();
    for ln in 1..=lx.blanked.len() {
        if lx.is_test.contains(&ln) {
            continue;
        }
        if let Some((name, hex)) = parse_tag_const(lx.code(ln)) {
            consts.push((ln, name, hex));
        }
    }
    if consts.is_empty() {
        return;
    }
    let name_blk = find_fn_block(lx, "fn tag_name");
    let tag_blk = find_fn_block(lx, "fn tag(");
    let mut test_code = String::new();
    for &l in &lx.is_test {
        test_code.push_str(lx.code(l));
        test_code.push('\n');
    }
    for (ln, name, hex) in consts {
        if lx.allowed("wire-tags", ln) {
            continue;
        }
        let mut variant: Option<String> = None;
        match name_blk {
            None => findings.push(Finding {
                file: relpath.to_string(),
                line: ln,
                rule: "wire-tags",
                message: "no tag_name() fn found for the TAG_* constants".to_string(),
            }),
            Some((a, b)) => match find_arm(lx, a, b, &name) {
                None => findings.push(Finding {
                    file: relpath.to_string(),
                    line: ln,
                    rule: "wire-tags",
                    message: format!("{name} has no tag_name() arm"),
                }),
                Some(arm_ln) => {
                    for s in &lx.strings {
                        if s.line == arm_ln {
                            variant = Some(s.text.clone());
                            break;
                        }
                    }
                }
            },
        }
        if let Some((a, b)) = tag_blk {
            let mut present = false;
            for l in a..=b {
                if contains_word(lx.code(l), &name) {
                    present = true;
                }
            }
            if !present {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: ln,
                    rule: "wire-tags",
                    message: format!("{name} missing from fn tag()"),
                });
            }
        }
        if let Some(v) = variant {
            if !contains_word(&test_code, &v) {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: ln,
                    rule: "wire-tags",
                    message: format!(
                        "variant {v} ({name}) has no round-trip test coverage in this file"
                    ),
                });
            }
        }
        if let Some(doc_text) = doc {
            if !doc_text.contains(&hex) {
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: ln,
                    rule: "wire-tags",
                    message: format!("{name} value {hex} not documented in ARCHITECTURE.md"),
                });
            }
        }
    }
}

// ======================================================================
// Rule 4: span-schema
// ======================================================================

/// Cross-file accumulator for rule 4: span call sites, the
/// `KNOWN_SPANS` vocabulary, and every schema string literal.
#[derive(Default)]
pub struct SpanAcc {
    /// `span("…")` call sites in non-test code: (file, line, name).
    pub spans: Vec<(String, usize, String)>,
    /// The `KNOWN_SPANS` const, when a scanned file declares one.
    pub known: Option<(String, Vec<String>)>,
    /// Every `privlogit-*/vN` string: (file, line, schema).
    pub schemas: Vec<(String, usize, String)>,
}

/// Extract every `privlogit-<base>/vN` schema string in `text`.
fn schemas_in(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(pos) = text[start..].find("privlogit-") {
        let abs = start + pos;
        let rest = &text[abs + "privlogit-".len()..];
        let base_len = rest
            .find(|c: char| !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'))
            .unwrap_or(rest.len());
        let mut next = abs + "privlogit-".len();
        if base_len > 0 {
            if let Some(ver) = rest[base_len..].strip_prefix("/v") {
                let digits: String = ver.chars().take_while(|c| c.is_ascii_digit()).collect();
                if !digits.is_empty() {
                    let end = abs + "privlogit-".len() + base_len + 2 + digits.len();
                    out.push(text[abs..end].to_string());
                    next = end;
                }
            }
        }
        start = next;
    }
    out
}

fn is_span_lit(lx: &Lexed, s: &StrLit) -> bool {
    let prefix: String = lx.code(s.line).chars().take(s.col).collect();
    prefix.trim_end().ends_with("span(")
}

/// Collect one file's span call sites, `KNOWN_SPANS` vocabulary, and
/// schema strings into `acc`.
pub fn collect_spans_schemas(relpath: &str, lx: &Lexed, acc: &mut SpanAcc) {
    for s in &lx.strings {
        if !lx.is_test.contains(&s.line) && is_span_lit(lx, s) {
            acc.spans.push((relpath.to_string(), s.line, s.text.clone()));
        }
        for schema in schemas_in(&s.text) {
            acc.schemas.push((relpath.to_string(), s.line, schema));
        }
    }
    for ln in 1..=lx.blanked.len() {
        let code = lx.code(ln);
        if code.contains("KNOWN_SPANS") && code.contains("&[&str]") {
            let mut end = lx.blanked.len();
            for l in ln..=lx.blanked.len() {
                if lx.code(l).contains("];") {
                    end = l;
                    break;
                }
            }
            let mut names = Vec::new();
            for s in &lx.strings {
                if s.line >= ln && s.line <= end {
                    names.push(s.text.clone());
                }
            }
            acc.known = Some((relpath.to_string(), names));
            break;
        }
    }
}

/// Rule `span-schema`: every non-test `span("…")` name must be in the
/// timeline's `KNOWN_SPANS` vocabulary and the docs taxonomy; every
/// `privlogit-*/vN` schema must be version-consistent across the tree
/// and documented.
pub fn span_schema(acc: &SpanAcc, doc: Option<&str>, findings: &mut Vec<Finding>) {
    for (file, line, name) in &acc.spans {
        if let Some((_, known)) = &acc.known {
            if !known.contains(name) {
                findings.push(Finding {
                    file: file.clone(),
                    line: *line,
                    rule: "span-schema",
                    message: format!("span \"{name}\" missing from timeline KNOWN_SPANS"),
                });
            }
        }
        if let Some(doc_text) = doc {
            if !doc_text.contains(name.as_str()) {
                findings.push(Finding {
                    file: file.clone(),
                    line: *line,
                    rule: "span-schema",
                    message: format!("span \"{name}\" missing from the ARCHITECTURE.md taxonomy"),
                });
            }
        }
    }
    let mut by_base: BTreeMap<String, BTreeMap<String, (String, usize)>> = BTreeMap::new();
    for (file, line, schema) in &acc.schemas {
        if let Some((base, ver)) = schema.rsplit_once("/v") {
            by_base
                .entry(base.to_string())
                .or_default()
                .entry(ver.to_string())
                .or_insert_with(|| (file.clone(), *line));
        }
    }
    for (base, vers) in &by_base {
        if vers.len() > 1 {
            let mut sites = Vec::new();
            for (v, (f, l)) in vers {
                sites.push(format!("{base}/v{v} at {f}:{l}"));
            }
            let (first_file, first_line) = vers.values().next().cloned().unwrap_or_default();
            findings.push(Finding {
                file: first_file,
                line: first_line,
                rule: "span-schema",
                message: format!("schema {base} has conflicting versions: {}", sites.join("; ")),
            });
        }
        if let Some(doc_text) = doc {
            for (v, (f, l)) in vers {
                let full = format!("{base}/v{v}");
                if !doc_text.contains(&full) {
                    findings.push(Finding {
                        file: f.clone(),
                        line: *l,
                        rule: "span-schema",
                        message: format!("schema {full} not documented in ARCHITECTURE.md"),
                    });
                }
            }
        }
    }
}
