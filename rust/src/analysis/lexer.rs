//! A lightweight Rust source lexer for the audit rules.
//!
//! Not a parser: it classifies every character of a source file as code,
//! comment, or literal, producing a *blanked* view (comments and string
//! contents replaced by spaces, columns preserved) that the lexical
//! rules in [`super::rules`] can scan without tripping on tokens inside
//! strings or comments. On top of that it tracks three pieces of
//! structure the rules need: `#[cfg(test)]` brace regions, the
//! `// audit:allow(RULE): reason` suppression grammar, and
//! `// audit:secret` type tags.

use std::collections::{BTreeMap, BTreeSet};

use super::{Finding, RULES};

/// One string literal: where it starts (1-based line, 0-based column of
/// the opening delimiter in the blanked line) and its raw contents.
#[derive(Clone, Debug)]
pub struct StrLit {
    /// 1-based source line of the opening quote.
    pub line: usize,
    /// 0-based character column of the opening quote.
    pub col: usize,
    /// Literal contents (escape sequences kept verbatim).
    pub text: String,
}

/// One `audit:allow` suppression: the rule it silences and the
/// inclusive line range it covers (a single line, or a whole `fn` block
/// when attached to a function signature).
#[derive(Clone, Debug)]
pub struct Allow {
    /// The silenced rule (one of [`RULES`]).
    pub rule: &'static str,
    /// First covered line (1-based).
    pub start: usize,
    /// Last covered line (inclusive).
    pub end: usize,
}

/// The lexed view of one source file.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    /// Source lines with comments and literal contents blanked to
    /// spaces (string delimiters kept), columns preserved.
    pub blanked: Vec<String>,
    /// Every string literal, in source order.
    pub strings: Vec<StrLit>,
    /// Comment text per line (concatenated when a line holds several).
    pub comments: BTreeMap<usize, String>,
    /// Lines inside `#[cfg(test)]` brace regions (or the whole file for
    /// paths under `tests/`).
    pub is_test: BTreeSet<usize>,
    /// Parsed `audit:allow` suppressions.
    pub allows: Vec<Allow>,
    /// Type names tagged secret via `// audit:secret`.
    pub secrets: BTreeSet<String>,
}

impl Lexed {
    /// Whether `rule` is suppressed at `line` by an attached allow.
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows.iter().any(|a| a.rule == rule && a.start <= line && line <= a.end)
    }

    /// The blanked text of `line` (1-based), or `""` past the end.
    pub fn code(&self, line: usize) -> &str {
        self.blanked.get(line.wrapping_sub(1)).map_or("", String::as_str)
    }
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
}

/// Lex `src` into its blanked view plus literals and comments.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Lexed::default();
    let mut blanked: Vec<char> = Vec::with_capacity(n);
    let mut state = State::Code;
    let mut line = 1usize;
    let mut col = 0usize;
    let mut cur_str: Option<(usize, usize, String)> = None;
    let mut cur_comment = String::new();
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        let nxt = cs.get(i + 1).copied().unwrap_or('\0');
        if c == '\n' {
            if !cur_comment.is_empty() {
                let entry = out.comments.entry(line).or_default();
                entry.push_str(&cur_comment);
                cur_comment.clear();
            }
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            blanked.push('\n');
            line += 1;
            col = 0;
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && nxt == '/' {
                    state = State::LineComment;
                    blanked.push(' ');
                    blanked.push(' ');
                    i += 2;
                    col += 2;
                } else if c == '/' && nxt == '*' {
                    state = State::BlockComment(1);
                    blanked.push(' ');
                    blanked.push(' ');
                    i += 2;
                    col += 2;
                } else if c == '"' {
                    cur_str = Some((line, col, String::new()));
                    blanked.push('"');
                    state = State::Str;
                    i += 1;
                    col += 1;
                } else if c == 'r' && (nxt == '"' || nxt == '#') {
                    // Raw string r"…" / r#"…"# (or a raw identifier,
                    // which falls through to plain code).
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && cs[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        cur_str = Some((line, col, String::new()));
                        let skip = j + 1 - i;
                        for _ in 0..skip {
                            blanked.push(' ');
                        }
                        i = j + 1;
                        col += skip;
                        state = State::RawStr(hashes);
                    } else {
                        blanked.push(c);
                        i += 1;
                        col += 1;
                    }
                } else if c == 'b' && nxt == '"' {
                    cur_str = Some((line, col, String::new()));
                    blanked.push(' ');
                    blanked.push('"');
                    state = State::Str;
                    i += 2;
                    col += 2;
                } else if c == '\'' {
                    // Lifetime vs char literal: after `'`, an identifier
                    // char not followed by a closing `'` is a lifetime.
                    let c2 = cs.get(i + 1).copied().unwrap_or('\0');
                    let c3 = cs.get(i + 2).copied().unwrap_or('\0');
                    if (c2.is_alphabetic() || c2 == '_') && c3 != '\'' {
                        blanked.push('\'');
                        i += 1;
                        col += 1;
                    } else {
                        // Char literal: skip to the closing quote.
                        let mut j = i + 1;
                        if j < n && cs[j] == '\\' {
                            j += 2;
                            while j < n && cs[j] != '\'' {
                                j += 1;
                            }
                        } else {
                            j += 1;
                        }
                        let end = (j + 1).min(n);
                        let skip = end - i;
                        blanked.push('\'');
                        for _ in 0..skip.saturating_sub(2) {
                            blanked.push(' ');
                        }
                        if skip > 1 {
                            blanked.push('\'');
                        }
                        i = end;
                        col += skip;
                    }
                } else {
                    blanked.push(c);
                    i += 1;
                    col += 1;
                }
            }
            State::LineComment => {
                cur_comment.push(c);
                blanked.push(' ');
                i += 1;
                col += 1;
            }
            State::BlockComment(depth) => {
                if c == '*' && nxt == '/' {
                    blanked.push(' ');
                    blanked.push(' ');
                    i += 2;
                    col += 2;
                    state = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                } else if c == '/' && nxt == '*' {
                    blanked.push(' ');
                    blanked.push(' ');
                    i += 2;
                    col += 2;
                    state = State::BlockComment(depth + 1);
                } else {
                    cur_comment.push(c);
                    blanked.push(' ');
                    i += 1;
                    col += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    if let Some((_, _, text)) = cur_str.as_mut() {
                        text.push(c);
                    }
                    blanked.push(' ');
                    i += 1;
                    col += 1;
                    if nxt != '\n' && i < n {
                        if let Some((_, _, text)) = cur_str.as_mut() {
                            text.push(nxt);
                        }
                        blanked.push(' ');
                        i += 1;
                        col += 1;
                    }
                } else if c == '"' {
                    if let Some((l0, c0, text)) = cur_str.take() {
                        out.strings.push(StrLit { line: l0, col: c0, text });
                    }
                    blanked.push('"');
                    state = State::Code;
                    i += 1;
                    col += 1;
                } else {
                    if let Some((_, _, text)) = cur_str.as_mut() {
                        text.push(c);
                    }
                    blanked.push(' ');
                    i += 1;
                    col += 1;
                }
            }
            State::RawStr(hashes) => {
                let mut closed = false;
                if c == '"' {
                    let mut h = 0usize;
                    let mut j = i + 1;
                    while h < hashes && j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        if let Some((l0, c0, text)) = cur_str.take() {
                            out.strings.push(StrLit { line: l0, col: c0, text });
                        }
                        let skip = j - i;
                        for _ in 0..skip {
                            blanked.push(' ');
                        }
                        i = j;
                        col += skip;
                        state = State::Code;
                        closed = true;
                    }
                }
                if !closed {
                    if let Some((_, _, text)) = cur_str.as_mut() {
                        text.push(c);
                    }
                    blanked.push(' ');
                    i += 1;
                    col += 1;
                }
            }
        }
    }
    if !cur_comment.is_empty() {
        let entry = out.comments.entry(line).or_default();
        entry.push_str(&cur_comment);
    }
    let text: String = blanked.into_iter().collect();
    out.blanked = text.split('\n').map(str::to_string).collect();
    out
}

/// Mark every line inside a `#[cfg(test)] { … }` region as test code.
pub fn mark_cfg_test(lx: &mut Lexed) {
    let mut depth = 0i64;
    let mut pending = false;
    let mut region_close: Option<i64> = None;
    for ln in 1..=lx.blanked.len() {
        let code = lx.blanked[ln - 1].clone();
        if code.contains("#[cfg(test)]") || code.contains("#[cfg(all(test") {
            pending = true;
        }
        if region_close.is_some() {
            lx.is_test.insert(ln);
        }
        for ch in code.chars() {
            if ch == '{' {
                depth += 1;
                if pending {
                    pending = false;
                    region_close = Some(depth);
                    lx.is_test.insert(ln);
                }
            } else if ch == '}' {
                if region_close == Some(depth) {
                    region_close = None;
                }
                depth -= 1;
            }
        }
    }
}

/// Whether `code` contains an `fn` item declaration.
pub fn has_fn_decl(code: &str) -> bool {
    for pos in super::rules::word_positions(code, "fn") {
        let rest = &code[pos + 2..];
        let trimmed = rest.trim_start();
        if trimmed.len() < rest.len() {
            if let Some(c) = trimmed.chars().next() {
                if c.is_ascii_alphabetic() || c == '_' {
                    return true;
                }
            }
        }
    }
    false
}

/// Last line of the brace block opening at or after `start` (1-based).
/// For a block-less item, the line holding the terminating `;`.
pub fn brace_block_end(lx: &Lexed, start: usize) -> usize {
    let mut depth = 0i64;
    let mut opened = false;
    for ln in start..=lx.blanked.len() {
        let code = &lx.blanked[ln - 1];
        for ch in code.chars() {
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
                if opened && depth == 0 {
                    return ln;
                }
            }
        }
        if !opened && code.contains(';') {
            return ln;
        }
    }
    lx.blanked.len()
}

fn next_code_line(lx: &Lexed, from: usize) -> Option<usize> {
    (from..=lx.blanked.len()).find(|&l| !lx.blanked[l - 1].trim().is_empty())
}

/// Parse `audit:allow(rule): reason` out of a comment, returning the
/// rule (validated against [`RULES`]) or `None` when malformed.
fn parse_allow(comment: &str) -> Option<&'static str> {
    let pos = comment.find("audit:allow(")?;
    let rest = &comment[pos + "audit:allow(".len()..];
    let close = rest.find(')')?;
    let rule = &rest[..close];
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':')?.trim();
    if reason.is_empty() {
        return None;
    }
    RULES.iter().find(|r| **r == rule).copied()
}

/// Parse the type name of a `struct`/`enum` declaration on `code`.
fn type_decl_name(code: &str) -> Option<String> {
    for kw in ["struct", "enum"] {
        for pos in super::rules::word_positions(code, kw) {
            let rest = code[pos + kw.len()..].trim_start();
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
    }
    None
}

/// Attach `audit:allow` / `audit:secret` annotations to their target
/// lines. A malformed or unknown-rule allow is itself a finding (rule
/// `audit-allow`): a suppression that silently fails open would defeat
/// the audit.
pub fn attach_allows(lx: &mut Lexed, relpath: &str, findings: &mut Vec<Finding>) {
    let comment_lines: Vec<usize> = lx.comments.keys().copied().collect();
    for ln in comment_lines {
        let txt = lx.comments[&ln].clone();
        // Only plain `//` and `/* … */` comments carry annotations.
        // Doc comments (`///`, `//!`, `/** … */`) may *describe* the
        // grammar — as this file's module docs do — without arming it.
        let body = txt.trim_start();
        if body.starts_with('/') || body.starts_with('!') || body.starts_with('*') {
            continue;
        }
        if txt.contains("audit:allow") {
            match parse_allow(&txt) {
                None => findings.push(Finding {
                    file: relpath.to_string(),
                    line: ln,
                    rule: "audit-allow",
                    message: "malformed or unknown audit:allow annotation \
                              (want `audit:allow(rule): reason`)"
                        .to_string(),
                }),
                Some(rule) => {
                    let target = if lx.code(ln).trim().is_empty() {
                        next_code_line(lx, ln + 1)
                    } else {
                        Some(ln)
                    };
                    match target {
                        None => findings.push(Finding {
                            file: relpath.to_string(),
                            line: ln,
                            rule: "audit-allow",
                            message: "audit:allow attaches to no code".to_string(),
                        }),
                        Some(t) => {
                            let end =
                                if has_fn_decl(lx.code(t)) { brace_block_end(lx, t) } else { t };
                            lx.allows.push(Allow { rule, start: t, end });
                        }
                    }
                }
            }
        }
        if txt.contains("audit:secret") && !txt.contains("audit:allow") {
            let from = if lx.code(ln).trim().is_empty() { ln + 1 } else { ln };
            if let Some(tgt) = next_code_line(lx, from) {
                for l in tgt..=(tgt + 2).min(lx.blanked.len()) {
                    if let Some(name) = type_decl_name(lx.code(l)) {
                        lx.secrets.insert(name);
                        break;
                    }
                }
            }
        }
    }
}
