//! Deterministic fault injection for fleet transports.
//!
//! The quorum layer (`net::fleet`) claims to survive slow, dead and
//! byzantine-slow nodes. This module makes those failure modes
//! *reproducible*: a [`FaultPlan`] describes exactly which reply of
//! which request kind misbehaves and how, and [`FaultyTransport`] — a
//! [`Transport`] wrapper — executes the plan on the node side of the
//! connection. Plans install onto a [`NodeServer`] via its public test
//! hooks ([`FaultPlan::install`]) for TCP tests, or wrap any transport
//! directly ([`FaultyTransport::wrap`]) for in-process tests.
//!
//! Faults are keyed by `(request tag, occurrence)`: occurrence `r` of
//! tag `t` is the `r`-th request of that kind this transport has seen,
//! which matches both the node server's and the center's per-tag round
//! numbering — so "kill the reply to `StepReq` round 2" means the same
//! thing on every layer and in the merged trace.
//!
//! How each [`FaultAction`] looks from the center:
//!
//! * [`Delay`](FaultAction::Delay) — a slow straggler; past the round
//!   deadline it becomes a read timeout (`outcome=timeout`).
//! * [`Hang`](FaultAction::Hang) — a hung node: the socket stays open,
//!   nothing arrives, the center's read times out (`outcome=timeout`).
//! * [`DropAfterBytes`](FaultAction::DropAfterBytes) — a node hanging
//!   mid-frame: the reply starts and stops; the center's read times out
//!   partway through the frame (`outcome=timeout`).
//! * [`TruncateFrame`](FaultAction::TruncateFrame) — a node dying
//!   mid-write: the frame is cut and the socket closes; the center sees
//!   an unexpected EOF (`outcome=error`).
//! * [`FaultPlan::fail_connects`] — a node not yet up: the first `k`
//!   connections are dropped before the handshake, exercising the
//!   center's connect retry.

use std::collections::BTreeMap;
use std::io;
use std::sync::Arc;
use std::time::Duration;

use crate::net::server::NodeServer;
use crate::net::wire;
use crate::net::Transport;

/// What happens to the reply the plan selected.
#[derive(Clone, Copy, Debug)]
pub enum FaultAction {
    /// Sleep this long, then send the reply normally (slow straggler —
    /// a delay past the round deadline becomes a timeout).
    Delay(Duration),
    /// Never send the reply and keep the connection open (hung node).
    Hang,
    /// Send only the first `k` bytes of the framed reply
    /// (`len ‖ payload ‖ crc`), then keep the connection open: the
    /// center's read stalls mid-frame.
    DropAfterBytes(usize),
    /// Send the frame's length prefix plus the first `k` payload bytes,
    /// then fail the session so the socket closes: the center reads an
    /// unexpected EOF mid-frame (node died mid-write).
    TruncateFrame(usize),
}

/// One scheduled fault: which occurrence of which request tag, in which
/// served session (None = every session), gets which action.
#[derive(Clone, Copy, Debug)]
struct Fault {
    session: Option<u64>,
    tag: u8,
    round: u64,
    action: FaultAction,
}

/// A deterministic schedule of transport faults: which occurrence of
/// which request tag gets which [`FaultAction`], plus how many initial
/// connection attempts to drop pre-handshake.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    fail_connects: u64,
}

impl FaultPlan {
    /// An empty plan (no faults).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Apply `action` to the reply of occurrence `round` of request
    /// `tag` (a `wire::TAG_*` constant). Occurrences count per tag from
    /// 0, matching the fleet's per-tag round numbering. For requests
    /// with multi-frame replies (`StepReq`), the action fires on the
    /// first reply frame.
    pub fn on(mut self, tag: u8, round: u64, action: FaultAction) -> FaultPlan {
        self.faults.push(Fault { session: None, tag, round, action });
        self
    }

    /// Like [`FaultPlan::on`], but scoped to served session `session`
    /// (0-based, counted per server across accepted connections). This
    /// is how a kill-and-restart node is modeled: session 0 dies
    /// mid-frame, the *next* accepted session — the readmission probe's
    /// fresh connection — behaves cleanly.
    pub fn on_session(mut self, session: u64, tag: u8, round: u64, action: FaultAction) -> FaultPlan {
        self.faults.push(Fault { session: Some(session), tag, round, action });
        self
    }

    /// Drop the first `k` accepted connections before the handshake —
    /// the connecting center sees an EOF during its hello and retries.
    pub fn fail_connects(mut self, k: u64) -> FaultPlan {
        self.fail_connects = k;
        self
    }

    /// Install this plan onto a [`NodeServer`] via its accept-gate and
    /// transport-wrapper hooks. Every served session gets a fresh
    /// [`FaultyTransport`] over the same plan (occurrence counters are
    /// per session, like the wire's round numbering).
    pub fn install(self, server: NodeServer) -> NodeServer {
        let FaultPlan { faults, fail_connects } = self;
        let mut server = server;
        if fail_connects > 0 {
            let mut remaining = fail_connects;
            server = server.with_accept_gate(Box::new(move || {
                if remaining > 0 {
                    remaining -= 1;
                    false
                } else {
                    true
                }
            }));
        }
        if !faults.is_empty() {
            let faults: Arc<[Fault]> = faults.into();
            let sessions = Arc::new(std::sync::atomic::AtomicU64::new(0));
            server = server.with_transport_wrapper(Box::new(move |inner| {
                let session = sessions.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                Box::new(FaultyTransport {
                    inner,
                    faults: Arc::clone(&faults),
                    session,
                    rounds: BTreeMap::new(),
                    armed: None,
                })
            }));
        }
        server
    }
}

/// A [`Transport`] that executes a [`FaultPlan`] from the node side:
/// received requests arm matching actions, the next reply fires them.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    faults: Arc<[Fault]>,
    /// 0-based index of the served session this transport carries
    /// (session-scoped faults match against it).
    session: u64,
    /// Per-tag occurrence counters over received requests.
    rounds: BTreeMap<u8, u64>,
    /// Action armed by the last received request, consumed by the next
    /// send.
    armed: Option<FaultAction>,
}

impl FaultyTransport {
    /// Wrap `inner`, applying `plan`'s per-round faults as session 0
    /// (the connect-gate part of a plan only takes effect via
    /// [`FaultPlan::install`]). For in-process tests over
    /// [`mem_transport_pair`](crate::net::mem_transport_pair), wrap the
    /// node end.
    pub fn wrap(inner: Box<dyn Transport>, plan: &FaultPlan) -> FaultyTransport {
        FaultyTransport {
            inner,
            faults: plan.faults.clone().into(),
            session: 0,
            rounds: BTreeMap::new(),
            armed: None,
        }
    }
}

/// Block this thread forever (spurious unparks included) — the fault
/// harness's "node stops responding but its socket stays open".
fn park_forever() -> ! {
    loop {
        std::thread::park();
    }
}

impl Transport for FaultyTransport {
    fn send_msg(&mut self, msg: Vec<u8>) -> io::Result<()> {
        let Some(action) = self.armed.take() else {
            return self.inner.send_msg(msg);
        };
        match action {
            FaultAction::Delay(d) => {
                std::thread::sleep(d);
                self.inner.send_msg(msg)
            }
            FaultAction::Hang => park_forever(),
            FaultAction::DropAfterBytes(k) => {
                // Reconstruct the frame exactly as the TCP layer would
                // (`len ‖ payload ‖ crc`) and stop k bytes in.
                let mut frame = Vec::with_capacity(msg.len() + 8);
                frame.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                frame.extend_from_slice(&msg);
                frame.extend_from_slice(&wire::crc32(&msg).to_le_bytes());
                let cut = k.min(frame.len());
                match self.inner.send_raw(&frame[..cut]) {
                    Ok(()) => {}
                    // Message-oriented inner (mem): best effort — a
                    // truncated body stands in for the partial frame.
                    Err(e) if e.kind() == io::ErrorKind::Unsupported => {
                        self.inner.send_msg(msg[..k.min(msg.len())].to_vec())?;
                    }
                    Err(e) => return Err(e),
                }
                park_forever()
            }
            FaultAction::TruncateFrame(k) => {
                let cut = k.min(msg.len());
                let mut partial = Vec::with_capacity(4 + cut);
                partial.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                partial.extend_from_slice(&msg[..cut]);
                match self.inner.send_raw(&partial) {
                    Ok(()) | Err(_) => {}
                }
                // Fail the session: the server tears the connection
                // down, so the center reads EOF mid-frame.
                Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "fault injection: node died mid-frame",
                ))
            }
        }
    }

    fn recv_msg(&mut self) -> io::Result<Vec<u8>> {
        let msg = self.inner.recv_msg()?;
        if let Some(&tag) = msg.first() {
            let c = self.rounds.entry(tag).or_insert(0);
            let round = *c;
            *c += 1;
            if let Some(f) = self.faults.iter().find(|f| {
                f.tag == tag && f.round == round && f.session.map_or(true, |s| s == self.session)
            }) {
                self.armed = Some(f.action);
            }
        }
        Ok(msg)
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.inner.send_raw(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mem_transport_pair;

    #[test]
    fn faults_fire_on_the_selected_occurrence_only() {
        let (mut center, node) = mem_transport_pair();
        let plan = FaultPlan::new().on(0x01, 1, FaultAction::TruncateFrame(3));
        let mut node = FaultyTransport::wrap(Box::new(node), &plan);

        // Occurrence 0 of tag 0x01: reply passes through untouched.
        center.send_msg(vec![0x01, 9, 9]).unwrap();
        node.recv_msg().unwrap();
        node.send_msg(b"world".to_vec()).unwrap();
        assert_eq!(center.recv_msg().unwrap(), b"world");

        // A different tag between occurrences must not advance 0x01's
        // counter.
        center.send_msg(vec![0x02]).unwrap();
        node.recv_msg().unwrap();
        node.send_msg(b"gram".to_vec()).unwrap();
        assert_eq!(center.recv_msg().unwrap(), b"gram");

        // Occurrence 1 of tag 0x01: mem fallback truncates the body and
        // the send fails (the "session" dies).
        center.send_msg(vec![0x01]).unwrap();
        node.recv_msg().unwrap();
        let err = node.send_msg(b"abcdef".to_vec()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(center.recv_msg().unwrap(), b"abc");
    }

    #[test]
    fn session_scoped_faults_fire_in_their_session_only() {
        let plan = FaultPlan::new().on_session(1, 0x01, 0, FaultAction::TruncateFrame(2));

        // Session 0 (what wrap() models): the fault must not fire.
        let (mut center, node) = mem_transport_pair();
        let mut node = FaultyTransport::wrap(Box::new(node), &plan);
        center.send_msg(vec![0x01]).unwrap();
        node.recv_msg().unwrap();
        node.send_msg(b"fine".to_vec()).unwrap();
        assert_eq!(center.recv_msg().unwrap(), b"fine");

        // The same plan observed from session 1: the fault fires.
        let (mut center, node) = mem_transport_pair();
        let mut node = FaultyTransport::wrap(Box::new(node), &plan);
        node.session = 1;
        center.send_msg(vec![0x01]).unwrap();
        node.recv_msg().unwrap();
        let err = node.send_msg(b"abcdef".to_vec()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
    }

    #[test]
    fn delay_forwards_the_reply_after_sleeping() {
        let (mut center, node) = mem_transport_pair();
        let plan = FaultPlan::new().on(0x08, 0, FaultAction::Delay(Duration::from_millis(30)));
        let mut node = FaultyTransport::wrap(Box::new(node), &plan);
        center.send_msg(vec![0x08]).unwrap();
        node.recv_msg().unwrap();
        let t0 = std::time::Instant::now();
        node.send_msg(b"late".to_vec()).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30), "delay not applied");
        assert_eq!(center.recv_msg().unwrap(), b"late");
    }
}
