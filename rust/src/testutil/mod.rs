//! Deterministic test utilities: a seeded RNG, a tiny property-testing
//! harness (the image has no `proptest`/`quickcheck`), and the
//! fault-injecting transport wrapper ([`faults`]) behind the fleet
//! fault-tolerance tests.

pub mod faults;

use crate::bigint::RandomSource;

/// Deterministic 64-bit RNG (SplitMix64 core). Test-only convenience;
/// protocol randomness uses [`crate::crypto::rng::ChaChaRng`].
pub struct TestRng {
    state: u64,
}

impl TestRng {
    /// Seeded construction — same seed, same stream, every platform.
    pub fn new(seed: u64) -> Self {
        TestRng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`.
    pub fn below_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Lemire-style rejection for negligible bias at test scale.
        self.next_u64() % bound
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-18);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Boolean with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

impl RandomSource for TestRng {
    fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let bytes = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&bytes[..chunk.len()]);
        }
    }
}

/// Run a property `check` over `cases` seeded inputs produced by `gen`.
/// On failure, reports the case index and seed so it can be replayed.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut TestRng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = TestRng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!("property failed at case {case} (seed {seed}): {msg}\ninput: {input:?}");
        }
    }
}

/// Assert two floats are close (absolute + relative tolerance).
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "{what}: {a} vs {b} (tol {tol}, scale {scale})"
    );
}

/// Assert two slices are element-wise close.
pub fn assert_all_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_close(*x, *y, tol, &format!("{what}[{i}]"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = TestRng::new(1);
        let mut b = TestRng::new(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = TestRng::new(2);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = TestRng::new(3);
        let n = 20000;
        let samples: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(0, 5, |r| r.below_u64(10), |x| {
            if *x < 100 { Err("always fails".into()) } else { Ok(()) }
        });
    }
}
