//! Cryptographic substrate: CSPRNG, the Paillier additively-homomorphic
//! cryptosystem, and the signed fixed-point codec that maps regression
//! statistics into Paillier's plaintext group.
//!
//! The paper's Type-1 computations (node ↔ Center exchange, §4.0.2) are
//! Paillier; Type-2 (between the two Center servers) are garbled circuits
//! ([`crate::gc`]).

pub mod fixed;
pub mod packed;
pub mod paillier;
pub mod rng;

pub use fixed::{EncodeError, FixedCodec, DEFAULT_FRAC_BITS};
pub use packed::{PackError, PackedCodec, PackedMeta, PackingParams, BLIND_SIGMA};
pub use paillier::{Ciphertext, Keypair, MontCiphertext, PrivateKey, PublicKey};
pub use rng::ChaChaRng;
