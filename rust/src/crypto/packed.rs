//! Radix-`2^b` slot packing: `k` fixed-point statistics per Paillier
//! plaintext.
//!
//! A 2048-bit Paillier plaintext carrying one ~64-bit fixed-point
//! statistic wastes ~97% of its capacity — and the statistic fan-in
//! (gradient and Hessian replies from every node, folded by the
//! aggregator) pays that waste in encryptions, wire bytes and
//! homomorphic folds alike. [`PackedCodec`] closes the gap by packing
//! `k` values into one plaintext as radix-`2^b` slots:
//!
//! ```text
//!   plaintext m = Σ_i slot_i · 2^(i·b)        (slot 0 in the low bits)
//!   slot_i      = round(v_i · 2^scale) + B    with bias B = 2^(w−1)
//! ```
//!
//! Slots are **biased**, not two's-complement: a negative value encoded
//! as `n − |x|` would sit near the top of its slot and carry into its
//! neighbor on the very first homomorphic addition. With the bias, a
//! slot holds a value in `[0, 2^w)` per contribution, and the sum of
//! `parts` contributions stays in `[0, parts·2^w)` — strictly inside
//! the slot as long as the headroom terms below hold. Every packed
//! vector therefore tracks `parts`, the number of biased contributions
//! folded into it (see [`PackedMeta`]); unpacking subtracts `parts·B`
//! per slot.
//!
//! **Headroom terms.** The slot width `b` is derived from the session's
//! [`FixedFmt`] so that overflow is *impossible by construction*; a
//! configuration that cannot guarantee this is rejected at session
//! setup with an error naming the violated term, never wrapped
//! silently:
//!
//! | term               | requirement on `b` (slot bits)                       |
//! |--------------------|------------------------------------------------------|
//! | `per_value`        | `b ≥ w` — one contribution fits                      |
//! | `fanin_sum`        | `b ≥ w + ⌈log₂(max_parts+1)⌉` — the n-node sum fits  |
//! | `blind_mask`       | `b ≥ w + ⌈log₂⌉ + σ + 1` — sum + statistical blind   |
//! | `hinv_apply`       | `b ≥ 2w + ⌈log₂(max_parts·p)⌉ + 1` — `Enc(H̃⁻¹)⊗g`    |
//! | `modulus_capacity` | `k·b ≤ modulus_bits − 2` and `k ≥ 2`                 |
//!
//! `σ` is [`BLIND_SIGMA`], the statistical-hiding parameter of the
//! blinded share conversion — the per-slot blind in a packed
//! [`to_shares`](crate::mpc::fabric::SecureFabric::to_shares) is drawn
//! below `2^(w + ⌈log₂⌉ + σ)` so it hides the slot sum to `2^−σ` while
//! provably not carrying into the next slot. The `modulus_capacity`
//! margin of 2 bits keeps every packed plaintext below `n/2`, so packed
//! sums never wrap mod `n` either.

use std::fmt;

use crate::bigint::BigUint;
use crate::crypto::fixed::magnitude_to_f64;
use crate::gc::word::FixedFmt;

/// Statistical-hiding parameter σ (bits) of the blinded share
/// conversion. Must equal `mpc::circuits::SIGMA` — the fabric asserts
/// the two constants agree at compile time (`crypto` sits below `mpc`
/// in the module DAG, so the shared value is defined here).
pub const BLIND_SIGMA: u32 = 40;

/// The wire-negotiated packing parameters ([`WireMsg::SetKey`] v6
/// fields): what a node needs, besides the session [`FixedFmt`], to
/// pack its statistic replies compatibly with the center.
///
/// [`WireMsg::SetKey`]: crate::net::wire::WireMsg::SetKey
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackingParams {
    /// Slots per plaintext.
    pub k: u32,
    /// Slot width `b` in bits.
    pub slot_bits: u32,
    /// Fan-in bound: the largest number of biased contributions any
    /// packed vector may accumulate.
    pub max_parts: u64,
}

/// Per-vector packing metadata carried by a packed
/// [`EncVec`](crate::mpc::fabric::EncVec): enough to unpack without
/// session context.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackedMeta {
    /// Slots per plaintext.
    pub k: u32,
    /// Slot width `b` in bits.
    pub slot_bits: u32,
    /// Logical element count (the ciphertext count is
    /// `len.div_ceil(k)`; the last plaintext's high slots are unused).
    pub len: usize,
    /// Biased contributions folded into each slot so far (1 after
    /// packing; summed by aggregation; scaled by constant-multiplies).
    /// Unpacking subtracts `parts · 2^(w−1)` per slot.
    pub parts: u128,
}

/// Why a packing configuration (or a packed payload) was rejected.
/// Every variant names the violated headroom term from the module-doc
/// table.
#[derive(Clone, Debug, PartialEq)]
pub enum PackError {
    /// The slot width cannot guarantee the named headroom term.
    Headroom {
        /// Violated term: `"per_value"`, `"fanin_sum"`, `"blind_mask"`
        /// or `"hinv_apply"`.
        term: &'static str,
        /// Slot bits the term needs.
        needed_bits: u32,
        /// Slot bits configured.
        slot_bits: u32,
    },
    /// The modulus cannot host the slot layout (`modulus_capacity`).
    Capacity {
        /// Always `"modulus_capacity"`.
        term: &'static str,
        /// Plaintext bits the layout needs (`k·b + 2`).
        needed_bits: u64,
        /// Modulus bits available.
        modulus_bits: u32,
    },
    /// A value cannot be encoded into a slot (`per_value` at runtime:
    /// non-finite, or magnitude at/over the `2^(w−1)` slot budget).
    Value {
        /// Always `"per_value"`.
        term: &'static str,
        /// The offending value.
        value: f64,
        /// The scale it was being encoded at.
        scale_bits: u32,
    },
    /// A fold would exceed (or a payload claims to exceed) the
    /// negotiated fan-in bound (`fanin_sum` at runtime).
    Fanin {
        /// Always `"fanin_sum"`.
        term: &'static str,
        /// Contributions the operation would reach.
        parts: u128,
        /// The negotiated bound.
        max_parts: u64,
    },
    /// A packed payload has the wrong ciphertext count for its length.
    Shape {
        /// Ciphertexts the length requires.
        wanted_cts: usize,
        /// Ciphertexts present.
        got_cts: usize,
        /// Logical element count.
        len: usize,
    },
    /// A decoded slot exceeds `parts · 2^w` — a corrupt or hostile
    /// packed payload (an honest one cannot get here: the headroom
    /// terms make overflow impossible).
    Slot {
        /// Flat element index of the bad slot.
        index: usize,
        /// Contributions the payload claimed.
        parts: u128,
    },
}

impl fmt::Display for PackError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PackError::Headroom { term, needed_bits, slot_bits } => write!(
                f,
                "packing headroom term `{term}` violated: needs {needed_bits} slot bits, \
                 layout has {slot_bits}"
            ),
            PackError::Capacity { term, needed_bits, modulus_bits } => write!(
                f,
                "packing headroom term `{term}` violated: layout needs {needed_bits} \
                 plaintext bits, modulus has {modulus_bits}"
            ),
            PackError::Value { term, value, scale_bits } => write!(
                f,
                "packing headroom term `{term}` violated: value {value} at scale \
                 2^{scale_bits} does not fit a slot"
            ),
            PackError::Fanin { term, parts, max_parts } => write!(
                f,
                "packing headroom term `{term}` violated: {parts} contributions exceed \
                 the negotiated fan-in bound {max_parts}"
            ),
            PackError::Shape { wanted_cts, got_cts, len } => write!(
                f,
                "packed payload of {len} values needs {wanted_cts} ciphertexts, got {got_cts}"
            ),
            PackError::Slot { index, parts } => write!(
                f,
                "packed slot {index} exceeds its {parts}-contribution bound \
                 (corrupt or hostile payload)"
            ),
        }
    }
}

impl std::error::Error for PackError {}

/// Bit length of a positive count (`⌈log₂(x+1)⌉`).
fn bitlen_u64(x: u64) -> u32 {
    64 - x.leading_zeros()
}

fn bitlen_u128(x: u128) -> u32 {
    128 - x.leading_zeros()
}

/// The slot-packing codec for one session: layout `(k, b)` plus the
/// fixed-point format and fan-in bound the layout was proven against.
/// Constructed by [`PackedCodec::plan`] (center side, derives the
/// layout) or [`PackedCodec::from_wire`] (node side, re-validates the
/// center's claimed layout — a hostile center must not be able to talk
/// a node into an overflowing one).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PackedCodec {
    k: u32,
    slot_bits: u32,
    fmt: FixedFmt,
    max_parts: u64,
    modulus_bits: u32,
}

impl PackedCodec {
    /// Derive the packing layout for a session: the smallest slot width
    /// satisfying every headroom term (including `hinv_apply` for up to
    /// `apply_terms` constant-multiply terms per slot), then as many
    /// slots as the modulus can host. Errors name the violated term;
    /// `Capacity` means the modulus cannot host even `k = 2` — the
    /// caller should fall back to the unpacked path.
    pub fn plan(
        modulus_bits: u32,
        fmt: FixedFmt,
        max_parts: u64,
        apply_terms: u64,
    ) -> Result<PackedCodec, PackError> {
        if max_parts == 0 || max_parts > u32::MAX as u64 {
            return Err(PackError::Fanin {
                term: "fanin_sum",
                parts: max_parts as u128,
                max_parts: u32::MAX as u64,
            });
        }
        let w = fmt.w as u32;
        let blind = w + bitlen_u64(max_parts) + BLIND_SIGMA + 1;
        let worst_terms = (max_parts as u128).saturating_mul(apply_terms.max(1) as u128);
        let hinv = 2 * w + bitlen_u128(worst_terms) + 1;
        let slot_bits = blind.max(hinv);
        let k = (modulus_bits.saturating_sub(2)) / slot_bits;
        if k < 2 {
            return Err(PackError::Capacity {
                term: "modulus_capacity",
                needed_bits: 2 * slot_bits as u64 + 2,
                modulus_bits,
            });
        }
        let codec = PackedCodec::from_wire(modulus_bits, fmt, k, slot_bits, max_parts)?;
        codec.apply_headroom(apply_terms)?;
        Ok(codec)
    }

    /// Validate a wire-claimed layout against the headroom terms, in
    /// ascending order of strength, returning the first violated term.
    /// This is the node-side trust boundary: the center claims `(k, b,
    /// max_parts)` in `SetKey`, and a layout that could overflow is a
    /// session error here — before a single statistic is packed.
    ///
    /// `hinv_apply` is *not* checked here (the node does not know the
    /// center's `apply_terms` at key-install time); the center checks
    /// it via [`PackedCodec::apply_headroom`] when planning, and the
    /// packed constant-multiply path re-checks before use.
    pub fn from_wire(
        modulus_bits: u32,
        fmt: FixedFmt,
        k: u32,
        slot_bits: u32,
        max_parts: u64,
    ) -> Result<PackedCodec, PackError> {
        if max_parts == 0 || max_parts > u32::MAX as u64 {
            return Err(PackError::Fanin {
                term: "fanin_sum",
                parts: max_parts as u128,
                max_parts: u32::MAX as u64,
            });
        }
        let w = fmt.w as u32;
        if slot_bits < w {
            return Err(PackError::Headroom {
                term: "per_value",
                needed_bits: w,
                slot_bits,
            });
        }
        let fanin = w + bitlen_u64(max_parts);
        if slot_bits < fanin {
            return Err(PackError::Headroom {
                term: "fanin_sum",
                needed_bits: fanin,
                slot_bits,
            });
        }
        let blind = fanin + BLIND_SIGMA + 1;
        if slot_bits < blind {
            return Err(PackError::Headroom {
                term: "blind_mask",
                needed_bits: blind,
                slot_bits,
            });
        }
        let need = (k as u64) * (slot_bits as u64) + 2;
        if k < 2 || need > modulus_bits as u64 {
            return Err(PackError::Capacity {
                term: "modulus_capacity",
                needed_bits: (k.max(2) as u64) * (slot_bits as u64) + 2,
                modulus_bits,
            });
        }
        Ok(PackedCodec { k, slot_bits, fmt, max_parts, modulus_bits })
    }

    /// Check the `hinv_apply` term: after an `Enc(H̃⁻¹)⊗g` row of up to
    /// `apply_terms` constant-multiply-and-add terms, each slot holds at
    /// most `max_parts·apply_terms·2^(2w−1)` — still strictly inside the
    /// slot, or this errors naming the term.
    pub fn apply_headroom(&self, apply_terms: u64) -> Result<(), PackError> {
        let w = self.fmt.w as u32;
        let worst = (self.max_parts as u128).saturating_mul(apply_terms.max(1) as u128);
        let need = 2 * w + bitlen_u128(worst) + 1;
        if self.slot_bits < need {
            return Err(PackError::Headroom {
                term: "hinv_apply",
                needed_bits: need,
                slot_bits: self.slot_bits,
            });
        }
        Ok(())
    }

    /// Slots per plaintext.
    pub fn k(&self) -> u32 {
        self.k
    }

    /// Slot width `b` in bits.
    pub fn slot_bits(&self) -> u32 {
        self.slot_bits
    }

    /// The fixed-point format the layout was derived from.
    pub fn fmt(&self) -> FixedFmt {
        self.fmt
    }

    /// The fan-in bound the layout was proven against.
    pub fn max_parts(&self) -> u64 {
        self.max_parts
    }

    /// The wire form of this layout.
    pub fn params(&self) -> PackingParams {
        PackingParams { k: self.k, slot_bits: self.slot_bits, max_parts: self.max_parts }
    }

    /// Ciphertexts needed to carry `len` packed values.
    pub fn cts_needed(&self, len: usize) -> usize {
        len.div_ceil(self.k as usize)
    }

    /// Occupied slots in ciphertext `ct_idx` of a `len`-value vector.
    pub fn slots_in_ct(&self, len: usize, ct_idx: usize) -> usize {
        let k = self.k as usize;
        len.saturating_sub(ct_idx * k).min(k)
    }

    /// The per-contribution slot bias `B = 2^(w−1)`.
    pub fn bias(&self) -> BigUint {
        BigUint::one().shl(self.fmt.w - 1)
    }

    /// Fresh metadata for a just-packed `len`-value vector (1
    /// contribution per slot).
    pub fn meta(&self, len: usize) -> PackedMeta {
        PackedMeta { k: self.k, slot_bits: self.slot_bits, len, parts: 1 }
    }

    /// Pack `vals` at scale `2^scale_bits` into plaintexts, slot 0 in
    /// the low bits, one biased contribution per slot. Rounds exactly
    /// like [`FixedCodec::encode_scaled`] (`round(v·2^scale)`, half
    /// away from zero), so a packed and an unpacked encoding of the
    /// same value decode bit-identically.
    ///
    /// [`FixedCodec::encode_scaled`]: crate::crypto::fixed::FixedCodec::encode_scaled
    pub fn pack(&self, vals: &[f64], scale_bits: u32) -> Result<Vec<BigUint>, PackError> {
        let w = self.fmt.w as u32;
        let bias: u128 = 1u128 << (w - 1);
        let b = self.slot_bits as usize;
        let k = self.k as usize;
        let mut out = Vec::with_capacity(self.cts_needed(vals.len()));
        for chunk in vals.chunks(k) {
            let mut m = BigUint::zero();
            for &v in chunk.iter().rev() {
                if !v.is_finite() {
                    return Err(PackError::Value { term: "per_value", value: v, scale_bits });
                }
                let scaled = v * (scale_bits as f64).exp2();
                let mag_f = scaled.abs().round();
                // Strictly below the 2^(w−1) per-value budget, same
                // bound FixedFmt::encode enforces on the GC path.
                if !(mag_f < (((w - 1) as f64).exp2())) {
                    return Err(PackError::Value { term: "per_value", value: v, scale_bits });
                }
                let mag = mag_f as u128;
                let slot = if scaled < 0.0 { bias - mag } else { bias + mag };
                m = m.shl(b).add(&BigUint::from_u128(slot));
            }
            out.push(m);
        }
        Ok(out)
    }

    /// Extract slot `idx` of packed plaintext `m` as a raw (biased,
    /// unnormalized) integer.
    pub fn slot(&self, m: &BigUint, idx: usize) -> BigUint {
        let b = self.slot_bits as usize;
        m.shr(idx * b).rem(&BigUint::one().shl(b))
    }

    /// Unpack `len` values from decrypted plaintexts `ms` holding
    /// `parts` biased contributions per slot, decoding each slot at
    /// scale `2^scale_bits`. The magnitude→`f64` conversion is the one
    /// [`FixedCodec::decode_scaled`] uses, so packed and unpacked
    /// decodes of the same sum are bit-identical.
    ///
    /// [`FixedCodec::decode_scaled`]: crate::crypto::fixed::FixedCodec::decode_scaled
    pub fn unpack_vec(
        &self,
        ms: &[BigUint],
        len: usize,
        parts: u128,
        scale_bits: u32,
    ) -> Result<Vec<f64>, PackError> {
        if parts == 0 || parts > self.max_parts as u128 {
            return Err(PackError::Fanin {
                term: "fanin_sum",
                parts,
                max_parts: self.max_parts,
            });
        }
        let wanted = self.cts_needed(len);
        if ms.len() != wanted {
            return Err(PackError::Shape { wanted_cts: wanted, got_cts: ms.len(), len });
        }
        let k = self.k as usize;
        // Total bias per slot after `parts` contributions, and the
        // fan-in bound parts·2^w no honest slot can reach.
        let bias_total = BigUint::from_u128(parts).shl(self.fmt.w - 1);
        let slot_bound = BigUint::from_u128(parts).shl(self.fmt.w);
        let mut out = Vec::with_capacity(len);
        for i in 0..len {
            // audit:allow(panic-free): i/k < wanted == ms.len() by the Shape check above
            let raw = self.slot(&ms[i / k], i % k);
            if raw.cmp(&slot_bound) != std::cmp::Ordering::Less {
                return Err(PackError::Slot { index: i, parts });
            }
            let (neg, mag) = if raw.cmp(&bias_total) == std::cmp::Ordering::Less {
                (true, bias_total.sub(&raw))
            } else {
                (false, raw.sub(&bias_total))
            };
            let v = magnitude_to_f64(&mag) / (scale_bits as f64).exp2();
            out.push(if neg { -v } else { v });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    fn codec() -> PackedCodec {
        // 256-bit modulus, 5-part fan-in, 5 apply terms → b=86, k=2.
        PackedCodec::plan(256, FMT, 5, 5).expect("layout must fit")
    }

    #[test]
    fn plan_derives_documented_layout() {
        let c = codec();
        // blind_mask: 40 + ⌈log₂6⌉(=3) + 40 + 1 = 84;
        // hinv_apply: 80 + ⌈log₂26⌉(=5) + 1 = 86 → b = 86, k = ⌊254/86⌋.
        assert_eq!(c.slot_bits(), 86);
        assert_eq!(c.k(), 2);
        assert_eq!(c.cts_needed(5), 3);
        assert_eq!(c.slots_in_ct(5, 2), 1);
        assert_eq!(c.cts_needed(0), 0);
        // Production scale: 2048-bit modulus packs ~23 slots.
        let big = PackedCodec::plan(2048, FMT, 5, 12).unwrap();
        assert_eq!(big.slot_bits(), 87);
        assert_eq!(big.k(), 2046 / 87);
        assert!(big.k() >= 20, "2048-bit modulus must pack ≥20 slots");
    }

    #[test]
    fn pack_unpack_roundtrip_with_negatives() {
        let c = codec();
        let vals = [1.5, -2.25, 0.0, -0.000001, 1234.5];
        let ms = c.pack(&vals, FMT.f).unwrap();
        assert_eq!(ms.len(), c.cts_needed(vals.len()));
        let back = c.unpack_vec(&ms, vals.len(), 1, FMT.f).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn plaintext_sum_of_packed_equals_sum() {
        // The homomorphic fold is plaintext addition; model it directly.
        let c = codec();
        let a = [3.25, -7.75, 0.5];
        let b = [-1.25, 2.5, -0.125];
        let ma = c.pack(&a, FMT.f).unwrap();
        let mb = c.pack(&b, FMT.f).unwrap();
        let sums: Vec<BigUint> = ma.iter().zip(&mb).map(|(x, y)| x.add(y)).collect();
        let got = c.unpack_vec(&sums, 3, 2, FMT.f).unwrap();
        for (i, g) in got.iter().enumerate() {
            assert!((g - (a[i] + b[i])).abs() < 1e-6, "slot {i}");
        }
    }

    #[test]
    fn headroom_terms_rejected_in_order() {
        // per_value: slot thinner than w.
        let e = PackedCodec::from_wire(256, FMT, 2, 39, 5).unwrap_err();
        assert!(matches!(e, PackError::Headroom { term: "per_value", .. }), "{e}");
        // fanin_sum: fits one value, not the 5-part sum.
        let e = PackedCodec::from_wire(256, FMT, 2, 42, 5).unwrap_err();
        assert!(matches!(e, PackError::Headroom { term: "fanin_sum", .. }), "{e}");
        // blind_mask: fits the sum, not sum + blind (boundary − 1).
        let e = PackedCodec::from_wire(256, FMT, 2, 83, 5).unwrap_err();
        assert!(matches!(e, PackError::Headroom { term: "blind_mask", .. }), "{e}");
        // Exactly at the blind_mask boundary: accepted.
        assert!(PackedCodec::from_wire(256, FMT, 2, 84, 5).is_ok());
        // modulus_capacity: k·b + 2 over the modulus (boundary + 1).
        let e = PackedCodec::from_wire(171, FMT, 2, 85, 5).unwrap_err();
        assert!(matches!(e, PackError::Capacity { term: "modulus_capacity", .. }), "{e}");
        assert!(PackedCodec::from_wire(172, FMT, 2, 85, 5).is_ok());
        // k = 1 is not packing.
        let e = PackedCodec::from_wire(256, FMT, 1, 85, 5).unwrap_err();
        assert!(matches!(e, PackError::Capacity { .. }), "{e}");
        // hinv_apply is the center-side check.
        let c = PackedCodec::from_wire(256, FMT, 2, 84, 5).unwrap();
        let e = c.apply_headroom(5).unwrap_err();
        assert!(matches!(e, PackError::Headroom { term: "hinv_apply", .. }), "{e}");
        assert!(codec().apply_headroom(5).is_ok());
        // Errors render the violated term by name.
        assert!(e.to_string().contains("hinv_apply"), "{e}");
    }

    #[test]
    fn slot_max_values_pack_and_reject_past_budget() {
        let c = codec();
        // Largest encodable magnitude at scale f: 2^(w−1) − 1 scaled.
        let max = ((1u64 << (FMT.w - 1)) - 1) as f64 / (FMT.f as f64).exp2();
        for v in [max, -max] {
            let ms = c.pack(&[v], FMT.f).unwrap();
            let back = c.unpack_vec(&ms, 1, 1, FMT.f).unwrap();
            assert!((back[0] - v).abs() < 1e-6, "{v} vs {}", back[0]);
        }
        // One past the budget is a per_value rejection, not a wrap.
        let over = (1u64 << (FMT.w - 1)) as f64 / (FMT.f as f64).exp2();
        for v in [over, -over, f64::NAN, f64::INFINITY] {
            let e = c.pack(&[v], FMT.f).unwrap_err();
            assert!(matches!(e, PackError::Value { term: "per_value", .. }), "{v}: {e}");
        }
    }

    #[test]
    fn unpack_guards_parts_shape_and_slots() {
        let c = codec();
        let ms = c.pack(&[1.0, 2.0, 3.0], FMT.f).unwrap();
        // parts over the negotiated bound.
        let e = c.unpack_vec(&ms, 3, 6, FMT.f).unwrap_err();
        assert!(matches!(e, PackError::Fanin { term: "fanin_sum", .. }), "{e}");
        // parts = 0 is meaningless.
        assert!(c.unpack_vec(&ms, 3, 0, FMT.f).is_err());
        // Wrong ciphertext count for the claimed length.
        let e = c.unpack_vec(&ms, 5, 1, FMT.f).unwrap_err();
        assert!(matches!(e, PackError::Shape { wanted_cts: 3, got_cts: 2, .. }), "{e}");
        // A slot holding ≥ parts·2^w is flagged, not mis-decoded.
        let hot = vec![BigUint::one().shl(FMT.w).shl(1)];
        let e = c.unpack_vec(&hot, 1, 1, FMT.f).unwrap_err();
        assert!(matches!(e, PackError::Slot { index: 0, .. }), "{e}");
    }

    #[test]
    fn max_parts_bounds_enforced() {
        assert!(matches!(
            PackedCodec::plan(2048, FMT, 0, 1).unwrap_err(),
            PackError::Fanin { .. }
        ));
        assert!(matches!(
            PackedCodec::plan(2048, FMT, u64::MAX, 1).unwrap_err(),
            PackError::Fanin { .. }
        ));
        // A modulus too small for two slots falls out as Capacity.
        assert!(matches!(
            PackedCodec::plan(128, FMT, 5, 5).unwrap_err(),
            PackError::Capacity { term: "modulus_capacity", .. }
        ));
    }
}
