//! Signed fixed-point codec between `f64` statistics and the Paillier
//! plaintext group `Z_n`.
//!
//! The paper uses "common privacy-preserving floating-point
//! representations" (§6, after Nikolaenko et al. 2013): a real value `v`
//! is represented as `round(v · 2^f)`; negatives wrap into the top half of
//! `Z_n` (two's-complement-style). Homomorphic addition then adds values;
//! scalar multiplication by another fixed-point constant yields scale
//! `2^{2f}`, tracked explicitly by the caller via `scale_bits`.

use std::fmt;

use crate::bigint::{BigInt, BigUint};

/// Default fractional bits. 40 leaves ample headroom in ≥256-bit moduli
/// for double-scale products plus aggregation across thousands of terms.
pub const DEFAULT_FRAC_BITS: u32 = 40;

/// Why a value could not be fixed-point encoded. Wire payloads and
/// datasets are untrusted inputs at the encode boundary, so a bad value
/// must be a session error naming the value and scale, never a panic.
#[derive(Clone, Debug, PartialEq)]
pub enum EncodeError {
    /// NaN or ±∞ has no fixed-point representation.
    NonFinite {
        /// The offending value.
        value: f64,
        /// The scale it was being encoded at.
        scale_bits: u32,
    },
    /// `|v·2^scale|` overflows the 126-bit integer conversion budget.
    Overflow {
        /// The offending value.
        value: f64,
        /// The scale it was being encoded at.
        scale_bits: u32,
    },
    /// The encoded magnitude reaches `n/2`, where it would alias a
    /// negative encoding.
    ModulusRange {
        /// The offending value.
        value: f64,
        /// The scale it was being encoded at.
        scale_bits: u32,
    },
}

impl fmt::Display for EncodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EncodeError::NonFinite { value, scale_bits } => {
                write!(f, "cannot encode non-finite value {value} at scale 2^{scale_bits}")
            }
            EncodeError::Overflow { value, scale_bits } => {
                write!(f, "fixed-point overflow encoding {value} at scale 2^{scale_bits}")
            }
            EncodeError::ModulusRange { value, scale_bits } => write!(
                f,
                "encoding {value} at scale 2^{scale_bits} exceeds n/2 — \
                 raise modulus or lower scale"
            ),
        }
    }
}

impl std::error::Error for EncodeError {}

/// Convert a nonnegative magnitude to `f64` via the top 64 bits + an
/// exponent, keeping precision for values wider than 2^53. Shared by
/// [`FixedCodec::decode_scaled`] and the packed-slot decoder
/// ([`super::packed::PackedCodec::unpack_vec`]) so the two decode paths
/// are bit-identical by construction.
pub fn magnitude_to_f64(mag: &BigUint) -> f64 {
    let bits = mag.bit_len();
    if bits <= 64 {
        mag.low_u64() as f64
    } else {
        let top = mag.shr(bits - 64).low_u64() as f64;
        top * ((bits - 64) as f64).exp2()
    }
}

/// Fixed-point encoder/decoder bound to a plaintext modulus `n`.
#[derive(Clone)]
pub struct FixedCodec {
    /// Plaintext modulus (Paillier `n`).
    pub n: BigUint,
    /// Fractional bits `f` for single-scale encodings.
    pub frac_bits: u32,
    half_n: BigUint,
}

impl FixedCodec {
    /// Create a codec for modulus `n` with `frac_bits` fractional bits.
    pub fn new(n: BigUint, frac_bits: u32) -> Self {
        let half_n = n.shr(1);
        FixedCodec { n, frac_bits, half_n }
    }

    /// Encode a real value at the default scale `2^frac_bits`.
    /// Panicking convenience for center-produced values already known
    /// finite and in range; untrusted inputs go through
    /// [`FixedCodec::encode_scaled`] and surface the error.
    pub fn encode(&self, v: f64) -> BigUint {
        self.encode_scaled(v, self.frac_bits).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Encode at an explicit scale `2^scale_bits`. Errors (naming the
    /// value and scale) instead of panicking: the encode boundary sees
    /// wire- and dataset-derived values, and a hostile payload or a
    /// NaN-bearing dataset must fail the session, not the process.
    pub fn encode_scaled(&self, v: f64, scale_bits: u32) -> Result<BigUint, EncodeError> {
        if !v.is_finite() {
            return Err(EncodeError::NonFinite { value: v, scale_bits });
        }
        let scaled = v * (scale_bits as f64).exp2();
        if !(scaled.abs() < 2f64.powi(126)) {
            return Err(EncodeError::Overflow { value: v, scale_bits });
        }
        let mag = BigUint::from_u128(scaled.abs().round() as u128);
        if !(mag < self.half_n) {
            return Err(EncodeError::ModulusRange { value: v, scale_bits });
        }
        Ok(if scaled < 0.0 && !mag.is_zero() {
            self.n.sub(&mag)
        } else {
            mag
        })
    }

    /// Decode a plaintext at the default scale.
    pub fn decode(&self, m: &BigUint) -> f64 {
        self.decode_scaled(m, self.frac_bits)
    }

    /// Decode at an explicit scale `2^scale_bits` (e.g. `2·frac_bits`
    /// after a fixed-point × fixed-point homomorphic product).
    pub fn decode_scaled(&self, m: &BigUint, scale_bits: u32) -> f64 {
        let signed = self.to_signed(m);
        let v = magnitude_to_f64(signed.magnitude()) / (scale_bits as f64).exp2();
        if signed.is_negative() {
            -v
        } else {
            v
        }
    }

    /// Interpret a plaintext as a signed integer in `(−n/2, n/2]`.
    pub fn to_signed(&self, m: &BigUint) -> BigInt {
        let m = m.rem(&self.n);
        if m > self.half_n {
            BigInt::from_biguint(self.n.sub(&m)).neg()
        } else {
            BigInt::from_biguint(m)
        }
    }

    /// Encode a signed 64-bit integer exactly (scale 0).
    pub fn encode_int(&self, v: i64) -> BigUint {
        if v >= 0 {
            BigUint::from_u64(v as u64)
        } else {
            self.n.sub(&BigUint::from_u64(v.unsigned_abs()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, TestRng};

    fn codec() -> FixedCodec {
        // 2^200-scale modulus stand-in (odd, > any test encoding)
        let n = BigUint::one().shl(200).sub_u64(1);
        FixedCodec::new(n, DEFAULT_FRAC_BITS)
    }

    #[test]
    fn roundtrip_exact_values() {
        let c = codec();
        for v in [0.0, 1.0, -1.0, 0.5, -0.5, 1234.56789, -9876.54321, 1e-9, -1e-9] {
            let dec = c.decode(&c.encode(v));
            assert_close(dec, v, 1e-11, "fixed roundtrip");
        }
    }

    #[test]
    fn roundtrip_property_random() {
        let c = codec();
        let mut rng = TestRng::new(17);
        for _ in 0..200 {
            let v = rng.range_f64(-1e6, 1e6);
            assert_close(c.decode(&c.encode(v)), v, 1e-10, "random roundtrip");
        }
    }

    #[test]
    fn addition_in_plaintext_space() {
        let c = codec();
        let a = 3.25;
        let b = -7.75;
        let sum = c.encode(a).add(&c.encode(b)).rem(&c.n);
        assert_close(c.decode(&sum), a + b, 1e-11, "signed add wraps correctly");
    }

    #[test]
    fn product_double_scale() {
        let c = codec();
        let a = -12.5;
        let b = 3.0;
        // plaintext-space product of encodings = value product at 2f scale
        let prod = c.encode(a).mul(&c.encode(b)).rem(&c.n);
        assert_close(
            c.decode_scaled(&prod, 2 * DEFAULT_FRAC_BITS),
            a * b,
            1e-9,
            "product decodes at 2f",
        );
    }

    #[test]
    fn encode_int_signed() {
        let c = codec();
        assert_eq!(c.to_signed(&c.encode_int(-42)), BigInt::from_i64(-42));
        assert_eq!(c.to_signed(&c.encode_int(42)), BigInt::from_i64(42));
    }

    /// Non-finite and out-of-range inputs are `Err`s naming the value
    /// and scale — a hostile node payload or NaN-bearing dataset must
    /// be a session error, not a center/node panic (the regression for
    /// the former `assert!`-based encode path).
    #[test]
    fn nan_rejected() {
        let c = codec();
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let e = c.encode_scaled(v, 24).expect_err("non-finite must not encode");
            assert!(matches!(e, EncodeError::NonFinite { .. }), "{v}: {e}");
            assert!(e.to_string().contains("non-finite"), "{e}");
            assert!(e.to_string().contains("2^24"), "error must name the scale: {e}");
        }
    }

    #[test]
    fn overflow_rejected_with_value_and_scale() {
        let c = codec();
        // 2.5 · 2^200 blows the 126-bit conversion budget.
        let e = c.encode_scaled(2.5, 200).expect_err("overflow must not encode");
        assert_eq!(e, EncodeError::Overflow { value: 2.5, scale_bits: 200 });
        assert!(e.to_string().contains("2.5"), "error must name the value: {e}");
        assert!(e.to_string().contains("2^200"), "error must name the scale: {e}");
        // A magnitude at n/2 aliases a negative encoding: ModulusRange.
        let tiny = FixedCodec::new(BigUint::from_u64(1_000_001), 0);
        let e = tiny.encode_scaled(600_000.0, 0).expect_err("n/2 must not encode");
        assert!(matches!(e, EncodeError::ModulusRange { .. }), "{e}");
        // In-range values still encode.
        assert!(tiny.encode_scaled(400_000.0, 0).is_ok());
    }

    /// The shared magnitude→f64 helper is exactly the decode path's
    /// conversion (packed and unpacked decodes stay bit-identical).
    #[test]
    fn magnitude_to_f64_matches_decode() {
        let c = codec();
        for v in [0.0, 1.0, 0.5, 1234.56789, 9.9e15, 1e37] {
            let m = c.encode_scaled(v, 0).unwrap();
            assert_eq!(
                magnitude_to_f64(&m).to_bits(),
                c.decode_scaled(&m, 0).to_bits(),
                "{v}"
            );
        }
    }
}
