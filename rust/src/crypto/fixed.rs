//! Signed fixed-point codec between `f64` statistics and the Paillier
//! plaintext group `Z_n`.
//!
//! The paper uses "common privacy-preserving floating-point
//! representations" (§6, after Nikolaenko et al. 2013): a real value `v`
//! is represented as `round(v · 2^f)`; negatives wrap into the top half of
//! `Z_n` (two's-complement-style). Homomorphic addition then adds values;
//! scalar multiplication by another fixed-point constant yields scale
//! `2^{2f}`, tracked explicitly by the caller via `scale_bits`.

use crate::bigint::{BigInt, BigUint};

/// Default fractional bits. 40 leaves ample headroom in ≥256-bit moduli
/// for double-scale products plus aggregation across thousands of terms.
pub const DEFAULT_FRAC_BITS: u32 = 40;

/// Fixed-point encoder/decoder bound to a plaintext modulus `n`.
#[derive(Clone)]
pub struct FixedCodec {
    /// Plaintext modulus (Paillier `n`).
    pub n: BigUint,
    /// Fractional bits `f` for single-scale encodings.
    pub frac_bits: u32,
    half_n: BigUint,
}

impl FixedCodec {
    /// Create a codec for modulus `n` with `frac_bits` fractional bits.
    pub fn new(n: BigUint, frac_bits: u32) -> Self {
        let half_n = n.shr(1);
        FixedCodec { n, frac_bits, half_n }
    }

    /// Encode a real value at the default scale `2^frac_bits`.
    pub fn encode(&self, v: f64) -> BigUint {
        self.encode_scaled(v, self.frac_bits)
    }

    /// Encode at an explicit scale `2^scale_bits`.
    pub fn encode_scaled(&self, v: f64, scale_bits: u32) -> BigUint {
        assert!(v.is_finite(), "cannot encode non-finite value {v}");
        let scaled = v * (scale_bits as f64).exp2();
        assert!(
            scaled.abs() < 2f64.powi(126),
            "fixed-point overflow encoding {v} at 2^{scale_bits}"
        );
        let mag = BigUint::from_u128(scaled.abs().round() as u128);
        assert!(
            mag < self.half_n,
            "encoded magnitude exceeds n/2 — raise modulus or lower scale"
        );
        if scaled < 0.0 && !mag.is_zero() {
            self.n.sub(&mag)
        } else {
            mag
        }
    }

    /// Decode a plaintext at the default scale.
    pub fn decode(&self, m: &BigUint) -> f64 {
        self.decode_scaled(m, self.frac_bits)
    }

    /// Decode at an explicit scale `2^scale_bits` (e.g. `2·frac_bits`
    /// after a fixed-point × fixed-point homomorphic product).
    pub fn decode_scaled(&self, m: &BigUint, scale_bits: u32) -> f64 {
        let signed = self.to_signed(m);
        let mag = signed.magnitude();
        // Convert magnitude to f64 via the top 64 bits + exponent to keep
        // precision for values wider than 2^53.
        let bits = mag.bit_len();
        let v = if bits <= 64 {
            mag.low_u64() as f64
        } else {
            let top = mag.shr(bits - 64).low_u64() as f64;
            top * ((bits - 64) as f64).exp2()
        };
        let v = v / (scale_bits as f64).exp2();
        if signed.is_negative() {
            -v
        } else {
            v
        }
    }

    /// Interpret a plaintext as a signed integer in `(−n/2, n/2]`.
    pub fn to_signed(&self, m: &BigUint) -> BigInt {
        let m = m.rem(&self.n);
        if m > self.half_n {
            BigInt::from_biguint(self.n.sub(&m)).neg()
        } else {
            BigInt::from_biguint(m)
        }
    }

    /// Encode a signed 64-bit integer exactly (scale 0).
    pub fn encode_int(&self, v: i64) -> BigUint {
        if v >= 0 {
            BigUint::from_u64(v as u64)
        } else {
            self.n.sub(&BigUint::from_u64(v.unsigned_abs()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_close, TestRng};

    fn codec() -> FixedCodec {
        // 2^200-scale modulus stand-in (odd, > any test encoding)
        let n = BigUint::one().shl(200).sub_u64(1);
        FixedCodec::new(n, DEFAULT_FRAC_BITS)
    }

    #[test]
    fn roundtrip_exact_values() {
        let c = codec();
        for v in [0.0, 1.0, -1.0, 0.5, -0.5, 1234.56789, -9876.54321, 1e-9, -1e-9] {
            let dec = c.decode(&c.encode(v));
            assert_close(dec, v, 1e-11, "fixed roundtrip");
        }
    }

    #[test]
    fn roundtrip_property_random() {
        let c = codec();
        let mut rng = TestRng::new(17);
        for _ in 0..200 {
            let v = rng.range_f64(-1e6, 1e6);
            assert_close(c.decode(&c.encode(v)), v, 1e-10, "random roundtrip");
        }
    }

    #[test]
    fn addition_in_plaintext_space() {
        let c = codec();
        let a = 3.25;
        let b = -7.75;
        let sum = c.encode(a).add(&c.encode(b)).rem(&c.n);
        assert_close(c.decode(&sum), a + b, 1e-11, "signed add wraps correctly");
    }

    #[test]
    fn product_double_scale() {
        let c = codec();
        let a = -12.5;
        let b = 3.0;
        // plaintext-space product of encodings = value product at 2f scale
        let prod = c.encode(a).mul(&c.encode(b)).rem(&c.n);
        assert_close(
            c.decode_scaled(&prod, 2 * DEFAULT_FRAC_BITS),
            a * b,
            1e-9,
            "product decodes at 2f",
        );
    }

    #[test]
    fn encode_int_signed() {
        let c = codec();
        assert_eq!(c.to_signed(&c.encode_int(-42)), BigInt::from_i64(-42));
        assert_eq!(c.to_signed(&c.encode_int(42)), BigInt::from_i64(42));
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn nan_rejected() {
        codec().encode(f64::NAN);
    }
}
