//! The Paillier additively-homomorphic cryptosystem (Paillier, EUROCRYPT'99).
//!
//! Used for the paper's Type-1 computations: nodes encrypt their local
//! summaries (gradients, Gram matrices, log-likelihoods) and the Center
//! aggregates them under encryption (`⊕`, `⊖`, scalar `⊗`).
//!
//! Standard construction with `g = n + 1`, which makes encryption
//! `c = (1 + m·n) · rⁿ mod n²` (one modpow instead of two) and decryption
//! `m = L(c^λ mod n²) · μ mod n` with `L(u) = (u − 1)/n`.
//! Decryption uses the CRT split over `p²`/`q²` (≈4× speedup).
//!
//! ## Hot-path engineering
//!
//! * **Fixed-base encryption** — the DJN short-exponent base `h_n` is
//!   the same for every `encrypt`/`rerandomize` under a key, so the key
//!   carries a one-time radix-2^w table ([`crate::bigint::FixedBase`])
//!   that turns each encryption's modpow into ~`256/w` multiplications
//!   with zero squarings. [`PublicKey::encrypt_reference`] keeps the
//!   generic-modpow path callable for parity tests and benches.
//! * **Cached CRT contexts** — [`PrivateKey`] holds the Montgomery
//!   contexts for `p²`/`q²` (and the fixed exponents `p−1`, `q−1`), so
//!   decryption never rebuilds `R`/`R²` per call.
//! * **Cheap `⊖`** — [`PublicKey::sub`] inverts the subtrahend with one
//!   extended-gcd modular inverse instead of a modulus-sized
//!   exponentiation ([`PublicKey::sub_reference`]).
//! * **Montgomery-resident batches** — [`MontCiphertext`] /
//!   [`PublicKey::add_many`] keep ciphertexts in Montgomery form across
//!   an aggregation fold, entering the domain once per operand.
//! * **Batch encryption** — [`PublicKey::encrypt_batch`] draws all
//!   randomness serially (the RNG stream is identical to sequential
//!   `encrypt` calls, so outputs are bit-identical) and fans the modpow
//!   work across scoped worker threads.

use std::sync::Arc;

use crate::bigint::{gen_prime, BigUint, FixedBase, MontElem, Montgomery, RandomSource};
use crate::runtime::pool;

/// Paillier public key (modulus `n`, implicit generator `g = n+1`).
#[derive(Clone)]
pub struct PublicKey {
    /// Modulus `n = p·q`.
    pub n: BigUint,
    /// `n²`, the ciphertext modulus.
    pub n2: BigUint,
    /// Montgomery context for `n²` (shared; ciphertext ops are the hot path).
    mont_n2: Arc<Montgomery>,
    /// `h = h₀ⁿ mod n²` for short-exponent (Damgård–Jurik–Nielsen-style)
    /// encryption: `c = (1+mn)·h^s` with a short random `s`. `h₀` is a
    /// nothing-up-my-sleeve value derived by hashing `n`, so the key
    /// reconstructs identically on every party.
    h_n: Arc<BigUint>,
    /// Fixed-base table for `h_n` over the short-exponent range — the
    /// per-key precomputation behind fast `encrypt`/`rerandomize`.
    h_fb: Arc<FixedBase>,
}

/// Short-exponent bits for DJN-style encryption (≥2× statistical security
/// of 112-bit; the paper's semi-honest model).
const SHORT_EXP_BITS: usize = 256;

/// Derive the nothing-up-my-sleeve base `h₀` from `n` via SHA-256 stream.
fn derive_h0(n: &BigUint) -> BigUint {
    use sha2::{Digest, Sha256};
    let mut out = Vec::new();
    let nb = n.to_bytes_le();
    let mut ctr = 0u32;
    while out.len() * 8 < n.bit_len() + 64 {
        let mut hasher = Sha256::new();
        hasher.update(b"privlogit-paillier-h0");
        hasher.update(&nb);
        hasher.update(ctr.to_le_bytes());
        out.extend_from_slice(&hasher.finalize());
        ctr += 1;
    }
    BigUint::from_bytes_le(&out).rem(n)
}

/// Paillier private key.
#[derive(Clone)]
pub struct PrivateKey {
    /// Carmichael `λ = lcm(p−1, q−1)`.
    pub lambda: BigUint,
    /// `μ = L(g^λ mod n²)^-1 mod n`.
    pub mu: BigUint,
    /// Public part (decryption needs `n`, `n²`).
    pub pk: PublicKey,
    // CRT acceleration.
    p2: BigUint,
    q2: BigUint,
    /// `λ mod (p−1)·p` exponent pieces and per-prime μ values.
    hp: BigUint,
    hq: BigUint,
    p: BigUint,
    q: BigUint,
    /// `q^-1 mod p` for CRT recombination.
    qinv_p: BigUint,
    /// Cached Montgomery contexts for the CRT moduli (decryption never
    /// rebuilds `R`/`R²` per call) and the fixed CRT exponents.
    mont_p2: Arc<Montgomery>,
    mont_q2: Arc<Montgomery>,
    p1: BigUint,
    q1: BigUint,
}

/// Key pair.
pub struct Keypair {
    pub pk: PublicKey,
    pub sk: PrivateKey,
}

// Secret material must never reach a Debug surface (log line, span
// field, panic message). These impls are deliberately opaque — the
// `audit` secret-flow rule rejects any derive or field-dumping impl.
impl std::fmt::Debug for PrivateKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("PrivateKey(<redacted>)")
    }
}

impl std::fmt::Debug for Keypair {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Keypair(<redacted>)")
    }
}

/// A Paillier ciphertext (an element of `Z*_{n²}`).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Ciphertext(pub BigUint);

/// A Paillier ciphertext resident in Montgomery form mod `n²`. Used by
/// add-heavy batches ([`PublicKey::add_mont`]): each value enters the
/// Montgomery domain once ([`PublicKey::ct_to_mont`]) however many
/// homomorphic additions it participates in, and leaves once at the
/// wire/batch boundary ([`PublicKey::ct_from_mont`]).
#[derive(Clone)]
pub struct MontCiphertext(MontElem);

impl Ciphertext {
    /// Serialized size in bytes (for communication accounting).
    pub fn byte_len(&self) -> usize {
        self.0.to_bytes_le().len()
    }
}

impl Keypair {
    /// Generate a fresh keypair with an `n` of `modulus_bits` bits.
    ///
    /// `modulus_bits` = 2048 matches the paper's security parameter;
    /// tests and fast experiments use smaller keys (the protocols scale
    /// every method identically in the key size, so *relative* results
    /// are preserved — see DESIGN.md §7).
    pub fn generate(modulus_bits: usize, rng: &mut dyn RandomSource) -> Keypair {
        assert!(modulus_bits >= 64, "modulus too small");
        let half = modulus_bits / 2;
        let (p, q) = loop {
            let p = gen_prime(half, rng);
            let q = gen_prime(half, rng);
            if p != q {
                break (p, q);
            }
        };
        let n = p.mul(&q);
        let n2 = n.mul(&n);
        let pk = PublicKey::from_modulus(n.clone(), n2.clone());
        let p1 = p.sub_u64(1);
        let q1 = q.sub_u64(1);
        let lambda = p1.lcm(&q1);
        // g = n+1 ⇒ g^λ mod n² = 1 + λ·n mod n² ⇒ L(g^λ) = λ mod n.
        let mu = lambda
            .rem(&n)
            .modinv(&n)
            .expect("λ invertible mod n for distinct primes");
        let p2 = p.mul(&p);
        let q2 = q.mul(&q);
        // h_p = L_p(g^{p-1} mod p²)^-1 mod p, with L_p(u) = (u-1)/p.
        let hp = Self::h_exp(&n, &p, &p2, &p1);
        let hq = Self::h_exp(&n, &q, &q2, &q1);
        let qinv_p = q.modinv(&p).expect("p, q coprime");
        let mont_p2 = Arc::new(Montgomery::new(&p2));
        let mont_q2 = Arc::new(Montgomery::new(&q2));
        let sk = PrivateKey {
            lambda,
            mu,
            pk: pk.clone(),
            p2,
            q2,
            hp,
            hq,
            p,
            q,
            qinv_p,
            mont_p2,
            mont_q2,
            p1,
            q1,
        };
        Keypair { pk, sk }
    }

    /// `h = L_s(g^{s-1} mod s²)^{-1} mod s` for prime `s` (g = n+1).
    fn h_exp(n: &BigUint, s: &BigUint, s2: &BigUint, s1: &BigUint) -> BigUint {
        let g = n.add_u64(1).rem(s2);
        let gs = g.modpow(s1, s2);
        let l = gs.sub_u64(1).divrem(s).0;
        l.rem(s).modinv(s).expect("L(g^{s-1}) invertible mod s")
    }
}

impl PublicKey {
    /// Rebuild a public key from its modulus (e.g. received over a
    /// channel; `n²` passed in to avoid recomputing when already known).
    /// Builds the per-key fixed-base encryption table (a one-time
    /// `O(2^w·256/w)`-multiplication precomputation).
    pub fn from_modulus(n: BigUint, n2: BigUint) -> Self {
        debug_assert_eq!(n.mul(&n), n2);
        let mont = Montgomery::new(&n2);
        let h0 = derive_h0(&n);
        let h_n = mont.pow(&h0, &n);
        let h_fb = mont.fixed_base(&h_n, SHORT_EXP_BITS);
        PublicKey {
            mont_n2: Arc::new(mont),
            n,
            n2,
            h_n: Arc::new(h_n),
            h_fb: Arc::new(h_fb),
        }
    }

    /// The shared Montgomery context for `n²` — for batch ciphertext
    /// algebra (multi-exponentiation, Montgomery-resident folds) built
    /// on [`crate::bigint::MontElem`].
    pub fn n2_mont(&self) -> Arc<Montgomery> {
        self.mont_n2.clone()
    }

    /// Draw a short DJN exponent (the per-encryption randomness).
    fn short_exp(rng: &mut ChaChaSource<'_>) -> BigUint {
        let mut sbytes = [0u8; SHORT_EXP_BITS / 8];
        rng.0.fill_bytes(&mut sbytes);
        BigUint::from_bytes_le(&sbytes)
    }

    /// Encrypt plaintext `m ∈ Z_n`: `c = (1 + m·n) · h^s mod n²` with a
    /// short random exponent `s` (DJN-style). `h^s` comes from the
    /// per-key fixed-base table — ~43 multiplications, zero squarings —
    /// and the final product is one mixed Montgomery multiplication.
    pub fn encrypt(&self, m: &BigUint, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let s = Self::short_exp(rng);
        self.encrypt_with_short_exp(m, &s)
    }

    /// Deterministic DJN encryption with a caller-chosen short exponent
    /// (the batch-encryption worker body; randomness is drawn by the
    /// caller so parallel execution preserves the RNG stream).
    pub fn encrypt_with_short_exp(&self, m: &BigUint, s: &BigUint) -> Ciphertext {
        let m = m.rem(&self.n);
        let gm = BigUint::one().add(&m.mul(&self.n)).rem(&self.n2);
        let hs = self.mont_n2.pow_fixed(&self.h_fb, s);
        Ciphertext(self.mont_n2.mul_elem_plain(&hs, &gm))
    }

    /// Reference DJN encryption through the generic windowed modpow (the
    /// pre-fixed-base hot path). Bit-identical to [`PublicKey::encrypt`]
    /// on the same RNG stream; kept callable for parity tests and the
    /// micro-bench speedup comparison.
    pub fn encrypt_reference(&self, m: &BigUint, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let s = Self::short_exp(rng);
        let m = m.rem(&self.n);
        let gm = BigUint::one().add(&m.mul(&self.n)).rem(&self.n2);
        let hs = self.mont_n2.pow(&self.h_n, &s);
        Ciphertext(self.mont_n2.mul(&gm, &hs))
    }

    /// Batch DJN encryption: all short exponents are drawn from `rng`
    /// first (serially — the stream is identical to sequential
    /// [`PublicKey::encrypt`] calls, so the ciphertexts are
    /// bit-identical whatever `workers` is), then the modpow work fans
    /// out across scoped worker threads.
    pub fn encrypt_batch(
        &self,
        ms: &[BigUint],
        rng: &mut ChaChaSource<'_>,
        workers: usize,
    ) -> Vec<Ciphertext> {
        let exps: Vec<BigUint> = ms.iter().map(|_| Self::short_exp(rng)).collect();
        pool::par_map_indexed(ms.len(), workers, |i| {
            self.encrypt_with_short_exp(&ms[i], &exps[i])
        })
    }

    /// Full-range-randomness encryption `c = (1 + m·n) · rⁿ mod n²`
    /// (classical Paillier; kept for protocols that must pick `r`).
    pub fn encrypt_full(&self, m: &BigUint, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let m = m.rem(&self.n);
        let r = rng.unit(&self.n);
        self.encrypt_with_r(&m, &r)
    }

    /// Deterministic encryption with caller-chosen randomness (tests,
    /// blinding protocols that must reuse `r`).
    pub fn encrypt_with_r(&self, m: &BigUint, r: &BigUint) -> Ciphertext {
        let gm = BigUint::one().add(&m.rem(&self.n).mul(&self.n)).rem(&self.n2);
        let rn = self.mont_n2.pow(r, &self.n);
        Ciphertext(self.mont_n2.mul(&gm, &rn))
    }

    /// "Trivial" encryption with fixed randomness r=1 (no semantic
    /// security; used for public constants inside protocols).
    pub fn encrypt_trivial(&self, m: &BigUint) -> Ciphertext {
        Ciphertext(BigUint::one().add(&m.rem(&self.n).mul(&self.n)).rem(&self.n2))
    }

    /// Homomorphic addition `Enc(a) ⊕ Enc(b) = Enc(a + b)`.
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        Ciphertext(self.mont_n2.mul(&a.0, &b.0))
    }

    /// Bring a ciphertext into Montgomery-resident form for a batch of
    /// additions.
    pub fn ct_to_mont(&self, c: &Ciphertext) -> MontCiphertext {
        MontCiphertext(self.mont_n2.enter(&c.0))
    }

    /// Leave Montgomery-resident form (canonical ciphertext residue).
    pub fn ct_from_mont(&self, c: &MontCiphertext) -> Ciphertext {
        Ciphertext(self.mont_n2.exit(&c.0))
    }

    /// Homomorphic addition over Montgomery-resident ciphertexts: one
    /// CIOS pass, no domain conversions, no divisions.
    pub fn add_mont(&self, a: &MontCiphertext, b: &MontCiphertext) -> MontCiphertext {
        MontCiphertext(self.mont_n2.mul_elem(&a.0, &b.0))
    }

    /// `⊕`-fold a batch of ciphertexts: every operand enters the
    /// Montgomery domain exactly once (the accumulator stays resident;
    /// the last operand rides the exit multiplication), versus one
    /// re-entry per addition for a fold over [`PublicKey::add`].
    /// Panics on an empty batch.
    pub fn add_many(&self, cts: &[&Ciphertext]) -> Ciphertext {
        assert!(!cts.is_empty(), "add_many needs at least one ciphertext");
        if cts.len() == 1 {
            return cts[0].clone();
        }
        let m = &self.mont_n2;
        let mut acc = m.enter(&cts[0].0);
        for c in &cts[1..cts.len() - 1] {
            acc = m.mul_elem(&acc, &m.enter(&c.0));
        }
        Ciphertext(m.mul_elem_plain(&acc, &cts[cts.len() - 1].0))
    }

    /// Homomorphic subtraction `Enc(a) ⊖ Enc(b) = Enc(a − b)`: one
    /// extended-gcd modular inverse of the subtrahend (`Enc(b)⁻¹ mod n²`
    /// is a valid encryption of `−b`) plus one multiplication — versus
    /// the modulus-sized exponentiation of [`PublicKey::sub_reference`].
    /// The result decrypts identically but is not bit-equal to the
    /// reference (the implicit randomness exponent differs in sign).
    pub fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let inv = b.0.modinv(&self.n2).expect("ciphertext invertible mod n²");
        Ciphertext(self.mont_n2.mul(&a.0, &inv))
    }

    /// Reference subtraction via `Enc(b)^(n−1)` — a full modulus-sized
    /// scalar multiplication per call (the hidden perf bug this module
    /// fixed); kept callable for parity tests and the micro-bench.
    pub fn sub_reference(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        let neg_b = self.scalar_mul(b, &self.n.sub_u64(1));
        self.add(a, &neg_b)
    }

    /// Homomorphic scalar multiplication `Enc(a) ⊗ k = Enc(a·k)`.
    pub fn scalar_mul(&self, a: &Ciphertext, k: &BigUint) -> Ciphertext {
        Ciphertext(self.mont_n2.pow(&a.0, &k.rem(&self.n)))
    }

    /// Re-randomize: multiply by a fresh encryption of zero (short
    /// exponent through the fixed-base table, like [`PublicKey::encrypt`]).
    pub fn rerandomize(&self, a: &Ciphertext, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let s = Self::short_exp(rng);
        let hs = self.mont_n2.pow_fixed(&self.h_fb, &s);
        Ciphertext(self.mont_n2.mul_elem_plain(&hs, &a.0))
    }

    /// Serialized public-key bytes (communication accounting).
    pub fn byte_len(&self) -> usize {
        self.n.to_bytes_le().len()
    }
}

impl PrivateKey {
    /// Decrypt via CRT: `m_p = L_p(c^{p−1} mod p²)·h_p mod p` (same for q),
    /// recombined with Garner's formula. The `p²`/`q²` Montgomery
    /// contexts are cached on the key.
    pub fn decrypt(&self, c: &Ciphertext) -> BigUint {
        let cp = self.mont_p2.pow(&c.0.rem(&self.p2), &self.p1);
        let cq = self.mont_q2.pow(&c.0.rem(&self.q2), &self.q1);
        let mp = cp.sub_u64(1).divrem(&self.p).0.mul_mod(&self.hp, &self.p);
        let mq = cq.sub_u64(1).divrem(&self.q).0.mul_mod(&self.hq, &self.q);
        // Garner: m = mq + q * ((mp - mq) * qinv mod p)
        let diff = mp.sub_mod(&mq.rem(&self.p), &self.p);
        let t = diff.mul_mod(&self.qinv_p, &self.p);
        mq.add(&self.q.mul(&t))
    }

    /// Reference (non-CRT) decryption `L(c^λ mod n²)·μ mod n` — kept for
    /// cross-checking the CRT path in tests.
    pub fn decrypt_plain(&self, c: &Ciphertext) -> BigUint {
        let u = c.0.modpow(&self.lambda, &self.pk.n2);
        let l = u.sub_u64(1).divrem(&self.pk.n).0;
        l.mul_mod(&self.mu, &self.pk.n)
    }
}

/// A thin adapter so `PublicKey` methods can take any [`RandomSource`]
/// without generic churn at every call site.
pub struct ChaChaSource<'a>(pub &'a mut dyn RandomSource);

impl ChaChaSource<'_> {
    fn unit(&mut self, n: &BigUint) -> BigUint {
        loop {
            let r = self.0.below(n);
            if !r.is_zero() && r.gcd(n).is_one() {
                return r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::ChaChaRng;

    fn setup() -> (Keypair, ChaChaRng) {
        let mut rng = ChaChaRng::from_u64_seed(1234);
        let kp = Keypair::generate(256, &mut rng);
        (kp, rng)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (kp, mut rng) = setup();
        for v in [0u64, 1, 42, 1 << 40, u64::MAX] {
            let m = BigUint::from_u64(v);
            let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
            assert_eq!(kp.sk.decrypt(&c), m, "roundtrip {v}");
            assert_eq!(kp.sk.decrypt_plain(&c), m, "plain decrypt {v}");
        }
    }

    /// The fixed-base encryption path is bit-identical to the generic
    /// modpow reference on the same RNG stream.
    #[test]
    fn fixed_base_encrypt_matches_reference() {
        let (kp, _) = setup();
        let mut rng_a = ChaChaRng::from_u64_seed(777);
        let mut rng_b = ChaChaRng::from_u64_seed(777);
        for v in [0u64, 3, 1 << 33, u64::MAX] {
            let m = BigUint::from_u64(v);
            let fast = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng_a));
            let refc = kp.pk.encrypt_reference(&m, &mut ChaChaSource(&mut rng_b));
            assert_eq!(fast, refc, "fixed-base vs reference at {v}");
        }
    }

    /// Batch encryption is bit-identical to sequential encryption on the
    /// same stream, for any worker count.
    #[test]
    fn batch_encrypt_matches_serial() {
        let (kp, _) = setup();
        let ms: Vec<BigUint> = (0..9u64).map(|i| BigUint::from_u64(i * i + 5)).collect();
        let mut rng_serial = ChaChaRng::from_u64_seed(31);
        let serial: Vec<Ciphertext> = ms
            .iter()
            .map(|m| kp.pk.encrypt(m, &mut ChaChaSource(&mut rng_serial)))
            .collect();
        for workers in [1usize, 4] {
            let mut rng_batch = ChaChaRng::from_u64_seed(31);
            let batch = kp.pk.encrypt_batch(&ms, &mut ChaChaSource(&mut rng_batch), workers);
            assert_eq!(batch, serial, "workers={workers}");
        }
    }

    #[test]
    fn crt_matches_plain_decrypt() {
        let (kp, mut rng) = setup();
        for _ in 0..10 {
            let m = rng.below(&kp.pk.n);
            let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
            assert_eq!(kp.sk.decrypt(&c), kp.sk.decrypt_plain(&c));
        }
    }

    #[test]
    fn homomorphic_add_sub() {
        let (kp, mut rng) = setup();
        let a = BigUint::from_u64(1_000_000);
        let b = BigUint::from_u64(2_345_678);
        let ca = kp.pk.encrypt(&a, &mut ChaChaSource(&mut rng));
        let cb = kp.pk.encrypt(&b, &mut ChaChaSource(&mut rng));
        assert_eq!(kp.sk.decrypt(&kp.pk.add(&ca, &cb)), a.add(&b));
        assert_eq!(kp.sk.decrypt(&kp.pk.sub(&cb, &ca)), b.sub(&a));
        // subtraction that wraps (negative result ≡ n - diff)
        let wrapped = kp.sk.decrypt(&kp.pk.sub(&ca, &cb));
        assert_eq!(wrapped, kp.pk.n.sub(&b.sub(&a)));
    }

    /// The inverse-based `⊖` decrypts identically to the reference
    /// scalar-multiplication path in both orders.
    #[test]
    fn sub_matches_reference_path() {
        let (kp, mut rng) = setup();
        for (x, y) in [(5u64, 3u64), (3, 5), (1 << 30, 77), (0, 12)] {
            let cx = kp.pk.encrypt(&BigUint::from_u64(x), &mut ChaChaSource(&mut rng));
            let cy = kp.pk.encrypt(&BigUint::from_u64(y), &mut ChaChaSource(&mut rng));
            assert_eq!(
                kp.sk.decrypt(&kp.pk.sub(&cx, &cy)),
                kp.sk.decrypt(&kp.pk.sub_reference(&cx, &cy)),
                "sub parity at ({x}, {y})"
            );
        }
    }

    #[test]
    fn homomorphic_scalar_mul() {
        let (kp, mut rng) = setup();
        let a = BigUint::from_u64(98765);
        let k = BigUint::from_u64(4321);
        let ca = kp.pk.encrypt(&a, &mut ChaChaSource(&mut rng));
        let ck = kp.pk.scalar_mul(&ca, &k);
        assert_eq!(kp.sk.decrypt(&ck), a.mul(&k));
    }

    #[test]
    fn rerandomize_changes_ciphertext_not_plaintext() {
        let (kp, mut rng) = setup();
        let m = BigUint::from_u64(7);
        let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        let c2 = kp.pk.rerandomize(&c, &mut ChaChaSource(&mut rng));
        assert_ne!(c, c2);
        assert_eq!(kp.sk.decrypt(&c2), m);
    }

    #[test]
    fn ciphertexts_probabilistic() {
        let (kp, mut rng) = setup();
        let m = BigUint::from_u64(5);
        let c1 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        let c2 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        assert_ne!(c1, c2, "semantic security: same plaintext, different ct");
    }

    #[test]
    fn trivial_encryption_decrypts() {
        let (kp, _) = setup();
        let m = BigUint::from_u64(314159);
        assert_eq!(kp.sk.decrypt(&kp.pk.encrypt_trivial(&m)), m);
    }

    /// Property: sum of many encryptions decrypts to sum of plaintexts —
    /// exactly the Center's aggregation pattern (Alg. 1 step 8).
    #[test]
    fn aggregation_property() {
        let (kp, mut rng) = setup();
        let mut acc = kp.pk.encrypt_trivial(&BigUint::zero());
        let mut expect = BigUint::zero();
        for i in 1..=20u64 {
            let m = BigUint::from_u64(i * i * 31);
            let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
            acc = kp.pk.add(&acc, &c);
            expect = expect.add(&m);
        }
        assert_eq!(kp.sk.decrypt(&acc), expect);
    }

    /// The Montgomery-resident fold is bit-identical to a chain of
    /// plain `add`s, and `ct_to_mont`/`ct_from_mont` round-trips.
    #[test]
    fn montgomery_resident_fold_matches_add_chain() {
        let (kp, mut rng) = setup();
        let cts: Vec<Ciphertext> = (1..=7u64)
            .map(|i| kp.pk.encrypt(&BigUint::from_u64(i * 13), &mut ChaChaSource(&mut rng)))
            .collect();
        let mut chain = cts[0].clone();
        for c in &cts[1..] {
            chain = kp.pk.add(&chain, c);
        }
        let refs: Vec<&Ciphertext> = cts.iter().collect();
        assert_eq!(kp.pk.add_many(&refs), chain, "fold vs chain");
        assert_eq!(kp.pk.add_many(&refs[..1]), cts[0], "singleton fold");

        let rt = kp.pk.ct_from_mont(&kp.pk.ct_to_mont(&cts[0]));
        assert_eq!(rt, cts[0], "resident round-trip");
        let ab = kp.pk.add_mont(&kp.pk.ct_to_mont(&cts[0]), &kp.pk.ct_to_mont(&cts[1]));
        assert_eq!(kp.pk.ct_from_mont(&ab), kp.pk.add(&cts[0], &cts[1]), "add_mont parity");
        // Resident scalar-mul (pow over a Montgomery-resident base)
        // round-trips to the plain-form scalar_mul result.
        let mont = kp.pk.n2_mont();
        let k = BigUint::from_u64(0xBEEF);
        let resident = mont.exit(&mont.pow_elem(&mont.enter(&cts[0].0), &k));
        assert_eq!(resident, kp.pk.scalar_mul(&cts[0], &k).0, "resident scalar-mul parity");
    }
}
