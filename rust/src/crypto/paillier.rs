//! The Paillier additively-homomorphic cryptosystem (Paillier, EUROCRYPT'99).
//!
//! Used for the paper's Type-1 computations: nodes encrypt their local
//! summaries (gradients, Gram matrices, log-likelihoods) and the Center
//! aggregates them under encryption (`⊕`, `⊖`, scalar `⊗`).
//!
//! Standard construction with `g = n + 1`, which makes encryption
//! `c = (1 + m·n) · rⁿ mod n²` (one modpow instead of two) and decryption
//! `m = L(c^λ mod n²) · μ mod n` with `L(u) = (u − 1)/n`.
//! Decryption uses the CRT split over `p²`/`q²` (≈4× speedup).

use std::sync::Arc;

use crate::bigint::{gen_prime, BigUint, Montgomery, RandomSource};

/// Paillier public key (modulus `n`, implicit generator `g = n+1`).
#[derive(Clone)]
pub struct PublicKey {
    /// Modulus `n = p·q`.
    pub n: BigUint,
    /// `n²`, the ciphertext modulus.
    pub n2: BigUint,
    /// Montgomery context for `n²` (shared; ciphertext ops are the hot path).
    mont_n2: Arc<Montgomery>,
    /// `h = h₀ⁿ mod n²` for short-exponent (Damgård–Jurik–Nielsen-style)
    /// encryption: `c = (1+mn)·h^s` with a short random `s`. `h₀` is a
    /// nothing-up-my-sleeve value derived by hashing `n`, so the key
    /// reconstructs identically on every party.
    h_n: Arc<BigUint>,
}

/// Short-exponent bits for DJN-style encryption (≥2× statistical security
/// of 112-bit; the paper's semi-honest model).
const SHORT_EXP_BITS: usize = 256;

/// Derive the nothing-up-my-sleeve base `h₀` from `n` via SHA-256 stream.
fn derive_h0(n: &BigUint) -> BigUint {
    use sha2::{Digest, Sha256};
    let mut out = Vec::new();
    let nb = n.to_bytes_le();
    let mut ctr = 0u32;
    while out.len() * 8 < n.bit_len() + 64 {
        let mut hasher = Sha256::new();
        hasher.update(b"privlogit-paillier-h0");
        hasher.update(&nb);
        hasher.update(ctr.to_le_bytes());
        out.extend_from_slice(&hasher.finalize());
        ctr += 1;
    }
    BigUint::from_bytes_le(&out).rem(n)
}

/// Paillier private key.
#[derive(Clone)]
pub struct PrivateKey {
    /// Carmichael `λ = lcm(p−1, q−1)`.
    pub lambda: BigUint,
    /// `μ = L(g^λ mod n²)^-1 mod n`.
    pub mu: BigUint,
    /// Public part (decryption needs `n`, `n²`).
    pub pk: PublicKey,
    // CRT acceleration.
    p2: BigUint,
    q2: BigUint,
    /// `λ mod (p−1)·p` exponent pieces and per-prime μ values.
    hp: BigUint,
    hq: BigUint,
    p: BigUint,
    q: BigUint,
    /// `q^-1 mod p` for CRT recombination.
    qinv_p: BigUint,
}

/// Key pair.
pub struct Keypair {
    pub pk: PublicKey,
    pub sk: PrivateKey,
}

/// A Paillier ciphertext (an element of `Z*_{n²}`).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Ciphertext(pub BigUint);

impl Ciphertext {
    /// Serialized size in bytes (for communication accounting).
    pub fn byte_len(&self) -> usize {
        self.0.to_bytes_le().len()
    }
}

impl Keypair {
    /// Generate a fresh keypair with an `n` of `modulus_bits` bits.
    ///
    /// `modulus_bits` = 2048 matches the paper's security parameter;
    /// tests and fast experiments use smaller keys (the protocols scale
    /// every method identically in the key size, so *relative* results
    /// are preserved — see DESIGN.md §7).
    pub fn generate(modulus_bits: usize, rng: &mut dyn RandomSource) -> Keypair {
        assert!(modulus_bits >= 64, "modulus too small");
        let half = modulus_bits / 2;
        let (p, q) = loop {
            let p = gen_prime(half, rng);
            let q = gen_prime(half, rng);
            if p != q {
                break (p, q);
            }
        };
        let n = p.mul(&q);
        let n2 = n.mul(&n);
        let pk = PublicKey::from_modulus(n.clone(), n2.clone());
        let p1 = p.sub_u64(1);
        let q1 = q.sub_u64(1);
        let lambda = p1.lcm(&q1);
        // g = n+1 ⇒ g^λ mod n² = 1 + λ·n mod n² ⇒ L(g^λ) = λ mod n.
        let mu = lambda
            .rem(&n)
            .modinv(&n)
            .expect("λ invertible mod n for distinct primes");
        let p2 = p.mul(&p);
        let q2 = q.mul(&q);
        // h_p = L_p(g^{p-1} mod p²)^-1 mod p, with L_p(u) = (u-1)/p.
        let hp = Self::h_exp(&n, &p, &p2, &p1);
        let hq = Self::h_exp(&n, &q, &q2, &q1);
        let qinv_p = q.modinv(&p).expect("p, q coprime");
        let sk = PrivateKey {
            lambda,
            mu,
            pk: pk.clone(),
            p2,
            q2,
            hp,
            hq,
            p,
            q,
            qinv_p,
        };
        Keypair { pk, sk }
    }

    /// `h = L_s(g^{s-1} mod s²)^{-1} mod s` for prime `s` (g = n+1).
    fn h_exp(n: &BigUint, s: &BigUint, s2: &BigUint, s1: &BigUint) -> BigUint {
        let g = n.add_u64(1).rem(s2);
        let gs = g.modpow(s1, s2);
        let l = gs.sub_u64(1).divrem(s).0;
        l.rem(s).modinv(s).expect("L(g^{s-1}) invertible mod s")
    }
}

impl PublicKey {
    /// Rebuild a public key from its modulus (e.g. received over a
    /// channel; `n²` passed in to avoid recomputing when already known).
    pub fn from_modulus(n: BigUint, n2: BigUint) -> Self {
        debug_assert_eq!(n.mul(&n), n2);
        let mont = Montgomery::new(&n2);
        let h0 = derive_h0(&n);
        let h_n = mont.pow(&h0, &n);
        PublicKey { mont_n2: Arc::new(mont), n, n2, h_n: Arc::new(h_n) }
    }

    /// Encrypt plaintext `m ∈ Z_n`: `c = (1 + m·n) · h^s mod n²` with a
    /// short random exponent `s` (DJN-style; §Perf — one 256-bit modpow
    /// instead of a full |n|-bit one).
    pub fn encrypt(&self, m: &BigUint, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let m = m.rem(&self.n);
        let mut sbytes = [0u8; SHORT_EXP_BITS / 8];
        rng.0.fill_bytes(&mut sbytes);
        let s = BigUint::from_bytes_le(&sbytes);
        let gm = BigUint::one().add(&m.mul(&self.n)).rem(&self.n2);
        let hs = self.mont_n2.pow(&self.h_n, &s);
        Ciphertext(self.mont_n2.mul(&gm, &hs))
    }

    /// Full-range-randomness encryption `c = (1 + m·n) · rⁿ mod n²`
    /// (classical Paillier; kept for protocols that must pick `r`).
    pub fn encrypt_full(&self, m: &BigUint, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let m = m.rem(&self.n);
        let r = rng.unit(&self.n);
        self.encrypt_with_r(&m, &r)
    }

    /// Deterministic encryption with caller-chosen randomness (tests,
    /// blinding protocols that must reuse `r`).
    pub fn encrypt_with_r(&self, m: &BigUint, r: &BigUint) -> Ciphertext {
        let gm = BigUint::one().add(&m.mul(&self.n)).rem(&self.n2);
        let rn = self.mont_n2.pow(r, &self.n);
        Ciphertext(self.mont_n2.mul(&gm, &rn))
    }

    /// "Trivial" encryption with fixed randomness r=1 (no semantic
    /// security; used for public constants inside protocols).
    pub fn encrypt_trivial(&self, m: &BigUint) -> Ciphertext {
        Ciphertext(BigUint::one().add(&m.rem(&self.n).mul(&self.n)).rem(&self.n2))
    }

    /// Homomorphic addition `Enc(a) ⊕ Enc(b) = Enc(a + b)`.
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        Ciphertext(self.mont_n2.mul(&a.0, &b.0))
    }

    /// Homomorphic subtraction `Enc(a) ⊖ Enc(b) = Enc(a − b)`.
    pub fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        // Enc(-b) = Enc(b)^(n-1) — i.e. scalar multiply by n−1 ≡ −1 (mod n).
        let neg_b = self.scalar_mul(b, &self.n.sub_u64(1));
        self.add(a, &neg_b)
    }

    /// Homomorphic scalar multiplication `Enc(a) ⊗ k = Enc(a·k)`.
    pub fn scalar_mul(&self, a: &Ciphertext, k: &BigUint) -> Ciphertext {
        Ciphertext(self.mont_n2.pow(&a.0, &k.rem(&self.n)))
    }

    /// Re-randomize: multiply by a fresh encryption of zero (short
    /// exponent, like [`PublicKey::encrypt`]).
    pub fn rerandomize(&self, a: &Ciphertext, rng: &mut ChaChaSource<'_>) -> Ciphertext {
        let mut sbytes = [0u8; SHORT_EXP_BITS / 8];
        rng.0.fill_bytes(&mut sbytes);
        let s = BigUint::from_bytes_le(&sbytes);
        let hs = self.mont_n2.pow(&self.h_n, &s);
        Ciphertext(self.mont_n2.mul(&a.0, &hs))
    }

    /// Serialized public-key bytes (communication accounting).
    pub fn byte_len(&self) -> usize {
        self.n.to_bytes_le().len()
    }
}

impl PrivateKey {
    /// Decrypt via CRT: `m_p = L_p(c^{p−1} mod p²)·h_p mod p` (same for q),
    /// recombined with Garner's formula.
    pub fn decrypt(&self, c: &Ciphertext) -> BigUint {
        let p1 = self.p.sub_u64(1);
        let q1 = self.q.sub_u64(1);
        let cp = c.0.rem(&self.p2).modpow(&p1, &self.p2);
        let cq = c.0.rem(&self.q2).modpow(&q1, &self.q2);
        let mp = cp.sub_u64(1).divrem(&self.p).0.mul_mod(&self.hp, &self.p);
        let mq = cq.sub_u64(1).divrem(&self.q).0.mul_mod(&self.hq, &self.q);
        // Garner: m = mq + q * ((mp - mq) * qinv mod p)
        let diff = mp.sub_mod(&mq.rem(&self.p), &self.p);
        let t = diff.mul_mod(&self.qinv_p, &self.p);
        mq.add(&self.q.mul(&t))
    }

    /// Reference (non-CRT) decryption `L(c^λ mod n²)·μ mod n` — kept for
    /// cross-checking the CRT path in tests.
    pub fn decrypt_plain(&self, c: &Ciphertext) -> BigUint {
        let u = c.0.modpow(&self.lambda, &self.pk.n2);
        let l = u.sub_u64(1).divrem(&self.pk.n).0;
        l.mul_mod(&self.mu, &self.pk.n)
    }
}

/// A thin adapter so `PublicKey` methods can take any [`RandomSource`]
/// without generic churn at every call site.
pub struct ChaChaSource<'a>(pub &'a mut dyn RandomSource);

impl ChaChaSource<'_> {
    fn unit(&mut self, n: &BigUint) -> BigUint {
        loop {
            let r = self.0.below(n);
            if !r.is_zero() && r.gcd(n).is_one() {
                return r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::ChaChaRng;

    fn setup() -> (Keypair, ChaChaRng) {
        let mut rng = ChaChaRng::from_u64_seed(1234);
        let kp = Keypair::generate(256, &mut rng);
        (kp, rng)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (kp, mut rng) = setup();
        for v in [0u64, 1, 42, 1 << 40, u64::MAX] {
            let m = BigUint::from_u64(v);
            let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
            assert_eq!(kp.sk.decrypt(&c), m, "roundtrip {v}");
            assert_eq!(kp.sk.decrypt_plain(&c), m, "plain decrypt {v}");
        }
    }

    #[test]
    fn crt_matches_plain_decrypt() {
        let (kp, mut rng) = setup();
        for _ in 0..10 {
            let m = rng.below(&kp.pk.n);
            let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
            assert_eq!(kp.sk.decrypt(&c), kp.sk.decrypt_plain(&c));
        }
    }

    #[test]
    fn homomorphic_add_sub() {
        let (kp, mut rng) = setup();
        let a = BigUint::from_u64(1_000_000);
        let b = BigUint::from_u64(2_345_678);
        let ca = kp.pk.encrypt(&a, &mut ChaChaSource(&mut rng));
        let cb = kp.pk.encrypt(&b, &mut ChaChaSource(&mut rng));
        assert_eq!(kp.sk.decrypt(&kp.pk.add(&ca, &cb)), a.add(&b));
        assert_eq!(kp.sk.decrypt(&kp.pk.sub(&cb, &ca)), b.sub(&a));
        // subtraction that wraps (negative result ≡ n - diff)
        let wrapped = kp.sk.decrypt(&kp.pk.sub(&ca, &cb));
        assert_eq!(wrapped, kp.pk.n.sub(&b.sub(&a)));
    }

    #[test]
    fn homomorphic_scalar_mul() {
        let (kp, mut rng) = setup();
        let a = BigUint::from_u64(98765);
        let k = BigUint::from_u64(4321);
        let ca = kp.pk.encrypt(&a, &mut ChaChaSource(&mut rng));
        let ck = kp.pk.scalar_mul(&ca, &k);
        assert_eq!(kp.sk.decrypt(&ck), a.mul(&k));
    }

    #[test]
    fn rerandomize_changes_ciphertext_not_plaintext() {
        let (kp, mut rng) = setup();
        let m = BigUint::from_u64(7);
        let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        let c2 = kp.pk.rerandomize(&c, &mut ChaChaSource(&mut rng));
        assert_ne!(c, c2);
        assert_eq!(kp.sk.decrypt(&c2), m);
    }

    #[test]
    fn ciphertexts_probabilistic() {
        let (kp, mut rng) = setup();
        let m = BigUint::from_u64(5);
        let c1 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        let c2 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
        assert_ne!(c1, c2, "semantic security: same plaintext, different ct");
    }

    #[test]
    fn trivial_encryption_decrypts() {
        let (kp, _) = setup();
        let m = BigUint::from_u64(314159);
        assert_eq!(kp.sk.decrypt(&kp.pk.encrypt_trivial(&m)), m);
    }

    /// Property: sum of many encryptions decrypts to sum of plaintexts —
    /// exactly the Center's aggregation pattern (Alg. 1 step 8).
    #[test]
    fn aggregation_property() {
        let (kp, mut rng) = setup();
        let mut acc = kp.pk.encrypt_trivial(&BigUint::zero());
        let mut expect = BigUint::zero();
        for i in 1..=20u64 {
            let m = BigUint::from_u64(i * i * 31);
            let c = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
            acc = kp.pk.add(&acc, &c);
            expect = expect.add(&m);
        }
        assert_eq!(kp.sk.decrypt(&acc), expect);
    }
}
