//! ChaCha20-based cryptographically secure PRNG.
//!
//! The `rand`/`rand_chacha` crates are unavailable in the build image, so
//! the ChaCha20 block function (RFC 8439) is implemented here. Seeding
//! comes from the OS entropy pool (`/dev/urandom`) or an explicit
//! 32-byte seed for reproducible protocol runs.

use crate::bigint::{BigUint, RandomSource};

/// ChaCha20 stream generator usable as a [`RandomSource`].
pub struct ChaChaRng {
    key: [u32; 8],
    counter: u64,
    nonce: [u32; 2],
    buf: [u8; 64],
    pos: usize,
}

impl ChaChaRng {
    /// Seed from the operating system entropy pool (`/dev/urandom`).
    /// Panics if the pool is unreadable — this RNG seeds Paillier key
    /// generation, so a silent low-entropy fallback would be a key
    /// compromise, not a convenience.
    pub fn from_os() -> Self {
        use std::io::Read as _;
        let mut seed = [0u8; 32];
        std::fs::File::open("/dev/urandom")
            .and_then(|mut f| f.read_exact(&mut seed))
            .expect("OS entropy unavailable (/dev/urandom)");
        Self::from_seed(seed)
    }

    /// Deterministic construction from a 32-byte seed.
    pub fn from_seed(seed: [u8; 32]) -> Self {
        let mut key = [0u32; 8];
        for (i, chunk) in seed.chunks(4).enumerate() {
            key[i] = u32::from_le_bytes(chunk.try_into().unwrap());
        }
        ChaChaRng { key, counter: 0, nonce: [0, 0], buf: [0; 64], pos: 64 }
    }

    /// Deterministic construction from a u64 seed (test / experiment use).
    pub fn from_u64_seed(seed: u64) -> Self {
        let mut bytes = [0u8; 32];
        bytes[..8].copy_from_slice(&seed.to_le_bytes());
        bytes[8..16].copy_from_slice(&seed.wrapping_mul(0x9E3779B97F4A7C15).to_le_bytes());
        Self::from_seed(bytes)
    }

    fn refill(&mut self) {
        let block = chacha20_block(&self.key, self.counter, &self.nonce);
        self.buf = block;
        self.counter = self.counter.wrapping_add(1);
        self.pos = 0;
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        self.fill_bytes(&mut b);
        u64::from_le_bytes(b)
    }

    /// Uniform random element of `[1, n)` coprime to `n` (Paillier `r`).
    pub fn unit_mod(&mut self, n: &BigUint) -> BigUint {
        loop {
            let r = self.below(n);
            if !r.is_zero() && r.gcd(n).is_one() {
                return r;
            }
        }
    }
}

impl RandomSource for ChaChaRng {
    fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut written = 0;
        while written < buf.len() {
            if self.pos == 64 {
                self.refill();
            }
            let take = (64 - self.pos).min(buf.len() - written);
            buf[written..written + take].copy_from_slice(&self.buf[self.pos..self.pos + take]);
            self.pos += take;
            written += take;
        }
    }
}

/// The ChaCha20 block function (RFC 8439 §2.3) with a 64-bit counter.
fn chacha20_block(key: &[u32; 8], counter: u64, nonce: &[u32; 2]) -> [u8; 64] {
    const SIGMA: [u32; 4] = [0x61707865, 0x3320646e, 0x79622d32, 0x6b206574];
    let mut state = [0u32; 16];
    state[..4].copy_from_slice(&SIGMA);
    state[4..12].copy_from_slice(key);
    state[12] = counter as u32;
    state[13] = (counter >> 32) as u32;
    state[14] = nonce[0];
    state[15] = nonce[1];
    let mut w = state;

    #[inline(always)]
    fn quarter(w: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
        w[a] = w[a].wrapping_add(w[b]);
        w[d] = (w[d] ^ w[a]).rotate_left(16);
        w[c] = w[c].wrapping_add(w[d]);
        w[b] = (w[b] ^ w[c]).rotate_left(12);
        w[a] = w[a].wrapping_add(w[b]);
        w[d] = (w[d] ^ w[a]).rotate_left(8);
        w[c] = w[c].wrapping_add(w[d]);
        w[b] = (w[b] ^ w[c]).rotate_left(7);
    }

    for _ in 0..10 {
        quarter(&mut w, 0, 4, 8, 12);
        quarter(&mut w, 1, 5, 9, 13);
        quarter(&mut w, 2, 6, 10, 14);
        quarter(&mut w, 3, 7, 11, 15);
        quarter(&mut w, 0, 5, 10, 15);
        quarter(&mut w, 1, 6, 11, 12);
        quarter(&mut w, 2, 7, 8, 13);
        quarter(&mut w, 3, 4, 9, 14);
    }
    let mut out = [0u8; 64];
    for i in 0..16 {
        let v = w[i].wrapping_add(state[i]);
        out[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 8439 §2.3.2 test vector (adapted: the RFC uses a 32-bit counter
    /// and 96-bit nonce; with nonce words (0x09000000, 0x4a000000) our
    /// layout reproduces the RFC state when counter = 1 | (0x00000000<<32)
    /// ... we instead pin the all-zero-key block-0 keystream, a widely
    /// published vector for the 64-bit-counter ChaCha20 variant).
    #[test]
    fn chacha_zero_key_vector() {
        let key = [0u32; 8];
        let block = chacha20_block(&key, 0, &[0, 0]);
        let expect: [u8; 16] = [
            0x76, 0xb8, 0xe0, 0xad, 0xa0, 0xf1, 0x3d, 0x90, 0x40, 0x5d, 0x6a, 0xe5, 0x53, 0x86,
            0xbd, 0x28,
        ];
        assert_eq!(&block[..16], &expect);
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = ChaChaRng::from_u64_seed(7);
        let mut b = ChaChaRng::from_u64_seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = ChaChaRng::from_u64_seed(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fill_bytes_unaligned() {
        let mut rng = ChaChaRng::from_u64_seed(1);
        let mut a = vec![0u8; 131];
        rng.fill_bytes(&mut a);
        // Same stream read in different chunk sizes must agree.
        let mut rng2 = ChaChaRng::from_u64_seed(1);
        let mut b = vec![0u8; 131];
        for chunk in b.chunks_mut(13) {
            rng2.fill_bytes(chunk);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn below_is_in_range_and_varies() {
        let mut rng = ChaChaRng::from_u64_seed(2);
        let bound = BigUint::from_dec_str("1000000000000000000000000").unwrap();
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..50 {
            let x = rng.below(&bound);
            assert!(x < bound);
            distinct.insert(x.to_dec_string());
        }
        assert!(distinct.len() > 40, "draws should be distinct");
    }

    #[test]
    fn unit_mod_coprime() {
        let mut rng = ChaChaRng::from_u64_seed(3);
        let n = BigUint::from_u64(35); // 5*7 — several non-units
        for _ in 0..20 {
            let r = rng.unit_mod(&n);
            assert!(r.gcd(&n).is_one());
            assert!(!r.is_zero() && r < n);
        }
    }
}
