//! Report formatting: turn [`RunReport`]s and cost ledgers into the
//! tables the CLI, examples and benches print.

use crate::protocols::RunReport;

/// Render a detailed single-run report.
pub fn render_report(r: &RunReport) -> String {
    let l = &r.ledger;
    let mut s = String::new();
    s.push_str(&format!("── {} on {} ──\n", r.protocol, r.dataset));
    s.push_str(&format!(
        "  n={} p={} orgs={}  backend: {}  nodes: {}\n",
        r.n, r.p, r.orgs, r.backend, r.engine
    ));
    s.push_str(&format!(
        "  iterations: {} (converged: {})\n",
        r.iterations, r.converged
    ));
    s.push_str(&format!(
        "  time: total {:.2}s  setup {:.2}s  iter-phase {:.2}s\n",
        r.total_secs,
        r.setup_secs,
        r.total_secs - r.setup_secs
    ));
    s.push_str(&format!(
        "  breakdown: center {:.2}s  nodes(max/round) {:.2}s\n",
        l.center_secs, l.node_secs
    ));
    s.push_str(&format!(
        "  crypto: {} encs, {} adds, {} scalar-muls, {} decrypts, {} GC ANDs, {} OT bits\n",
        l.paillier_encs, l.paillier_adds, l.paillier_scalar, l.paillier_decrypts, l.gc_ands,
        l.ot_bits
    ));
    s.push_str(&format!(
        "  network: {:.2} MiB sent / {:.2} MiB recv in {} rounds\n",
        l.bytes as f64 / (1024.0 * 1024.0),
        l.bytes_recv as f64 / (1024.0 * 1024.0),
        l.rounds
    ));
    if l.fleet_bytes_sent > 0 || l.fleet_bytes_recv > 0 {
        s.push_str(&format!(
            "  fleet wire (measured): {:.2} MiB sent / {:.2} MiB recv\n",
            l.fleet_bytes_sent as f64 / (1024.0 * 1024.0),
            l.fleet_bytes_recv as f64 / (1024.0 * 1024.0),
        ));
    }
    s
}

/// Render a Table-2-style comparison row.
pub fn table2_row(dataset: &str, iters: (usize, usize), secs: (f64, f64, f64)) -> String {
    format!(
        "| {:<10} | {:>6} | {:>9} | {:>10.1} | {:>17.1} | {:>15.1} |",
        dataset, iters.0, iters.1, secs.0, secs.1, secs.2
    )
}

/// Table 2 header (matches the paper's columns).
pub fn table2_header() -> String {
    format!(
        "| {:<10} | {:>6} | {:>9} | {:>10} | {:>17} | {:>15} |\n|{}|",
        "Dataset",
        "Newton",
        "PrivLogit",
        "Newton (s)",
        "PL-Hessian (s)",
        "PL-Local (s)",
        "-".repeat(86)
    )
}

/// First coefficients preview for logs.
pub fn beta_preview(beta: &[f64]) -> String {
    let head: Vec<String> = beta.iter().take(5).map(|b| format!("{b:+.4}")).collect();
    format!("[{}{}]", head.join(", "), if beta.len() > 5 { ", …" } else { "" })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::CostLedger;

    fn dummy_report() -> RunReport {
        RunReport {
            protocol: "privlogit-local",
            backend: "real".into(),
            engine: "cpu".into(),
            dataset: "Wine".into(),
            p: 12,
            n: 6497,
            orgs: 4,
            iterations: 13,
            converged: true,
            beta: vec![0.1, -0.2, 0.3],
            setup_secs: 1.5,
            total_secs: 4.0,
            ledger: CostLedger::default(),
        }
    }

    #[test]
    fn report_contains_key_fields() {
        let s = render_report(&dummy_report());
        assert!(s.contains("privlogit-local"));
        assert!(s.contains("iterations: 13"));
        assert!(s.contains("setup 1.50s"));
        assert!(s.contains("sent"), "network line reports both directions");
        assert!(s.contains("recv"), "network line reports both directions");
    }

    #[test]
    fn table_rows_align() {
        let h = table2_header();
        let r = table2_row("Wine", (5, 13), (32.0, 24.0, 17.0));
        let width = h.lines().next().unwrap().len();
        assert_eq!(r.len(), width, "row/header width");
    }

    #[test]
    fn beta_preview_truncates() {
        let s = beta_preview(&[1.0; 10]);
        assert!(s.contains('…'));
        let s2 = beta_preview(&[1.0, 2.0]);
        assert!(!s2.contains('…'));
    }
}
