//! Report formatting: turn [`RunReport`]s and cost ledgers into the
//! tables the CLI, examples and benches print, plus the machine-readable
//! `--json` rendering (schema `privlogit-report/v1`).

use std::collections::BTreeMap;

use crate::net::wire::tag_name;
use crate::obs::json::{JsonObj, JsonValue};
use crate::obs::TagFlow;
use crate::protocols::RunReport;

/// Schema identifier of the `--json` report document.
pub const REPORT_SCHEMA: &str = "privlogit-report/v1";

/// Iteration-phase seconds: `total - setup`, clamped at zero. The two
/// numbers come from different clocks (the ledger's virtual total vs.
/// wall-measured setup), so tiny runs can put setup a hair above total —
/// a negative phase time is a rendering bug, not information. Returns
/// the clamped value and whether clamping fired.
fn iter_phase_secs(r: &RunReport) -> (f64, bool) {
    let raw = r.total_secs - r.setup_secs;
    if raw < 0.0 {
        (0.0, true)
    } else {
        (raw, false)
    }
}

fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

/// One per-tag breakdown table (skipped entirely for an empty map).
fn tag_table(s: &mut String, title: &str, flows: &BTreeMap<u8, TagFlow>) {
    if flows.is_empty() {
        return;
    }
    s.push_str(&format!("  {title} by tag:\n"));
    s.push_str(&format!(
        "    {:<6}{:<12}{:>10}{:>12}{:>10}{:>12}\n",
        "tag", "name", "sent fr", "sent MiB", "recv fr", "recv MiB"
    ));
    for (tag, f) in flows {
        s.push_str(&format!(
            "    {:#04x}  {:<12}{:>10}{:>12.3}{:>10}{:>12.3}\n",
            tag,
            tag_name(*tag),
            f.sent_frames,
            mib(f.sent_bytes),
            f.recv_frames,
            mib(f.recv_bytes)
        ));
    }
}

/// Render a detailed single-run report.
pub fn render_report(r: &RunReport) -> String {
    let l = &r.ledger;
    let mut s = String::new();
    s.push_str(&format!("── {} on {} ──\n", r.protocol, r.dataset));
    s.push_str(&format!(
        "  n={} p={} orgs={}  backend: {}  nodes: {}\n",
        r.n, r.p, r.orgs, r.backend, r.engine
    ));
    s.push_str(&format!(
        "  iterations: {} (converged: {})\n",
        r.iterations, r.converged
    ));
    let (iter_secs, clamped) = iter_phase_secs(r);
    s.push_str(&format!(
        "  time: total {:.2}s  setup {:.2}s  iter-phase {:.2}s{}\n",
        r.total_secs,
        r.setup_secs,
        iter_secs,
        if clamped { " (clamped)" } else { "" }
    ));
    s.push_str(&format!(
        "  breakdown: center {:.2}s  nodes(max/round) {:.2}s\n",
        l.center_secs, l.node_secs
    ));
    s.push_str(&format!(
        "  crypto: {} encs, {} adds, {} scalar-muls, {} decrypts, {} GC ANDs, {} OT bits\n",
        l.paillier_encs, l.paillier_adds, l.paillier_scalar, l.paillier_decrypts, l.gc_ands,
        l.ot_bits
    ));
    s.push_str(&format!(
        "  network: {:.2} MiB sent / {:.2} MiB recv in {} rounds\n",
        mib(l.bytes),
        mib(l.bytes_recv),
        l.rounds
    ));
    if l.fleet_bytes_sent > 0 || l.fleet_bytes_recv > 0 {
        s.push_str(&format!(
            "  fleet wire (measured): {:.2} MiB sent / {:.2} MiB recv\n",
            mib(l.fleet_bytes_sent),
            mib(l.fleet_bytes_recv),
        ));
    }
    if l.excluded_nodes > 0 {
        s.push_str(&format!(
            "  exclusions: {} node(s) dropped after missed rounds (quorum mode)\n",
            l.excluded_nodes
        ));
    }
    if l.readmitted_nodes > 0 {
        s.push_str(&format!(
            "  readmissions: {} node(s) restored after answering a round-boundary probe\n",
            l.readmitted_nodes
        ));
    }
    tag_table(&mut s, "fleet wire", &l.fleet_tag_flows);
    tag_table(&mut s, "center peer control frames", &l.peer_tag_flows);
    s
}

fn flows_json(flows: &BTreeMap<u8, TagFlow>) -> JsonValue {
    JsonValue::Arr(
        flows
            .iter()
            .map(|(tag, f)| {
                JsonObj::new()
                    .u64("tag", *tag as u64)
                    .str("tag_name", tag_name(*tag))
                    .u64("sent_frames", f.sent_frames)
                    .u64("sent_bytes", f.sent_bytes)
                    .u64("recv_frames", f.recv_frames)
                    .u64("recv_bytes", f.recv_bytes)
                    .build()
            })
            .collect(),
    )
}

/// Render the machine-readable report (schema [`REPORT_SCHEMA`]): the
/// full [`RunReport`] plus the ledger, one compact JSON document. The
/// human table ([`render_report`]) is unchanged by `--json`-capable
/// callers — they pick one or the other.
pub fn render_report_json(r: &RunReport) -> String {
    let l = &r.ledger;
    let (iter_secs, clamped) = iter_phase_secs(r);
    let ledger = JsonObj::new()
        .f64("center_secs", l.center_secs)
        .f64("node_secs", l.node_secs)
        .f64("setup_secs", l.setup_secs)
        .u64("bytes", l.bytes)
        .u64("bytes_recv", l.bytes_recv)
        .u64("fleet_bytes_sent", l.fleet_bytes_sent)
        .u64("fleet_bytes_recv", l.fleet_bytes_recv)
        .push("fleet_tag_flows", flows_json(&l.fleet_tag_flows))
        .push("peer_tag_flows", flows_json(&l.peer_tag_flows))
        .u64("excluded_nodes", l.excluded_nodes)
        .u64("readmitted_nodes", l.readmitted_nodes)
        .u64("rounds", l.rounds)
        .u64("paillier_encs", l.paillier_encs)
        .u64("paillier_adds", l.paillier_adds)
        .u64("paillier_scalar", l.paillier_scalar)
        .u64("paillier_decrypts", l.paillier_decrypts)
        .u64("gc_ands", l.gc_ands)
        .u64("ot_bits", l.ot_bits)
        .build();
    JsonObj::new()
        .str("schema", REPORT_SCHEMA)
        .str("protocol", r.protocol)
        .str("backend", &r.backend)
        .str("engine", &r.engine)
        .str("dataset", &r.dataset)
        .u64("p", r.p as u64)
        .u64("n", r.n as u64)
        .u64("orgs", r.orgs as u64)
        .u64("iterations", r.iterations as u64)
        .bool("converged", r.converged)
        .push("beta", JsonValue::Arr(r.beta.iter().map(|&b| JsonValue::Num(b)).collect()))
        .f64("setup_secs", r.setup_secs)
        .f64("total_secs", r.total_secs)
        .f64("iter_phase_secs", iter_secs)
        .bool("iter_phase_clamped", clamped)
        .push("ledger", ledger)
        .build()
        .render()
}

/// Render a Table-2-style comparison row.
pub fn table2_row(dataset: &str, iters: (usize, usize), secs: (f64, f64, f64)) -> String {
    format!(
        "| {:<10} | {:>6} | {:>9} | {:>10.1} | {:>17.1} | {:>15.1} |",
        dataset, iters.0, iters.1, secs.0, secs.1, secs.2
    )
}

/// Table 2 header (matches the paper's columns).
pub fn table2_header() -> String {
    format!(
        "| {:<10} | {:>6} | {:>9} | {:>10} | {:>17} | {:>15} |\n|{}|",
        "Dataset",
        "Newton",
        "PrivLogit",
        "Newton (s)",
        "PL-Hessian (s)",
        "PL-Local (s)",
        "-".repeat(86)
    )
}

/// First coefficients preview for logs.
pub fn beta_preview(beta: &[f64]) -> String {
    let head: Vec<String> = beta.iter().take(5).map(|b| format!("{b:+.4}")).collect();
    format!("[{}{}]", head.join(", "), if beta.len() > 5 { ", …" } else { "" })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpc::CostLedger;

    fn dummy_report() -> RunReport {
        RunReport {
            protocol: "privlogit-local",
            backend: "real".into(),
            engine: "cpu".into(),
            dataset: "Wine".into(),
            p: 12,
            n: 6497,
            orgs: 4,
            iterations: 13,
            converged: true,
            beta: vec![0.1, -0.2, 0.3],
            setup_secs: 1.5,
            total_secs: 4.0,
            ledger: CostLedger::default(),
        }
    }

    #[test]
    fn report_contains_key_fields() {
        let s = render_report(&dummy_report());
        assert!(s.contains("privlogit-local"));
        assert!(s.contains("iterations: 13"));
        assert!(s.contains("setup 1.50s"));
        assert!(s.contains("sent"), "network line reports both directions");
        assert!(s.contains("recv"), "network line reports both directions");
    }

    /// Satellite (c): setup clocked above total must never print a
    /// negative iteration-phase time — clamp to zero and say so.
    #[test]
    fn iter_phase_clamps_when_setup_exceeds_total() {
        let mut r = dummy_report();
        r.setup_secs = 5.0; // > total_secs = 4.0
        let s = render_report(&r);
        assert!(s.contains("iter-phase 0.00s (clamped)"), "{s}");
        assert!(!s.contains("-1.00"), "{s}");
        // The healthy path stays unflagged.
        let s = render_report(&dummy_report());
        assert!(s.contains("iter-phase 2.50s\n"), "{s}");
        assert!(!s.contains("clamped"), "{s}");
    }

    #[test]
    fn tag_tables_render_when_flows_present() {
        let mut r = dummy_report();
        // Empty maps: no tables at all.
        let s = render_report(&r);
        assert!(!s.contains("by tag"), "{s}");
        let flow = TagFlow {
            sent_frames: 3,
            sent_bytes: 2 * 1024 * 1024,
            recv_frames: 3,
            recv_bytes: 1024,
        };
        r.ledger.fleet_tag_flows.insert(crate::net::wire::TAG_STEP_REQ, flow);
        r.ledger.peer_tag_flows.insert(crate::net::wire::TAG_GC_EXEC, flow);
        let s = render_report(&r);
        assert!(s.contains("fleet wire by tag"), "{s}");
        assert!(s.contains("center peer control frames by tag"), "{s}");
        assert!(s.contains("StepReq"), "{s}");
        assert!(s.contains("GcExec"), "{s}");
        assert!(s.contains("2.000"), "sent MiB column: {s}");
    }

    /// The `--json` document must parse back with our own parser and
    /// carry the ledger and per-tag flows faithfully.
    #[test]
    fn report_json_round_trips() {
        let mut r = dummy_report();
        r.ledger.paillier_encs = 42;
        r.ledger.fleet_bytes_sent = 1000;
        let flow = TagFlow { sent_frames: 7, sent_bytes: 700, ..TagFlow::default() };
        r.ledger.fleet_tag_flows.insert(crate::net::wire::TAG_STATS_REQ, flow);
        let doc = crate::obs::json::parse(&render_report_json(&r)).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(REPORT_SCHEMA));
        assert_eq!(doc.get("protocol").unwrap().as_str(), Some("privlogit-local"));
        assert_eq!(doc.get("iterations").unwrap().as_u64(), Some(13));
        assert_eq!(doc.get("beta").unwrap().as_arr().unwrap().len(), 3);
        let ledger = doc.get("ledger").unwrap();
        assert_eq!(ledger.get("paillier_encs").unwrap().as_u64(), Some(42));
        assert_eq!(ledger.get("fleet_bytes_sent").unwrap().as_u64(), Some(1000));
        let flows = ledger.get("fleet_tag_flows").unwrap().as_arr().unwrap();
        assert_eq!(flows.len(), 1);
        assert_eq!(flows[0].get("tag").unwrap().as_u64(), Some(0x01));
        assert_eq!(flows[0].get("tag_name").unwrap().as_str(), Some("StatsReq"));
        assert_eq!(flows[0].get("sent_frames").unwrap().as_u64(), Some(7));
        assert_eq!(flows[0].get("sent_bytes").unwrap().as_u64(), Some(700));
    }

    #[test]
    fn table_rows_align() {
        let h = table2_header();
        let r = table2_row("Wine", (5, 13), (32.0, 24.0, 17.0));
        let width = h.lines().next().unwrap().len();
        assert_eq!(r.len(), width, "row/header width");
    }

    #[test]
    fn beta_preview_truncates() {
        let s = beta_preview(&[1.0; 10]);
        assert!(s.contains('…'));
        let s2 = beta_preview(&[1.0, 2.0]);
        assert!(!s2.contains('…'));
    }
}
