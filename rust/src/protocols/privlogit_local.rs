//! PrivLogit-Local (paper Algorithm 3): decentralizing the Newton step.
//!
//! Setup materializes `Enc(H̃⁻¹)` once (garbled Cholesky + triangular
//! inversion + masked re-encryption) and disseminates it to nodes. Each
//! iteration, node `j` computes `Enc(H̃⁻¹)⊗g_j` *locally* using only
//! cheap Paillier multiply-by-constant ops (its own gradient is
//! privacy-free to itself, paper §4.2); the Center merely ⊕-aggregates
//! `p` ciphertexts, adds the regularization term `Enc(λH̃⁻¹β)` and
//! reveals the (by-design public) update step. No garbled circuits run
//! in the iteration loop except the single-bit convergence check.

use super::common::*;
use crate::coordinator::checkpoint::{self, SessionCheckpoint};
use crate::coordinator::fleet::{Fleet, NodePayload};
use crate::mpc::{EncMat, EncVec, SecureFabric};
use crate::obs;

/// Persist one round-boundary checkpoint: β plus the session identity
/// and membership an operator needs to `--resume` (see
/// [`crate::coordinator::checkpoint`]). A write failure aborts the run
/// — the operator asked for durability, so silently training on
/// without it would be the worse failure mode.
fn write_checkpoint<F: SecureFabric>(
    dir: &std::path::Path,
    durable: &DurableRun,
    fab: &F,
    fleet: &dyn Fleet,
    round: u64,
    beta: &[f64],
) -> anyhow::Result<()> {
    let (live, excluded) = fleet.membership();
    let cp = SessionCheckpoint {
        protocol: "privlogit-local".into(),
        round,
        beta: beta.to_vec(),
        w: fab.fmt().w as u32,
        f: fab.fmt().f,
        seed: durable.seed,
        modulus_bits: durable.modulus_bits,
        epoch: durable.epoch,
        session: fab.session_id(),
        p: fleet.p() as u64,
        n_total: fleet.n_total() as u64,
        dataset: fleet.dataset_name(),
        live,
        excluded,
        ledger: checkpoint::ledger_snapshot(fab.ledger()),
    };
    checkpoint::save(dir, &cp)?;
    // A round boundary is a durability boundary: flush buffered trace
    // lines too, so a center killed after this checkpoint leaves a
    // parseable trace of everything the checkpoint covers.
    crate::obs::flush();
    Ok(())
}

/// Setup: `SetupOnce` + Algorithm 3 step 2 (materialize `Enc(H̃⁻¹)`).
pub fn setup_inverse<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    lambda: f64,
    scale: f64,
) -> anyhow::Result<EncMat> {
    let p = fleet.p();
    let replies = fleet.gram(scale)?;
    let enc_h = node_matrix_round(fab, replies, crate::mpc::tri_len(p))?;
    let agg = fab.aggregate(enc_h)?;
    let h = fab.add_plain(&agg, &reg_diag_tri(p, lambda * scale))?;
    let h_shares = fab.to_shares(&h)?;
    // One garbled program: Cholesky + triangular inverse + TᵀT + masked
    // wide reveal, re-encrypted so nodes receive Enc(H̃⁻¹) (scale f).
    Ok(fab.inverse_to_enc(&h_shares, p))
}

/// One iteration's node round: per-node `Enc(H̃⁻¹ g_j)` and `Enc(l_sj)`.
///
/// Two topologies, one interface: with node-side encryption installed
/// (the deployed remote fleet) the nodes apply their stored `Enc(H̃⁻¹)`
/// themselves and only ciphertexts cross the wire; otherwise the nodes
/// return plaintext statistics and the fabric performs the encryption
/// and the multiply-by-constant, attributing the cost to the node.
///
/// Attribution uses each reply's [`crate::coordinator::fleet::StepReply::org`]
/// — under a quorum fleet the replies may come from a strict subset of
/// the original membership, and the aggregation below simply sums over
/// whoever replied.
fn node_step_round<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    hinv: &EncMat,
    beta: &[f64],
    scale: f64,
) -> anyhow::Result<(Vec<EncVec>, Vec<EncVec>)> {
    let p = hinv.p;
    let f = fab.fmt().f;
    let mut enc_parts = Vec::new();
    let mut enc_l = Vec::new();
    if fleet.nodes_encrypt() {
        for r in fleet.step(beta, scale)? {
            let j = r.org;
            fab.ledger_mut().add_node(j, r.secs);
            // Step replies are wire-controlled: validate shape and
            // scales here, with errors naming the node.
            anyhow::ensure!(
                r.part.cts.len() == p,
                "node {j} step reply has {} partial-step ciphertexts, expected p = {p}",
                r.part.cts.len()
            );
            anyhow::ensure!(
                r.part.scale == 2 * f,
                "node {j} step reply carries scale {}, expected 2f = {}",
                r.part.scale,
                2 * f
            );
            anyhow::ensure!(
                r.loglik.cts.len() == 1 && r.loglik.scale == f,
                "node {j} log-likelihood reply is malformed \
                 ({} ciphertexts at scale {}, expected 1 at {f})",
                r.loglik.cts.len(),
                r.loglik.scale
            );
            enc_parts.push(enc_vec_from(r.part.scale, r.part.cts));
            enc_l.push(enc_vec_from(r.loglik.scale, r.loglik.cts));
            // Node-performed crypto: the exact scalar/add tally is the
            // node's private business (it depends on which encoded
            // gradient constants are zero), so charge the same p²/p(p−1)
            // model `ModelFabric::node_apply_hinv` uses, keeping op
            // tables comparable across deployment topologies.
            fab.ledger_mut().paillier_scalar += (p * p) as u64;
            fab.ledger_mut().paillier_adds += (p * (p - 1)) as u64;
            fab.ledger_mut().paillier_encs += 1;
        }
    } else {
        for r in fleet.stats(beta, scale)? {
            let j = r.org;
            fab.ledger_mut().add_node(j, r.secs);
            match r.payload {
                NodePayload::Plain { values, loglik } => {
                    enc_l.push(fab.node_encrypt_vec(j, &[loglik]));
                    enc_parts.push(fab.node_apply_hinv(j, hinv, &values));
                }
                NodePayload::Enc(_) => anyhow::bail!(
                    "node {j} sent ciphertexts but no Enc(H̃⁻¹) was installed"
                ),
            }
        }
    }
    fab.ledger_mut().end_node_round();
    Ok((enc_parts, enc_l))
}

/// Run PrivLogit-Local (Algorithm 3). A node or center peer that dies
/// mid-protocol surfaces as `Err` — unless the fleet runs in quorum
/// mode, in which case the round proceeds over the surviving subset.
///
/// **Quorum semantics.** `scale = 1/n` is fixed at protocol start and
/// deliberately *not* rescaled when nodes drop out: the stationarity
/// condition `Σ_live g_j − λβ = 0` is scale-invariant, so the fixed
/// point is exactly the regularized MLE of the surviving subset, and
/// the full-fleet `H̃` remains a valid PSD majorizer of the subset's
/// Hessian whether the exclusion happened during the Gram round or
/// mid-iteration. Only the *preconditioning* reflects the original
/// membership — convergence slows slightly, correctness is unaffected.
pub fn run_privlogit_local<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    cfg: &ProtocolConfig,
) -> anyhow::Result<RunReport> {
    run_privlogit_local_durable(fab, fleet, cfg, &DurableRun::default())
}

/// [`run_privlogit_local`] with session durability: checkpoints β and
/// the session state to `durable.state_dir` at every round boundary
/// (atomic tmp + rename), and/or continues from `durable.resume`
/// instead of round 0.
///
/// **Resume semantics.** PrivLogit-Local's only cross-round state is β
/// and the rebroadcastable `Enc(H̃⁻¹)`, which is why resume is scoped
/// to this protocol. Setup re-runs in the new incarnation (same seed ⇒
/// same keypair ⇒ same session id, so the merged timeline stitches);
/// iteration continues at the checkpointed global index — `proto.iter`
/// spans carry the *global* round, so both incarnations' spans line up
/// — and the convergence window restarts (the first resumed pass has
/// no previous log-likelihood to compare against, costing at most one
/// extra iteration). The resumed report's ledger accounts the new
/// incarnation only; `iterations` is global.
pub fn run_privlogit_local_durable<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    cfg: &ProtocolConfig,
    durable: &DurableRun,
) -> anyhow::Result<RunReport> {
    let p = fleet.p();
    let n = fleet.n_total();
    let scale = 1.0 / n as f64;

    let (mut beta, iter_offset) = match &durable.resume {
        Some(cp) => {
            anyhow::ensure!(
                cp.beta.len() == p,
                "checkpoint β has {} coefficients but the fleet serves p = {p} — \
                 resume needs the same shards the session started with",
                cp.beta.len()
            );
            (cp.beta.clone(), cp.round)
        }
        None => (vec![0.0; p], 0),
    };

    // Steps 1–2: setup; Enc(H̃⁻¹) is then broadcast to all nodes — for
    // real over the wire when the fleet's nodes hold the key.
    let hinv = {
        let _sp = obs::span("proto.setup")
            .session(fab.session_id())
            .str("protocol", "privlogit-local");
        setup_inverse(fab, fleet, cfg.lambda, scale)?
    };
    if fleet.nodes_encrypt() {
        fleet.install_hinv(&enc_stat_of(&hinv.tri)?)?;
    }
    // Broadcast cost: p(p+1)/2 ciphertexts to each of S nodes.
    let bcast = (crate::mpc::tri_len(p) * fleet.orgs()) as u64;
    fab.ledger_mut().bytes += bcast * 2 * 128; // ~2·|n|/8 bytes per ct at 1024-bit
    fab.ledger_mut().bytes_recv += bcast * 2 * 128; // received by the nodes
    fab.ledger_mut().rounds += 1;
    let setup_secs = total_secs(fab);

    let mut prev_l = None;
    let mut iterations = iter_offset as usize;
    let mut converged = false;

    // Setup survived: a crash before the first round boundary resumes
    // here rather than re-running a possibly long dead session's work.
    if let Some(dir) = &durable.state_dir {
        write_checkpoint(dir, durable, fab, fleet, iterations as u64, &beta)?;
    }

    while iterations < cfg.max_iters {
        // One span per model-update round, at the *global* iteration
        // index; the final (convergence-only) pass emits one too, so
        // span count = iterations + converged.
        let _sp = obs::span("proto.iter")
            .session(fab.session_id())
            .round(iterations as u64)
            .str("protocol", "privlogit-local");
        // Steps 4–9: nodes compute l_sj (encrypted) and the *local*
        // partial Newton step Enc(H̃⁻¹ g_j) via multiply-by-constant.
        let (enc_parts, enc_l) = node_step_round(fab, fleet, &hinv, &beta, scale)?;

        // Step 10: compose the global step; regularization term
        // Enc(λ·H̃⁻¹β) from the public β (computed center-side).
        let agg = fab.aggregate(enc_parts)?;
        let reg: Vec<f64> = beta.iter().map(|b| -cfg.lambda * b * scale).collect();
        let reg_part = fab.center_apply_hinv(&hinv, &reg);
        let step_enc = fab.aggregate(vec![agg, reg_part])?;

        // Steps 12–13: aggregate log-likelihood + secure convergence.
        let l = aggregate_loglik(fab, enc_l, &beta, cfg.lambda, scale)?;
        let l_sh = fab.to_shares(&l)?;
        if let Some(prev) = &prev_l {
            if fab.converged(&l_sh, prev, cfg.tol) {
                converged = true;
                break;
            }
        }
        prev_l = Some(l_sh);

        // Step 11 + 14: reveal the update step (β is public each
        // iteration, §5.3) and disseminate the new coefficients.
        let delta = fab.decrypt_reveal(&step_enc);
        for (b, d) in beta.iter_mut().zip(&delta) {
            *b += d;
        }
        iterations += 1;

        // Round boundary: the new iterate is durable before the next
        // round starts, so a crash loses at most the round in flight.
        if let Some(dir) = &durable.state_dir {
            write_checkpoint(dir, durable, fab, fleet, iterations as u64, &beta)?;
        }
    }

    Ok(RunReport {
        protocol: "privlogit-local",
        backend: fab.backend_label().to_string(),
        engine: fleet.label(),
        dataset: fleet.dataset_name(),
        p,
        n,
        orgs: fleet.orgs(),
        iterations,
        converged,
        beta,
        setup_secs,
        total_secs: total_secs(fab),
        ledger: final_ledger(fab, fleet),
    })
}
