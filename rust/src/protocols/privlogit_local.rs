//! PrivLogit-Local (paper Algorithm 3): decentralizing the Newton step.
//!
//! Setup materializes `Enc(H̃⁻¹)` once (garbled Cholesky + triangular
//! inversion + masked re-encryption) and disseminates it to nodes. Each
//! iteration, node `j` computes `Enc(H̃⁻¹)⊗g_j` *locally* using only
//! cheap Paillier multiply-by-constant ops (its own gradient is
//! privacy-free to itself, paper §4.2); the Center merely ⊕-aggregates
//! `p` ciphertexts, adds the regularization term `Enc(λH̃⁻¹β)` and
//! reveals the (by-design public) update step. No garbled circuits run
//! in the iteration loop except the single-bit convergence check.

use super::common::*;
use crate::coordinator::fleet::Fleet;
use crate::mpc::{EncMat, SecureFabric};

/// Setup: `SetupOnce` + Algorithm 3 step 2 (materialize `Enc(H̃⁻¹)`).
pub fn setup_inverse<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    lambda: f64,
    scale: f64,
) -> EncMat {
    let p = fleet.p();
    let replies = fleet.gram(scale);
    let enc_h = node_matrix_round(fab, replies);
    let agg = fab.aggregate(enc_h);
    let h = fab.add_plain(&agg, &reg_diag_tri(p, lambda * scale));
    let h_shares = fab.to_shares(&h);
    // One garbled program: Cholesky + triangular inverse + TᵀT + masked
    // wide reveal, re-encrypted so nodes receive Enc(H̃⁻¹) (scale f).
    fab.inverse_to_enc(&h_shares, p)
}

/// Run PrivLogit-Local (Algorithm 3).
pub fn run_privlogit_local<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    cfg: &ProtocolConfig,
) -> RunReport {
    let p = fleet.p();
    let n = fleet.n_total();
    let scale = 1.0 / n as f64;

    // Steps 1–2: setup; Enc(H̃⁻¹) is then broadcast to all nodes.
    let hinv = setup_inverse(fab, fleet, cfg.lambda, scale);
    // Broadcast cost: p(p+1)/2 ciphertexts to each of S nodes.
    let bcast = (crate::mpc::tri_len(p) * fleet.orgs()) as u64;
    fab.ledger_mut().bytes += bcast * 2 * 128; // ~2·|n|/8 bytes per ct at 1024-bit
    fab.ledger_mut().bytes_recv += bcast * 2 * 128; // received by the nodes
    fab.ledger_mut().rounds += 1;
    let setup_secs = total_secs(fab);

    let mut beta = vec![0.0; p];
    let mut prev_l = None;
    let mut iterations = 0;
    let mut converged = false;

    for _ in 0..cfg.max_iters {
        // Steps 4–9: nodes compute l_sj (encrypted) and the *local*
        // partial Newton step Enc(H̃⁻¹ g_j) via multiply-by-constant.
        let replies = fleet.stats(&beta, scale);
        let mut enc_parts = Vec::with_capacity(replies.len());
        let mut enc_l = Vec::with_capacity(replies.len());
        for (j, r) in replies.iter().enumerate() {
            fab.ledger_mut().add_node(j, r.secs);
            enc_l.push(fab.node_encrypt_vec(j, &[r.loglik]));
            enc_parts.push(fab.node_apply_hinv(j, &hinv, &r.values));
        }
        fab.ledger_mut().end_node_round();

        // Step 10: compose the global step; regularization term
        // Enc(λ·H̃⁻¹β) from the public β (computed center-side).
        let agg = fab.aggregate(enc_parts);
        let reg: Vec<f64> = beta.iter().map(|b| -cfg.lambda * b * scale).collect();
        let reg_part = fab.center_apply_hinv(&hinv, &reg);
        let step_enc = fab.aggregate(vec![agg, reg_part]);

        // Steps 12–13: aggregate log-likelihood + secure convergence.
        let l = aggregate_loglik(fab, enc_l, &beta, cfg.lambda, scale);
        let l_sh = fab.to_shares(&l);
        if let Some(prev) = &prev_l {
            if fab.converged(&l_sh, prev, cfg.tol) {
                converged = true;
                break;
            }
        }
        prev_l = Some(l_sh);

        // Step 11 + 14: reveal the update step (β is public each
        // iteration, §5.3) and disseminate the new coefficients.
        let delta = fab.decrypt_reveal(&step_enc);
        for (b, d) in beta.iter_mut().zip(&delta) {
            *b += d;
        }
        iterations += 1;
    }

    RunReport {
        protocol: "privlogit-local",
        backend: fab.backend_label().to_string(),
        engine: fleet.label(),
        dataset: fleet.dataset_name(),
        p,
        n,
        orgs: fleet.orgs(),
        iterations,
        converged,
        beta,
        setup_secs,
        total_secs: total_secs(fab),
        ledger: final_ledger(fab, fleet),
    }
}
