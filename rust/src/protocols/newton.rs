//! Secure distributed Newton method — the state-of-the-art baseline the
//! paper compares against (after Li et al. 2016), implemented on the same
//! cryptographic stack as the PrivLogit protocols.
//!
//! Per iteration: every node computes and encrypts its *exact* Hessian
//! contribution `X_jᵀAX_j` (p(p+1)/2 ciphertexts!) plus gradient and
//! log-likelihood; the Center aggregates, converts to shares, and runs a
//! garbled Cholesky + back-substitution — `O(p³)` secure work *every*
//! iteration. This repetition is precisely the bottleneck PrivLogit
//! removes (paper §3.1).

use super::common::*;
use crate::coordinator::fleet::Fleet;
use crate::mpc::SecureFabric;
use crate::obs;

/// Run the secure Newton baseline over a node fleet. A node that dies
/// mid-protocol surfaces as `Err`.
pub fn run_newton<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    cfg: &ProtocolConfig,
) -> anyhow::Result<RunReport> {
    let p = fleet.p();
    let n = fleet.n_total();
    let scale = 1.0 / n as f64;
    let mut beta = vec![0.0; p];
    let mut prev_l = None;
    let mut iterations = 0;
    let mut converged = false;
    let setup_secs = total_secs(fab); // keygen + base OT only

    for iter in 0..cfg.max_iters {
        // One span per model-update round; the final (convergence-only)
        // pass emits one too, so span count = iterations + converged.
        let _sp = obs::span("proto.iter")
            .session(fab.session_id())
            .round(iter as u64)
            .str("protocol", "newton");
        // --- node round: exact Hessian + gradient + log-likelihood ---
        let (enc_g, enc_l) = node_stats_round(fab, fleet, &beta, scale)?;
        let h_replies = fleet.hessian(&beta, scale)?;
        let enc_h = node_matrix_round(fab, h_replies, crate::mpc::tri_len(p))?;

        // --- center: aggregate + regularize ---
        let g = aggregate_gradient(fab, enc_g, &beta, cfg.lambda, scale)?;
        let l = aggregate_loglik(fab, enc_l, &beta, cfg.lambda, scale)?;
        let h = {
            let agg = fab.aggregate(enc_h)?;
            fab.add_plain(&agg, &reg_diag_tri(p, cfg.lambda * scale))?
        };

        // --- secure convergence check ---
        let l_shares = fab.to_shares(&l)?;
        if let Some(prev) = &prev_l {
            if fab.converged(&l_shares, prev, cfg.tol) {
                converged = true;
                break;
            }
        }
        prev_l = Some(l_shares);

        // --- secure Newton step: garbled Cholesky + solve (every iter) ---
        let h_shares = fab.to_shares(&h)?;
        let g_shares = fab.to_shares(&g)?;
        let delta = fab.newton_step(&h_shares, &g_shares, p);
        for (b, d) in beta.iter_mut().zip(&delta) {
            *b += d;
        }
        iterations += 1;
    }

    Ok(RunReport {
        protocol: "newton",
        backend: fab.backend_label().to_string(),
        engine: fleet.label(),
        dataset: fleet.dataset_name(),
        p,
        n,
        orgs: fleet.orgs(),
        iterations,
        converged,
        beta,
        setup_secs,
        total_secs: total_secs(fab),
        ledger: final_ledger(fab, fleet),
    })
}
