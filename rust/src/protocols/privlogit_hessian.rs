//! PrivLogit-Hessian (paper Algorithm 1): the direct secure realization of
//! the PrivLogit optimizer.
//!
//! `SetupOnce` (Algorithm 2) runs exactly once: nodes encrypt their
//! constant `¼X_jᵀX_j` shares, the Center aggregates, converts to shares
//! and garbled-Cholesky-decomposes — the only `O(p³)` secure computation
//! in the whole run. Every iteration afterwards costs one gradient
//! aggregation plus an `O(p²)` garbled back-substitution.

use super::common::*;
use crate::coordinator::fleet::Fleet;
use crate::mpc::{SecVec, SecureFabric};
use crate::obs;

/// `SetupOnce` (Algorithm 2): secure approximate-Hessian aggregation and
/// Cholesky factorization. Returns the shared triangular factor `L`.
pub fn setup_once<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    lambda: f64,
    scale: f64,
) -> anyhow::Result<SecVec> {
    let p = fleet.p();
    let replies = fleet.gram(scale)?;
    let enc_h = node_matrix_round(fab, replies, crate::mpc::tri_len(p))?;
    let agg = fab.aggregate(enc_h)?;
    let h = fab.add_plain(&agg, &reg_diag_tri(p, lambda * scale))?;
    let h_shares = fab.to_shares(&h)?;
    Ok(fab.cholesky_shares(&h_shares, p))
}

/// Run PrivLogit-Hessian (Algorithm 1). A node that dies mid-protocol
/// surfaces as `Err`.
pub fn run_privlogit_hessian<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    cfg: &ProtocolConfig,
) -> anyhow::Result<RunReport> {
    let p = fleet.p();
    let n = fleet.n_total();
    let scale = 1.0 / n as f64;

    // Step 1: SetupOnce (the one-time O(p³) phase).
    let l_shares = {
        let _sp = obs::span("proto.setup")
            .session(fab.session_id())
            .str("protocol", "privlogit-hessian");
        setup_once(fab, fleet, cfg.lambda, scale)?
    };
    let setup_secs = total_secs(fab);

    let mut beta = vec![0.0; p];
    let mut prev_l = None;
    let mut iterations = 0;
    let mut converged = false;

    for iter in 0..cfg.max_iters {
        // One span per model-update round; the final (convergence-only)
        // pass emits one too, so span count = iterations + converged.
        let _sp = obs::span("proto.iter")
            .session(fab.session_id())
            .round(iter as u64)
            .str("protocol", "privlogit-hessian");
        // Steps 3–7: node gradient + log-likelihood round.
        let (enc_g, enc_l) = node_stats_round(fab, fleet, &beta, scale)?;
        // Steps 8, 11: aggregation + public regularization terms.
        let g = aggregate_gradient(fab, enc_g, &beta, cfg.lambda, scale)?;
        let l = aggregate_loglik(fab, enc_l, &beta, cfg.lambda, scale)?;
        // Step 12: secure convergence check.
        let l_sh = fab.to_shares(&l)?;
        if let Some(prev) = &prev_l {
            if fab.converged(&l_sh, prev, cfg.tol) {
                converged = true;
                break;
            }
        }
        prev_l = Some(l_sh);
        // Steps 9–10: O(p²) garbled back-substitution; β update (public
        // per §5.3 — coefficients are disseminated every iteration).
        let g_shares = fab.to_shares(&g)?;
        let delta = fab.solve_reveal(&l_shares, &g_shares, p);
        for (b, d) in beta.iter_mut().zip(&delta) {
            *b += d;
        }
        iterations += 1;
    }

    Ok(RunReport {
        protocol: "privlogit-hessian",
        backend: fab.backend_label().to_string(),
        engine: fleet.label(),
        dataset: fleet.dataset_name(),
        p,
        n,
        orgs: fleet.orgs(),
        iterations,
        converged,
        beta,
        setup_secs,
        total_secs: total_secs(fab),
        ledger: final_ledger(fab, fleet),
    })
}
