//! Secure distributed **ridge regression** — the Nikolaenko et al. (2013)
//! style one-shot protocol the paper repeatedly cites as its closest
//! large-scale precedent ("not even for a much simpler linear regression
//! model", §6.3).
//!
//! Ridge is the degenerate case of the PrivLogit pipeline: the normal
//! equations `(XᵀX + λI)β = Xᵀy` need no iteration at all, so the whole
//! fit is one `SetupOnce`-shaped pass — node Gram/moment encryption,
//! Paillier aggregation, one garbled Cholesky + solve. Including it
//! both validates the fabric on a second model family and provides the
//! cross-paper baseline for the ablation bench.

use super::common::*;
use crate::coordinator::fleet::Fleet;
use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::mpc::SecureFabric;

/// A node's ridge moments: packed `X_jᵀX_j` triangle and `X_jᵀy_j`.
pub fn local_moments(data: &Dataset, scale: f64) -> (Vec<f64>, Vec<f64>) {
    let mut gram = data.x.gram();
    gram.scale(scale);
    let p = data.p();
    let mut xty = vec![0.0; p];
    for i in 0..data.n() {
        let row = data.x.row(i);
        for j in 0..p {
            xty[j] += row[j] * data.y[i] * scale;
        }
    }
    (pack_tri(&gram), xty)
}

/// Plaintext reference fit (ground truth for tests/benches).
pub fn fit_ridge_plaintext(parts: &[Dataset], lambda: f64) -> Vec<f64> {
    let p = parts[0].p();
    let n: usize = parts.iter().map(|d| d.n()).sum();
    let scale = 1.0 / n as f64;
    let mut a = Matrix::zeros(p, p);
    let mut b = vec![0.0; p];
    for d in parts {
        let (tri, xty) = local_moments(d, scale);
        for i in 0..p {
            for j in 0..=i {
                a[(i, j)] += tri[crate::mpc::tri_idx(i, j)];
                a[(j, i)] = a[(i, j)];
            }
        }
        for j in 0..p {
            b[j] += xty[j];
        }
    }
    a.add_diag(lambda * scale);
    a.solve_spd(&b).expect("ridge normal matrix SPD")
}

/// Run the one-shot secure ridge protocol. Returns (β, report-style
/// timing): the entire fit is a single setup-phase-shaped pass.
pub fn run_ridge<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    lambda: f64,
) -> anyhow::Result<RunReport> {
    let p = fleet.p();
    let n = fleet.n_total();
    let scale = 1.0 / n as f64;

    // Node round: both moment sets. (Fleet's gram hook returns ¼XᵀX for
    // PrivLogit — undo the ¼ homomorphically-free at the node by scaling.)
    let gram_replies = fleet.gram(4.0 * scale)?; // ¼·4 = 1
    let enc_gram = node_matrix_round(fab, gram_replies, crate::mpc::tri_len(p))?;
    // Xᵀy is not a Fleet hook (logistic never needs it): compute via the
    // stats hook at β=0 — g(0) = Xᵀ(y − ½) = Xᵀy − ½Xᵀ1, and for
    // standardized columns Xᵀ1 = 0, so g(0) = Xᵀy exactly.
    let zero_beta = vec![0.0; p];
    let (enc_xty, _enc_l) = node_stats_round(fab, fleet, &zero_beta, scale)?;

    let a = {
        let agg = fab.aggregate(enc_gram)?;
        fab.add_plain(&agg, &reg_diag_tri(p, lambda * scale))?
    };
    let b = fab.aggregate(enc_xty)?;

    let a_shares = fab.to_shares(&a)?;
    let b_shares = fab.to_shares(&b)?;
    let beta = fab.newton_step(&a_shares, &b_shares, p); // Cholesky + solve

    Ok(RunReport {
        protocol: "ridge",
        backend: fab.backend_label().to_string(),
        engine: fleet.label(),
        dataset: fleet.dataset_name(),
        p,
        n,
        orgs: fleet.orgs(),
        iterations: 1,
        converged: true,
        beta,
        setup_secs: 0.0,
        total_secs: total_secs(fab),
        ledger: fab.ledger().clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::LocalFleet;
    use crate::data::synthesize;
    use crate::gc::word::FixedFmt;
    use crate::linalg::r_squared;
    use crate::mpc::{ModelFabric, RealFabric};
    use crate::runtime::CpuCompute;
    use crate::testutil::assert_all_close;

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    #[test]
    fn plaintext_ridge_solves_normal_equations() {
        let d = synthesize("r", 2000, 5, 91);
        let parts = d.partition(3);
        let beta = fit_ridge_plaintext(&parts, 1.0);
        // residual orthogonality: Xᵀ(y − Xβ) = λβ (+ mean offset in the
        // intercept-free standardized model)
        let n = d.n() as f64;
        let pred = d.x.matvec(&beta);
        let resid: Vec<f64> = d.y.iter().zip(&pred).map(|(y, p)| y - p).collect();
        let xtr = d.x.transpose().matvec(&resid);
        for j in 0..d.p() {
            assert!(
                (xtr[j] / n - beta[j] / n).abs() < 1e-9,
                "normal equations: {} vs {}",
                xtr[j] / n,
                beta[j] / n
            );
        }
    }

    #[test]
    fn secure_ridge_real_crypto_matches_plaintext() {
        let d = synthesize("r2", 1000, 4, 92);
        let parts = d.partition(2);
        let expect = fit_ridge_plaintext(&parts, 1.0);
        let mut fleet = LocalFleet::new(parts, Box::new(CpuCompute));
        let mut fab = RealFabric::new(256, FMT, 93);
        let rep = run_ridge(&mut fab, &mut fleet, 1.0).unwrap();
        assert_all_close(&rep.beta, &expect, 2e-3, "secure ridge");
        let r2 = r_squared(&rep.beta, &expect);
        assert!(r2 > 0.9999, "R²={r2}");
        assert!(rep.ledger.gc_ands > 0, "one garbled solve must run");
    }

    #[test]
    fn secure_ridge_modeled_is_one_shot() {
        let d = synthesize("r3", 3000, 20, 94);
        let parts = d.partition(4);
        let expect = fit_ridge_plaintext(&parts, 1.0);
        let mut fleet = LocalFleet::new(parts, Box::new(CpuCompute));
        let mut fab = ModelFabric::new(2048, FMT);
        let rep = run_ridge(&mut fab, &mut fleet, 1.0).unwrap();
        assert_all_close(&rep.beta, &expect, 1e-4, "modeled ridge");
        assert_eq!(rep.iterations, 1);
    }
}
