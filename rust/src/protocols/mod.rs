//! The paper's three secure protocols over one shared fabric:
//!
//! * [`newton::run_newton`] — the secure distributed Newton baseline
//!   (repeated `O(p³)` garbled Hessian work — §2.2, the state of the art
//!   the paper benchmarks against);
//! * [`privlogit_hessian::run_privlogit_hessian`] — Algorithm 1 (one-time
//!   garbled Cholesky, `O(p²)` iterations);
//! * [`privlogit_local::run_privlogit_local`] — Algorithm 3 (one-time
//!   `Enc(H̃⁻¹)`, iterations reduced to node-side multiply-by-constant
//!   plus `O(p)` aggregation).
//!
//! All three run against either [`crate::mpc::RealFabric`] (everything
//! executed) or [`crate::mpc::ModelFabric`] (calibrated cost model for
//! paper-scale p — DESIGN.md §7), with identical protocol logic, and
//! over any [`crate::coordinator::fleet::Fleet`] — in-process, threaded
//! or remote TCP node servers. Every run returns `Result`: a node or
//! center peer that dies mid-protocol surfaces as a descriptive error,
//! not a panic.
//!
//! Cheap end-to-end run (modeled backend, tiny synthetic study):
//!
//! ```
//! use privlogit::coordinator::fleet::LocalFleet;
//! use privlogit::data::synthesize;
//! use privlogit::gc::word::FixedFmt;
//! use privlogit::mpc::ModelFabric;
//! use privlogit::protocols::{Protocol, ProtocolConfig};
//! use privlogit::runtime::CpuCompute;
//!
//! let parts = synthesize("doc", 300, 3, 7).partition(2);
//! let mut fleet = LocalFleet::new(parts, Box::new(CpuCompute));
//! let mut fab = ModelFabric::new(2048, FixedFmt::DEFAULT);
//! let report = Protocol::PrivLogitLocal
//!     .run(&mut fab, &mut fleet, &ProtocolConfig::default())
//!     .unwrap();
//! assert!(report.converged);
//! ```

pub mod common;
pub mod newton;
pub mod privlogit_hessian;
pub mod privlogit_local;
pub mod ridge;

pub use common::{DurableRun, ProtocolConfig, RunReport};
pub use newton::run_newton;
pub use privlogit_hessian::run_privlogit_hessian;
pub use privlogit_local::{run_privlogit_local, run_privlogit_local_durable};

/// Which protocol to run (CLI/config selection).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    /// Secure Newton baseline.
    Newton,
    /// PrivLogit-Hessian (Algorithm 1).
    PrivLogitHessian,
    /// PrivLogit-Local (Algorithm 3).
    PrivLogitLocal,
}

impl Protocol {
    /// All protocols, in the paper's comparison order.
    pub const ALL: [Protocol; 3] =
        [Protocol::Newton, Protocol::PrivLogitHessian, Protocol::PrivLogitLocal];

    /// Parse a CLI name (no error text; prefer `str::parse::<Protocol>`
    /// where a descriptive error can reach the user).
    pub fn parse(s: &str) -> Option<Protocol> {
        match s.to_ascii_lowercase().as_str() {
            "newton" => Some(Protocol::Newton),
            "privlogit-hessian" | "hessian" | "plh" => Some(Protocol::PrivLogitHessian),
            "privlogit-local" | "local" | "pll" => Some(Protocol::PrivLogitLocal),
            _ => None,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Protocol::Newton => "newton",
            Protocol::PrivLogitHessian => "privlogit-hessian",
            Protocol::PrivLogitLocal => "privlogit-local",
        }
    }

    /// Valid CLI spellings, for error messages.
    pub const VALID_NAMES: &'static str =
        "newton | privlogit-hessian (hessian, plh) | privlogit-local (local, pll)";

    /// Dispatch to the protocol implementation. A node or center peer
    /// that dies mid-protocol surfaces as `Err`.
    pub fn run<F: crate::mpc::SecureFabric>(
        &self,
        fab: &mut F,
        fleet: &mut dyn crate::coordinator::fleet::Fleet,
        cfg: &ProtocolConfig,
    ) -> anyhow::Result<RunReport> {
        match self {
            Protocol::Newton => run_newton(fab, fleet, cfg),
            Protocol::PrivLogitHessian => run_privlogit_hessian(fab, fleet, cfg),
            Protocol::PrivLogitLocal => run_privlogit_local(fab, fleet, cfg),
        }
    }

    /// [`Protocol::run`] with session durability. Checkpointing and
    /// resume are scoped to PrivLogit-Local — its only cross-round
    /// state is β and the rebroadcastable `Enc(H̃⁻¹)`. Newton and
    /// PrivLogit-Hessian carry garbled-circuit state (share custody at
    /// S2) that cannot be reconstructed in a new process, so a resume
    /// request aborts with a clear error and a `--state-dir` is
    /// ignored with a warning.
    pub fn run_durable<F: crate::mpc::SecureFabric>(
        &self,
        fab: &mut F,
        fleet: &mut dyn crate::coordinator::fleet::Fleet,
        cfg: &ProtocolConfig,
        durable: &DurableRun,
    ) -> anyhow::Result<RunReport> {
        match self {
            Protocol::PrivLogitLocal => {
                run_privlogit_local_durable(fab, fleet, cfg, durable)
            }
            _ => {
                anyhow::ensure!(
                    durable.resume.is_none(),
                    "--resume is only supported for privlogit-local (its cross-round \
                     state is just β and the rebroadcastable Enc(H̃⁻¹)); {} holds \
                     share custody at center-b that a new process cannot rebuild — \
                     restart the session from round 0 instead",
                    self.name()
                );
                if durable.state_dir.is_some() {
                    crate::obs::warn(format_args!(
                        "--state-dir is ignored for {}: only privlogit-local \
                         checkpoints at round boundaries",
                        self.name()
                    ));
                }
                self.run(fab, fleet, cfg)
            }
        }
    }
}

impl std::str::FromStr for Protocol {
    type Err = anyhow::Error;

    /// Parse a CLI name; a typo's error names the valid spellings.
    fn from_str(s: &str) -> Result<Protocol, anyhow::Error> {
        Protocol::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown protocol {s:?} — valid: {}", Protocol::VALID_NAMES)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::{LocalFleet, ThreadedFleet};
    use crate::data::synthesize;
    use crate::gc::word::FixedFmt;
    use crate::linalg::r_squared;
    use crate::mpc::{ModelFabric, RealFabric, SecureFabric};
    use crate::optim::{fit, Method, OptimConfig};
    use crate::runtime::CpuCompute;

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    fn plaintext_fit(parts: &[crate::data::Dataset], method: Method) -> crate::optim::Fit {
        fit(parts, method, OptimConfig::default())
    }

    /// REAL crypto end-to-end, small p: all three protocols reproduce the
    /// plaintext optimum with R² ≈ 1 (the Fig. 2 claim) and the expected
    /// iteration counts.
    #[test]
    fn real_protocols_match_plaintext() {
        let d = synthesize("t", 1200, 4, 31);
        let parts = d.partition(3);
        let cfg = ProtocolConfig::default();
        let newton_ref = plaintext_fit(&parts, Method::Newton);
        let privlogit_ref = plaintext_fit(&parts, Method::PrivLogit);

        for proto in Protocol::ALL {
            // exercise the real threaded node topology for one protocol
            let mut fleet: Box<dyn crate::coordinator::fleet::Fleet> =
                if proto == Protocol::PrivLogitLocal {
                    Box::new(ThreadedFleet::spawn(parts.clone()))
                } else {
                    Box::new(LocalFleet::new(parts.clone(), Box::new(CpuCompute)))
                };
            let mut fab = RealFabric::new(256, FMT, 0xBEEF ^ proto.name().len() as u64);
            let rep = proto.run(&mut fab, fleet.as_mut(), &cfg).unwrap();
            assert!(rep.converged, "{} converged", proto.name());
            let r2 = r_squared(&rep.beta, &newton_ref.beta);
            assert!(r2 > 0.9999, "{}: R² = {r2}", proto.name());
            let expect_iters = match proto {
                Protocol::Newton => newton_ref.iterations,
                _ => privlogit_ref.iterations,
            };
            assert!(
                (rep.iterations as i64 - expect_iters as i64).abs() <= 2,
                "{}: iterations {} vs plaintext {}",
                proto.name(),
                rep.iterations,
                expect_iters
            );
            assert!(rep.total_secs > 0.0);
        }
    }

    /// Modeled backend at the Loans scale (p=33): iteration counts match
    /// the plaintext optimizers and the runtime ordering matches Table 2
    /// (PL-Local < PL-Hessian < Newton).
    #[test]
    fn modeled_protocols_table2_ordering() {
        let d = synthesize("t", 4000, 33, 32);
        let parts = d.partition(5);
        let cfg = ProtocolConfig::default();

        let mut totals = Vec::new();
        for proto in Protocol::ALL {
            let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
            let mut fab = ModelFabric::new(2048, FMT);
            let rep = proto.run(&mut fab, &mut fleet, &cfg).unwrap();
            assert!(rep.converged, "{}", proto.name());
            totals.push((proto, rep.total_secs, rep.iterations));
        }
        let newton = totals[0].1;
        let plh = totals[1].1;
        let pll = totals[2].1;
        assert!(pll < plh, "PL-Local ({pll:.1}s) < PL-Hessian ({plh:.1}s)");
        assert!(plh < newton, "PL-Hessian ({plh:.1}s) < Newton ({newton:.1}s) at p=33");
        // PrivLogit iteration inflation visible
        assert!(totals[1].2 > totals[0].2, "PrivLogit iterations > Newton");
    }

    /// The speedup must *grow* with p (Fig. 4's key trend).
    #[test]
    fn modeled_speedup_grows_with_p() {
        let cfg = ProtocolConfig::default();
        let mut total_speedups = Vec::new();
        let mut iter_speedups = Vec::new();
        for (p, seed) in [(10usize, 33u64), (40, 34)] {
            let d = synthesize("t", 3000, p, seed);
            let parts = d.partition(4);
            let run = |proto: Protocol| {
                let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
                let mut fab = ModelFabric::new(2048, FMT);
                let r = proto.run(&mut fab, &mut fleet, &cfg).unwrap();
                (r.total_secs, r.total_secs - r.setup_secs)
            };
            let newton = run(Protocol::Newton);
            let pll = run(Protocol::PrivLogitLocal);
            total_speedups.push(newton.0 / pll.0);
            iter_speedups.push(newton.1 / pll.1);
        }
        // PL-Local always wins on total time (Table 2's constant claim)…
        assert!(
            total_speedups.iter().all(|&s| s > 1.0),
            "always faster: {total_speedups:?}"
        );
        // …and the Fig. 4 growth trend shows in the iteration phase
        // (the paper's accounting amortizes the one-time setup; our
        // honest total-time speedup plateaus near I_N/3 — see
        // EXPERIMENTS.md §Fig4 discussion).
        assert!(
            iter_speedups[1] > iter_speedups[0] * 1.5,
            "iteration-phase speedup must grow with p: {iter_speedups:?}"
        );
    }

    #[test]
    fn protocol_parsing() {
        assert_eq!(Protocol::parse("newton"), Some(Protocol::Newton));
        assert_eq!(Protocol::parse("PLH"), Some(Protocol::PrivLogitHessian));
        assert_eq!(Protocol::parse("privlogit-local"), Some(Protocol::PrivLogitLocal));
        assert_eq!(Protocol::parse("sgd"), None);
    }

    /// A typo's parse error must name the typo and every valid spelling.
    #[test]
    fn protocol_parse_errors_are_descriptive() {
        assert_eq!("pll".parse::<Protocol>().unwrap(), Protocol::PrivLogitLocal);
        let err = "sgd".parse::<Protocol>().unwrap_err().to_string();
        assert!(err.contains("sgd"), "{err}");
        assert!(err.contains("newton"), "{err}");
        assert!(err.contains("privlogit-hessian"), "{err}");
        assert!(err.contains("privlogit-local"), "{err}");
    }
}
