//! Shared protocol plumbing: configuration, run reports, and the
//! node-round / aggregation helpers all three protocols use.
//!
//! The node-round helpers absorb the two encryption topologies behind
//! one interface: in-process fleets reply in plaintext and the *fabric*
//! encrypts at its boundary ([`SecureFabric::node_encrypt_vec`]); remote
//! fleets with an installed key reply with ciphertexts the nodes
//! encrypted themselves, which the helpers merely unwrap into [`EncVec`]s
//! — so protocol code is written once and runs over either.

use crate::bigint::BigUint;
use crate::coordinator::fleet::{EncStat, Fleet, NodePayload, NodeReply};
use crate::crypto::paillier::Ciphertext;
use crate::linalg::Matrix;
use crate::mpc::{tri_idx, tri_len, CostLedger, EncData, EncVec, SecureFabric};

/// Protocol configuration (paper §6 defaults).
#[derive(Clone, Copy, Debug)]
pub struct ProtocolConfig {
    /// ℓ₂ regularization λ.
    pub lambda: f64,
    /// Relative log-likelihood convergence threshold.
    pub tol: f64,
    /// Defensive iteration cap.
    pub max_iters: usize,
}

impl Default for ProtocolConfig {
    fn default() -> Self {
        ProtocolConfig { lambda: 1.0, tol: 1e-6, max_iters: 500 }
    }
}

/// Session-durability context for a protocol run: where to checkpoint,
/// what to resume from, and the session identity the checkpoints must
/// record so a resumed incarnation can prove continuity (same seed ⇒
/// same Paillier modulus ⇒ same session id in the merged timeline).
/// The default is a plain non-durable run.
#[derive(Clone, Debug, Default)]
pub struct DurableRun {
    /// Directory to persist round-boundary checkpoints under; `None`
    /// disables checkpointing.
    pub state_dir: Option<std::path::PathBuf>,
    /// Checkpoint to continue from (β and the completed-iteration
    /// index) instead of starting at round 0.
    pub resume: Option<crate::coordinator::checkpoint::SessionCheckpoint>,
    /// RNG seed of the session, recorded into checkpoints.
    pub seed: u64,
    /// Paillier modulus bits of the session, recorded into checkpoints.
    pub modulus_bits: u64,
    /// Session epoch this incarnation runs at (0 fresh; a resume runs
    /// at the checkpointed epoch + 1).
    pub epoch: u64,
}

/// Result of one secure protocol run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Protocol name ("newton", "privlogit-hessian", "privlogit-local").
    pub protocol: &'static str,
    /// Secure backend label (real vs modeled).
    pub backend: String,
    /// Node compute engine label (pjrt vs cpu).
    pub engine: String,
    /// Dataset name.
    pub dataset: String,
    /// Features.
    pub p: usize,
    /// Total samples.
    pub n: usize,
    /// Participating organizations.
    pub orgs: usize,
    /// Model-update iterations performed.
    pub iterations: usize,
    /// Whether the tolerance was met.
    pub converged: bool,
    /// Final coefficients.
    pub beta: Vec<f64>,
    /// One-time setup seconds (key gen + base OT + SetupOnce).
    pub setup_secs: f64,
    /// Total protocol seconds (compute + modeled network).
    pub total_secs: f64,
    /// Final cost ledger snapshot.
    pub ledger: CostLedger,
}

impl RunReport {
    /// Paper-style one-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<18} {:<10} iters={:<4} total={:>9.2}s setup={:>7.2}s (backend: {})",
            self.protocol, self.dataset, self.iterations, self.total_secs, self.setup_secs,
            self.backend
        )
    }
}

/// Pack the lower triangle of a symmetric matrix.
pub fn pack_tri(m: &Matrix) -> Vec<f64> {
    let p = m.rows;
    let mut out = Vec::with_capacity(tri_len(p));
    for i in 0..p {
        for j in 0..=i {
            out.push(m[(i, j)]);
        }
    }
    out
}

/// `λ·scale` added to the packed-triangle diagonal (the regularization
/// term of Eq. 6/5), as a plaintext vector for `add_plain`.
pub fn reg_diag_tri(p: usize, lambda_scaled: f64) -> Vec<f64> {
    let mut v = vec![0.0; tri_len(p)];
    for i in 0..p {
        v[tri_idx(i, i)] = lambda_scaled;
    }
    v
}

/// Wrap node-encrypted ciphertext residues as the fabric's
/// ciphertext-vector form (consuming — no per-ciphertext copies).
pub fn enc_vec_from(scale: u32, cts: Vec<BigUint>) -> EncVec {
    EncVec { scale, packed: None, data: EncData::Real(cts.into_iter().map(Ciphertext).collect()) }
}

/// Wrap node-encrypted *slot-packed* ciphertexts: `len` logical values
/// in `⌈len/k⌉` ciphertexts, one biased contribution per slot.
pub fn enc_vec_from_packed(
    scale: u32,
    cts: Vec<BigUint>,
    meta: crate::crypto::packed::PackedMeta,
) -> EncVec {
    EncVec {
        scale,
        packed: Some(meta),
        data: EncData::Real(cts.into_iter().map(Ciphertext).collect()),
    }
}

/// Extract the raw ciphertexts of a real [`EncVec`] for the fleet wire
/// (errors on a modeled vector — modeled ciphertexts are plaintext and
/// must never cross a process boundary).
pub fn enc_stat_of(v: &EncVec) -> anyhow::Result<EncStat> {
    match &v.data {
        EncData::Real(cts) => {
            Ok(EncStat { scale: v.scale, cts: cts.iter().map(|c| c.0.clone()).collect() })
        }
        EncData::Model(_) => {
            anyhow::bail!("modeled ciphertexts cannot cross the fleet wire")
        }
    }
}

/// One node round: every organization computes + encrypts its local
/// gradient and log-likelihood shares at `beta` (Alg. 1 steps 3–7).
/// Returns (per-node Enc(g_j), per-node Enc(l_sj)).
///
/// Node-encrypted replies are wire-controlled data: their shape (p + 1
/// ciphertexts — gradient then log-likelihood) and scale are validated
/// here, at the ingestion boundary, with errors naming the node — one
/// malformed reply must never panic the center.
///
/// Attribution uses each reply's own [`NodeReply::org`], not its
/// position: under a quorum fleet the reply vector may be a strict
/// subset of the original membership (aggregation is subset-aware — the
/// sums below simply run over whoever replied).
pub fn node_stats_round<F: SecureFabric>(
    fab: &mut F,
    fleet: &mut dyn Fleet,
    beta: &[f64],
    scale: f64,
) -> anyhow::Result<(Vec<EncVec>, Vec<EncVec>)> {
    let p = fleet.p();
    let f = fab.fmt().f;
    let replies = fleet.stats(beta, scale)?;
    let mut enc_g = Vec::with_capacity(replies.len());
    let mut enc_l = Vec::with_capacity(replies.len());
    for r in replies {
        let j = r.org;
        fab.ledger_mut().add_node(j, r.secs);
        match r.payload {
            NodePayload::Plain { values, loglik } => {
                enc_g.push(fab.node_encrypt_vec(j, &values));
                enc_l.push(fab.node_encrypt_vec(j, &[loglik]));
            }
            NodePayload::Enc(stat) => {
                // The node encrypted grad ‖ loglik itself; split them.
                // Under a negotiated packing layout the gradient rides
                // in ⌈p/k⌉ slot-packed ciphertexts; the loglik share is
                // always its own trailing unpacked ciphertext (it folds
                // on a different fan-in path).
                let grad_cts = match fab.packing() {
                    Some(codec) => codec.cts_needed(p),
                    None => p,
                };
                anyhow::ensure!(
                    stat.cts.len() == grad_cts + 1,
                    "node {j} stats reply has {} ciphertexts, expected {} + loglik",
                    stat.cts.len(),
                    grad_cts
                );
                anyhow::ensure!(
                    stat.scale == f,
                    "node {j} stats reply carries scale {}, session scale is {f}",
                    stat.scale
                );
                fab.ledger_mut().paillier_encs += stat.cts.len() as u64;
                let EncStat { scale, mut cts } = stat;
                let ll = cts.pop().expect("length checked above");
                enc_g.push(match fab.packing() {
                    Some(codec) => enc_vec_from_packed(scale, cts, codec.meta(p)),
                    None => enc_vec_from(scale, cts),
                });
                enc_l.push(enc_vec_from(scale, vec![ll]));
            }
        }
    }
    fab.ledger_mut().end_node_round();
    Ok((enc_g, enc_l))
}

/// One node matrix round (Gram or exact Hessian): each node's packed
/// triangle as ciphertexts (fabric-encrypted or node-encrypted).
/// `expect_len` is the packed-triangle length; node-encrypted replies
/// that do not match it (or the session scale) are session errors
/// naming the node. Attribution uses [`NodeReply::org`] — under a
/// quorum fleet the reply vector may be a subset of the membership.
pub fn node_matrix_round<F: SecureFabric>(
    fab: &mut F,
    replies: Vec<NodeReply>,
    expect_len: usize,
) -> anyhow::Result<Vec<EncVec>> {
    let f = fab.fmt().f;
    let mut enc = Vec::with_capacity(replies.len());
    for r in replies {
        let j = r.org;
        fab.ledger_mut().add_node(j, r.secs);
        match r.payload {
            NodePayload::Plain { values, .. } => enc.push(fab.node_encrypt_vec(j, &values)),
            NodePayload::Enc(stat) => {
                let want = match fab.packing() {
                    Some(codec) => codec.cts_needed(expect_len),
                    None => expect_len,
                };
                anyhow::ensure!(
                    stat.cts.len() == want,
                    "node {j} matrix reply has {} ciphertexts, expected {want}",
                    stat.cts.len()
                );
                anyhow::ensure!(
                    stat.scale == f,
                    "node {j} matrix reply carries scale {}, session scale is {f}",
                    stat.scale
                );
                fab.ledger_mut().paillier_encs += stat.cts.len() as u64;
                enc.push(match fab.packing() {
                    Some(codec) => enc_vec_from_packed(stat.scale, stat.cts, codec.meta(expect_len)),
                    None => enc_vec_from(stat.scale, stat.cts),
                });
            }
        }
    }
    fab.ledger_mut().end_node_round();
    Ok(enc)
}

/// Aggregate the per-node log-likelihood shares and apply the public
/// `−(λ/2)βᵀβ·scale` term (Eq. 9).
pub fn aggregate_loglik<F: SecureFabric>(
    fab: &mut F,
    enc_l: Vec<EncVec>,
    beta: &[f64],
    lambda: f64,
    scale: f64,
) -> anyhow::Result<EncVec> {
    let l = fab.aggregate(enc_l)?;
    let b2: f64 = beta.iter().map(|b| b * b).sum();
    fab.add_plain(&l, &[-0.5 * lambda * b2 * scale])
}

/// Aggregate per-node gradients and apply the public `−λβ·scale` term
/// (Eq. 4).
pub fn aggregate_gradient<F: SecureFabric>(
    fab: &mut F,
    enc_g: Vec<EncVec>,
    beta: &[f64],
    lambda: f64,
    scale: f64,
) -> anyhow::Result<EncVec> {
    let g = fab.aggregate(enc_g)?;
    let reg: Vec<f64> = beta.iter().map(|b| -lambda * b * scale).collect();
    fab.add_plain(&g, &reg)
}

/// Total time (compute + modeled network) from a fabric's ledger.
pub fn total_secs<F: SecureFabric>(fab: &F) -> f64 {
    fab.ledger().total_secs(fab.cost_model())
}

/// Final ledger for a [`RunReport`]: the fabric's ledger plus the wire
/// traffic the fleet itself measured (zero for in-process fleets, real
/// socket bytes for [`crate::net::fleet::RemoteFleet`]). Fleet traffic
/// goes to the dedicated `fleet_bytes_*` fields — the `bytes` counters
/// model the target deployment's ciphertext traffic, which with today's
/// plaintext-statistics fleet wire would otherwise be double-counted.
pub fn final_ledger<F: SecureFabric>(fab: &F, fleet: &dyn Fleet) -> CostLedger {
    let mut ledger = fab.ledger().clone();
    let net = fleet.net_stats();
    ledger.fleet_bytes_sent += net.bytes_sent;
    ledger.fleet_bytes_recv += net.bytes_recv;
    ledger.excluded_nodes += fleet.excluded_count();
    ledger.readmitted_nodes += fleet.readmitted_count();
    for (tag, flow) in fleet.tag_flows() {
        ledger.fleet_tag_flows.entry(tag).or_default().merge(&flow);
    }
    for (tag, flow) in fab.peer_tag_flows() {
        ledger.peer_tag_flows.entry(tag).or_default().merge(&flow);
    }
    ledger
}
