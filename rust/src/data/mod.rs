//! Dataset substrate: synthesis (the paper's §6.1 recipe), stand-ins for
//! the four real-world studies, horizontal partitioning across
//! organizations, standardization and CSV I/O.
//!
//! **Substitution note (DESIGN.md §7):** the paper's real datasets (Wine,
//! LendingClub Loans, Insurance, Mashable News) are not redistributable
//! here; we synthesize stand-ins with the *same dimensionality* from the
//! paper's own simulation recipe (random covariates, random coefficients,
//! Bernoulli responses). Secure-side cost depends only on `p` and the
//! iteration count, which the standardized synthesis controls.

use crate::linalg::Matrix;
use crate::testutil::TestRng;

/// A labeled logistic-regression dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable name (paper's dataset id).
    pub name: String,
    /// Covariates, n×p (standardized unless stated otherwise).
    pub x: Matrix,
    /// Binary responses, length n.
    pub y: Vec<f64>,
    /// True generating coefficients when synthetic (for diagnostics).
    pub beta_true: Option<Vec<f64>>,
}

impl Dataset {
    /// Samples.
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Features.
    pub fn p(&self) -> usize {
        self.x.cols
    }

    /// Standardize columns to zero mean / unit variance in place
    /// (standard practice for regression; required for fixed-point
    /// dynamic range — DESIGN.md §5).
    pub fn standardize(&mut self) {
        let (n, p) = (self.n(), self.p());
        for j in 0..p {
            let mut mean = 0.0;
            for i in 0..n {
                mean += self.x[(i, j)];
            }
            mean /= n as f64;
            let mut var = 0.0;
            for i in 0..n {
                var += (self.x[(i, j)] - mean).powi(2);
            }
            var /= n as f64;
            let sd = if var > 1e-12 { var.sqrt() } else { 1.0 };
            for i in 0..n {
                self.x[(i, j)] = (self.x[(i, j)] - mean) / sd;
            }
        }
    }

    /// Split horizontally (by rows) into `s` near-equal blocks — the
    /// paper's emulation of `s` data-contributing organizations.
    pub fn partition(&self, s: usize) -> Vec<Dataset> {
        assert!(s >= 1 && s <= self.n(), "1 ≤ orgs ≤ n");
        let n = self.n();
        let base = n / s;
        let extra = n % s;
        let mut out = Vec::with_capacity(s);
        let mut row = 0;
        for k in 0..s {
            let take = base + if k < extra { 1 } else { 0 };
            let mut x = Matrix::zeros(take, self.p());
            let mut y = Vec::with_capacity(take);
            for i in 0..take {
                for j in 0..self.p() {
                    x[(i, j)] = self.x[(row + i, j)];
                }
                y.push(self.y[row + i]);
            }
            row += take;
            out.push(Dataset {
                name: format!("{}#org{k}", self.name),
                x,
                y,
                beta_true: self.beta_true.clone(),
            });
        }
        out
    }

    /// Proportion of positive responses.
    pub fn positive_rate(&self) -> f64 {
        self.y.iter().sum::<f64>() / self.n() as f64
    }

    /// Write as CSV (`y,x1,…,xp` header) — for interop/debugging.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("y");
        for j in 0..self.p() {
            s.push_str(&format!(",x{j}"));
        }
        s.push('\n');
        for i in 0..self.n() {
            s.push_str(&format!("{}", self.y[i]));
            for j in 0..self.p() {
                s.push_str(&format!(",{}", self.x[(i, j)]));
            }
            s.push('\n');
        }
        s
    }

    /// Parse the CSV format produced by [`Dataset::to_csv`].
    pub fn from_csv(name: &str, text: &str) -> Option<Dataset> {
        let mut lines = text.lines();
        let header = lines.next()?;
        let p = header.split(',').count() - 1;
        let mut xdata = Vec::new();
        let mut y = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let mut fields = line.split(',');
            y.push(fields.next()?.trim().parse().ok()?);
            let mut cnt = 0;
            for f in fields {
                xdata.push(f.trim().parse().ok()?);
                cnt += 1;
            }
            if cnt != p {
                return None;
            }
        }
        let n = y.len();
        Some(Dataset {
            name: name.to_string(),
            x: Matrix::from_rows(n, p, xdata),
            y,
            beta_true: None,
        })
    }
}

/// Synthesize a dataset following the paper's §6.1 recipe: random
/// covariates `X`, random coefficients `β`, responses `y ~ Bernoulli(σ(Xβ))`.
///
/// The default linear-predictor variance follows `σ_z² = 3 + p/15`, which
/// reproduces the paper's Table 2 iteration profile (PrivLogit iteration
/// counts growing from ~15 at p=10 to ~200 at p=400 while Newton stays in
/// single digits). Use [`synthesize_with_signal`] to control it directly.
pub fn synthesize(name: &str, n: usize, p: usize, seed: u64) -> Dataset {
    synthesize_with_signal(name, n, p, seed, 3.0 + p as f64 / 15.0)
}

/// [`synthesize`] with an explicit linear-predictor variance `σ_z²`.
/// Larger signal ⇒ more extreme probabilities ⇒ smaller logistic curvature
/// ⇒ looser Böhning–Lindsay bound ⇒ more PrivLogit iterations — the knob
/// that matches each paper dataset's conditioning.
pub fn synthesize_with_signal(name: &str, n: usize, p: usize, seed: u64, sigma2: f64) -> Dataset {
    let mut rng = TestRng::new(seed);
    let mut x = Matrix::zeros(n, p);
    for v in x.as_mut_slice() {
        *v = rng.gaussian();
    }
    // β_j ~ U(−c, c) with c chosen so Var(xᵀβ) = σ_z² (Var U(−c,c) = c²/3).
    let c = (3.0 * sigma2 / p as f64).sqrt();
    let beta: Vec<f64> = (0..p).map(|_| rng.range_f64(-c, c)).collect();
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let z: f64 = x.row(i).iter().zip(&beta).map(|(a, b)| a * b).sum();
            let prob = 1.0 / (1.0 + (-z).exp());
            if rng.bernoulli(prob) {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let mut d = Dataset { name: name.to_string(), x, y, beta_true: Some(beta) };
    d.standardize();
    d
}

/// A named evaluation workload (dimensions as in the paper's §6.1).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Paper's dataset name.
    pub name: &'static str,
    /// Paper's sample count.
    pub paper_n: usize,
    /// Paper's feature count (drives all secure-side cost).
    pub p: usize,
    /// Row-scaled n used here (node-side plaintext work only).
    pub n: usize,
    /// Linear-predictor variance σ_z², calibrated per dataset so the
    /// plaintext iteration counts match the paper's Table 2 column.
    pub sigma2: f64,
    /// Paper's Table 2 iteration counts (Newton, PrivLogit) — the
    /// calibration target, reported alongside measurements.
    pub paper_iters: (usize, usize),
}

/// The paper's evaluation suite: four real-study stand-ins + the SimuX
/// series. `n` is row-scaled where the paper used millions of rows; `p`
/// is always exact (secure cost depends only on `p` — paper §6.1).
/// `sigma2` reproduces each dataset's conditioning (see Table 2).
pub const WORKLOADS: &[Workload] = &[
    Workload { name: "Wine", paper_n: 6_497, p: 12, n: 6_497, sigma2: 3.3, paper_iters: (5, 13) },
    Workload {
        name: "Loans",
        paper_n: 122_578,
        p: 33,
        n: 24_000,
        sigma2: 3.6,
        paper_iters: (6, 17),
    },
    Workload {
        name: "Insurance",
        paper_n: 9_882,
        p: 38,
        n: 9_882,
        sigma2: 12.0,
        paper_iters: (7, 59),
    },
    Workload { name: "News", paper_n: 39_082, p: 52, n: 16_000, sigma2: 3.0, paper_iters: (5, 13) },
    Workload {
        name: "SimuX10",
        paper_n: 50_000,
        p: 10,
        n: 20_000,
        sigma2: 4.6,
        paper_iters: (6, 20),
    },
    Workload {
        name: "SimuX12",
        paper_n: 1_000_000,
        p: 12,
        n: 20_000,
        sigma2: 5.0,
        paper_iters: (6, 22),
    },
    Workload {
        name: "SimuX50",
        paper_n: 1_000_000,
        p: 50,
        n: 16_000,
        sigma2: 7.0,
        paper_iters: (6, 32),
    },
    Workload {
        name: "SimuX100",
        paper_n: 3_000_000,
        p: 100,
        n: 12_000,
        sigma2: 12.0,
        paper_iters: (7, 59),
    },
    Workload {
        name: "SimuX150",
        paper_n: 4_000_000,
        p: 150,
        n: 12_000,
        sigma2: 16.0,
        paper_iters: (7, 83),
    },
    Workload {
        name: "SimuX200",
        paper_n: 5_000_000,
        p: 200,
        n: 10_000,
        sigma2: 20.0,
        paper_iters: (8, 105),
    },
    Workload {
        name: "SimuX400",
        paper_n: 50_000_000,
        p: 400,
        n: 8_000,
        sigma2: 33.0,
        paper_iters: (8, 206),
    },
];

/// Look up a workload by (case-insensitive) name.
pub fn workload(name: &str) -> Option<Workload> {
    WORKLOADS.iter().find(|w| w.name.eq_ignore_ascii_case(name)).copied()
}

/// Materialize a workload (deterministic per name).
pub fn load_workload(w: Workload) -> Dataset {
    let seed =
        w.name.bytes().fold(0xBEEFu64, |acc, b| acc.wrapping_mul(131).wrapping_add(b as u64));
    synthesize_with_signal(w.name, w.n, w.p, seed, w.sigma2)
}

/// Resolve a CLI dataset name: a paper workload (`Wine`, `SimuX100`, …)
/// or an inline synthetic spec `synth:n=1200,p=4,seed=7` (any key may be
/// omitted; defaults n=1000, p=4, seed=42). The spec form is
/// deterministic per string, so node servers and the center materialize
/// identical shards from the same `--dataset` argument.
pub fn dataset_by_name(name: &str) -> Option<Dataset> {
    if let Some(spec) = name.strip_prefix("synth:") {
        let (mut n, mut p, mut seed) = (1000usize, 4usize, 42u64);
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part.split_once('=')?;
            match k.trim() {
                "n" => n = v.trim().parse().ok()?,
                "p" => p = v.trim().parse().ok()?,
                "seed" => seed = v.trim().parse().ok()?,
                _ => return None,
            }
        }
        if n == 0 || p == 0 {
            return None;
        }
        return Some(synthesize(name, n, p, seed));
    }
    workload(name).map(load_workload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesize_shapes_and_balance() {
        let d = synthesize("t", 2000, 8, 1);
        assert_eq!((d.n(), d.p()), (2000, 8));
        let rate = d.positive_rate();
        assert!(rate > 0.2 && rate < 0.8, "class balance {rate}");
        assert!(d.y.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn standardized_columns() {
        let d = synthesize("t", 5000, 5, 2);
        for j in 0..5 {
            let mut mean = 0.0;
            let mut var = 0.0;
            for i in 0..d.n() {
                mean += d.x[(i, j)];
            }
            mean /= d.n() as f64;
            for i in 0..d.n() {
                var += (d.x[(i, j)] - mean).powi(2);
            }
            var /= d.n() as f64;
            assert!(mean.abs() < 1e-10, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-8, "col {j} var {var}");
        }
    }

    #[test]
    fn partition_covers_all_rows() {
        let d = synthesize("t", 103, 4, 3);
        for s in [1, 2, 5, 20] {
            let parts = d.partition(s);
            assert_eq!(parts.len(), s);
            let total: usize = parts.iter().map(|p| p.n()).sum();
            assert_eq!(total, 103, "s={s}");
            // near-equal
            let sizes: Vec<usize> = parts.iter().map(|p| p.n()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "s={s} sizes {sizes:?}");
            // first block starts with the dataset's first row
            assert_eq!(parts[0].x[(0, 0)], d.x[(0, 0)]);
        }
    }

    #[test]
    fn csv_roundtrip() {
        let d = synthesize("t", 20, 3, 4);
        let csv = d.to_csv();
        let back = Dataset::from_csv("t", &csv).unwrap();
        assert_eq!(back.n(), d.n());
        assert_eq!(back.p(), d.p());
        assert!((back.x[(7, 2)] - d.x[(7, 2)]).abs() < 1e-9);
        assert_eq!(back.y, d.y);
    }

    #[test]
    fn workloads_table_matches_paper_dims() {
        assert_eq!(workload("wine").unwrap().p, 12);
        assert_eq!(workload("Loans").unwrap().p, 33);
        assert_eq!(workload("Insurance").unwrap().p, 38);
        assert_eq!(workload("News").unwrap().p, 52);
        assert_eq!(workload("SimuX400").unwrap().p, 400);
        assert!(workload("nope").is_none());
    }

    /// `synth:` inline specs resolve deterministically; workload names
    /// still resolve through the same entry point; junk is rejected.
    #[test]
    fn dataset_by_name_specs() {
        let d = dataset_by_name("synth:n=300,p=3,seed=9").unwrap();
        assert_eq!((d.n(), d.p()), (300, 3));
        let again = dataset_by_name("synth:n=300,p=3,seed=9").unwrap();
        assert_eq!(d.x.as_slice(), again.x.as_slice(), "deterministic per spec");
        let defaults = dataset_by_name("synth:").unwrap();
        assert_eq!((defaults.n(), defaults.p()), (1000, 4));
        assert_eq!(dataset_by_name("Wine").unwrap().p(), 12);
        assert!(dataset_by_name("synth:p=0").is_none());
        assert!(dataset_by_name("synth:bogus=1").is_none());
        assert!(dataset_by_name("nope").is_none());
    }

    #[test]
    fn load_workload_deterministic() {
        let w = workload("Wine").unwrap();
        let a = load_workload(w);
        let b = load_workload(w);
        assert_eq!(a.y, b.y);
        assert_eq!(a.x, b.x);
    }
}
