//! Dependency-free observability: a leveled stderr logger, scoped trace
//! spans, and a per-process JSONL trace exporter.
//!
//! The paper's whole argument is a *time breakdown* — PrivLogit wins by
//! moving cost out of the per-iteration critical path — so the repo
//! needs per-phase, per-process measurement, not just one end-of-run
//! [`crate::mpc::CostLedger`]. This module is the cross-cutting layer
//! every subsystem (fabric, fleet, node servers, center-b, thread pool,
//! protocols) threads its spans through.
//!
//! * **Logging** — `PRIVLOGIT_LOG=warn|info|debug` (default `warn`)
//!   gates [`warn`]/[`info`]/[`debug`] lines on stderr, each prefixed
//!   with the process label ([`set_proc`]).
//! * **Tracing** — `PRIVLOGIT_TRACE=<path>` turns on a buffered JSONL
//!   writer (schema `privlogit-trace/v1`): one header line, then one
//!   object per finished [`Span`]. When tracing is off a span costs a
//!   single relaxed atomic load — no clock reads, no allocation.
//!   Buffered lines are flushed at a size threshold and at session
//!   boundaries ([`flush`]) so traces survive a killed process.
//! * **Session identity** — [`session_id`] hashes the Paillier modulus
//!   bytes, which every process in a deployment already holds (center-a
//!   generates the key, nodes receive it via `SetKey`, center-b via the
//!   peer `SetKey`), into a stable 64-bit id. Per-process trace files
//!   therefore join on (session, round, tag) with **no wire change**.
//! * **Rounds** — each instrumented endpoint numbers the occurrences of
//!   a wire tag within a session itself; both ends of a wire count the
//!   same occurrences in the same order, so the indices agree and the
//!   `privlogit trace` subcommand can merge per-process files into one
//!   cross-process timeline.
//!
//! Tracing *reads* — it never draws randomness, takes locks on the hot
//! path while disabled, or reorders work — so the byte-identical
//! parallelism guarantee of `runtime::pool` is preserved (proved in
//! `rust/tests/perf_parity.rs` with tracing force-enabled).

pub mod json;
pub mod timeline;

use std::fs::File;
use std::io::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use json::{JsonObj, JsonValue};

/// Trace schema identifier written in every file's header line.
pub const TRACE_SCHEMA: &str = "privlogit-trace/v1";

// ---------------------------------------------------------------------
// process label
// ---------------------------------------------------------------------

static PROC: OnceLock<String> = OnceLock::new();

/// Set this process's role label (`center-a`, `center-b`, `node:2`, …)
/// for log lines and the trace header. First caller wins; call once,
/// early, from the CLI subcommand dispatch.
pub fn set_proc(label: &str) {
    let _ = PROC.set(label.to_string());
}

/// The process label (default `privlogit`).
pub fn proc_label() -> &'static str {
    PROC.get_or_init(|| "privlogit".to_string())
}

// ---------------------------------------------------------------------
// leveled stderr logger
// ---------------------------------------------------------------------

/// Log verbosity, selected by `PRIVLOGIT_LOG`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unexpected-but-handled conditions (default).
    Warn = 1,
    /// Session lifecycle events.
    Info = 2,
    /// Per-round detail.
    Debug = 3,
}

static LOG_LEVEL: AtomicU8 = AtomicU8::new(0); // 0 = not yet parsed

fn log_level() -> u8 {
    match LOG_LEVEL.load(Ordering::Relaxed) {
        0 => {
            let lv = match std::env::var("PRIVLOGIT_LOG").ok().as_deref() {
                Some("debug") => 3,
                Some("info") => 2,
                _ => 1,
            };
            LOG_LEVEL.store(lv, Ordering::Relaxed);
            lv
        }
        lv => lv,
    }
}

/// Whether `level` lines are currently emitted.
pub fn log_enabled(level: Level) -> bool {
    log_level() >= level as u8
}

fn log_line(level: Level, name: &str, args: std::fmt::Arguments<'_>) {
    if log_enabled(level) {
        eprintln!("[{} {}] {}", proc_label(), name, args);
    }
}

/// Log at warn level: `obs::warn(format_args!("…"))`.
pub fn warn(args: std::fmt::Arguments<'_>) {
    log_line(Level::Warn, "warn", args);
}

/// Log at info level.
pub fn info(args: std::fmt::Arguments<'_>) {
    log_line(Level::Info, "info", args);
}

/// Log at debug level.
pub fn debug(args: std::fmt::Arguments<'_>) {
    log_line(Level::Debug, "debug", args);
}

// ---------------------------------------------------------------------
// session ids and per-tag wire accounting
// ---------------------------------------------------------------------

/// Hash key material (the Paillier modulus bytes) into the stable
/// 64-bit session id all processes of one deployment agree on. FNV-1a:
/// deterministic, dependency-free, and collision-safe at the scale of
/// "a handful of concurrent experiment sessions".
pub fn session_id(modulus_bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in modulus_bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Render a session id the way traces carry it (hex, or `"-"` for the
/// pre-key phase).
pub fn session_str(session: u64) -> String {
    if session == 0 {
        "-".to_string()
    } else {
        format!("{session:016x}")
    }
}

/// Byte/frame counters for one wire tag in both directions — the
/// per-tag refinement of the aggregate sent/recv counters kept by
/// `ChannelStats` and `RemoteFleet`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TagFlow {
    /// Frames sent carrying this tag.
    pub sent_frames: u64,
    /// Bytes sent (payload + frame overhead) under this tag.
    pub sent_bytes: u64,
    /// Frames received carrying this tag.
    pub recv_frames: u64,
    /// Bytes received under this tag.
    pub recv_bytes: u64,
}

impl TagFlow {
    /// Fold another flow into this one (merging per-connection maps).
    pub fn merge(&mut self, other: &TagFlow) {
        self.sent_frames += other.sent_frames;
        self.sent_bytes += other.sent_bytes;
        self.recv_frames += other.recv_frames;
        self.recv_bytes += other.recv_bytes;
    }
}

// ---------------------------------------------------------------------
// trace sink
// ---------------------------------------------------------------------

const FLUSH_LINES: usize = 64;

struct Sink {
    file: File,
    buf: Vec<String>,
}

impl Sink {
    fn push(&mut self, line: String) {
        self.buf.push(line);
        if self.buf.len() >= FLUSH_LINES {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let mut chunk = String::new();
        for line in self.buf.drain(..) {
            chunk.push_str(&line);
            chunk.push('\n');
        }
        let _ = self.file.write_all(chunk.as_bytes());
        let _ = self.file.flush();
    }
}

impl Drop for Sink {
    fn drop(&mut self) {
        self.flush();
    }
}

// 0 = not yet initialized, 1 = disabled, 2 = enabled
static TRACE_STATE: AtomicU8 = AtomicU8::new(0);
static SINK: OnceLock<Mutex<Sink>> = OnceLock::new();

/// Whether tracing is on. The steady-state cost of instrumentation when
/// tracing is disabled is exactly this one relaxed atomic load.
pub fn trace_enabled() -> bool {
    match TRACE_STATE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => init_trace_from_env(),
    }
}

fn init_trace_from_env() -> bool {
    match std::env::var("PRIVLOGIT_TRACE") {
        Ok(path) if !path.is_empty() => install_trace(&path),
        _ => {
            TRACE_STATE.store(1, Ordering::Relaxed);
            false
        }
    }
}

/// Open (create/truncate) `path` as this process's trace file and turn
/// tracing on. Normally driven by `PRIVLOGIT_TRACE`; tests call it
/// directly to force-enable tracing in-process (environment-variable
/// initialization races across parallel tests in one binary).
pub fn install_trace(path: &str) -> bool {
    let Ok(file) = File::create(path) else {
        warn(format_args!("cannot open trace file {path:?}; tracing disabled"));
        TRACE_STATE.store(1, Ordering::Relaxed);
        return false;
    };
    let header = JsonObj::new()
        .str("schema", TRACE_SCHEMA)
        .str("proc", proc_label())
        .u64("pid", std::process::id() as u64)
        .build()
        .render();
    let mut sink = Sink { file, buf: Vec::new() };
    sink.push(header);
    if SINK.set(Mutex::new(sink)).is_ok() {
        TRACE_STATE.store(2, Ordering::Relaxed);
        true
    } else {
        // a second install keeps the first sink
        TRACE_STATE.load(Ordering::Relaxed) == 2
    }
}

/// Flush buffered trace lines to disk. Called at session boundaries
/// (end of a node/center-b session, end of a protocol run) so traces
/// survive a process that is later killed rather than exiting cleanly.
pub fn flush() {
    if TRACE_STATE.load(Ordering::Relaxed) == 2 {
        if let Some(sink) = SINK.get() {
            if let Ok(mut s) = sink.lock() {
                s.flush();
            }
        }
    }
}

fn emit_line(line: String) {
    if let Some(sink) = SINK.get() {
        if let Ok(mut s) = sink.lock() {
            s.push(line);
        }
    }
}

// ---------------------------------------------------------------------
// spans
// ---------------------------------------------------------------------

/// A scoped trace timer. Create with [`span`], attach structured fields
/// with the builder methods, and the event is emitted when the span is
/// dropped (or explicitly [`Span::done`]). When tracing is disabled the
/// span is inert: no clock is read and no field is recorded.
pub struct Span {
    state: Option<SpanState>,
}

struct SpanState {
    name: &'static str,
    wall_start: SystemTime,
    t0: Instant,
    fields: Vec<(&'static str, JsonValue)>,
}

/// Open a span named per the taxonomy in docs/ARCHITECTURE.md
/// §Observability (`fabric.*`, `fleet.*`, `node.req`, `peer.req`,
/// `proto.iter`, `pool.par_map`).
pub fn span(name: &'static str) -> Span {
    if !trace_enabled() {
        return Span { state: None };
    }
    Span {
        state: Some(SpanState {
            name,
            wall_start: SystemTime::now(),
            t0: Instant::now(),
            fields: Vec::new(),
        }),
    }
}

impl Span {
    /// Whether this span will emit an event (tracing on).
    pub fn active(&self) -> bool {
        self.state.is_some()
    }

    fn put(&mut self, key: &'static str, v: JsonValue) {
        if let Some(s) = self.state.as_mut() {
            s.fields.push((key, v));
        }
    }

    /// Attach the session id (hex in the event; 0 renders as `"-"`).
    pub fn session(mut self, session: u64) -> Span {
        if self.active() {
            self.put("session", JsonValue::Str(session_str(session)));
        }
        self
    }

    /// Attach the per-session round index for the joined wire tag.
    pub fn round(mut self, round: u64) -> Span {
        self.put("round", JsonValue::Num(round as f64));
        self
    }

    /// Attach a wire tag (numeric, plus its symbolic name).
    pub fn tag(mut self, tag: u8) -> Span {
        if self.active() {
            self.put("tag", JsonValue::Num(tag as f64));
            self.put("tag_name", JsonValue::Str(crate::net::wire::tag_name(tag).to_string()));
        }
        self
    }

    /// Attach an arbitrary integer field.
    pub fn u64(mut self, key: &'static str, v: u64) -> Span {
        self.put(key, JsonValue::Num(v as f64));
        self
    }

    /// Attach an arbitrary string field.
    pub fn str(mut self, key: &'static str, v: &str) -> Span {
        if self.active() {
            self.put(key, JsonValue::Str(v.to_string()));
        }
        self
    }

    /// Record an integer field after the span was opened (byte deltas,
    /// op counts known only at the end of the section).
    pub fn record_u64(&mut self, key: &'static str, v: u64) {
        self.put(key, JsonValue::Num(v as f64));
    }

    /// Record the session id after the span was opened (a `SetKey`
    /// handler learns the session mid-request).
    pub fn record_session(&mut self, session: u64) {
        if self.active() {
            self.put("session", JsonValue::Str(session_str(session)));
        }
    }

    /// Record a float field after the span was opened.
    pub fn record_f64(&mut self, key: &'static str, v: f64) {
        self.put(key, JsonValue::Num(v));
    }

    /// Record a string field after the span was opened (a round's
    /// outcome classification is known only once it resolves).
    pub fn record_str(&mut self, key: &'static str, v: &str) {
        if self.active() {
            self.put(key, JsonValue::Str(v.to_string()));
        }
    }

    /// Finish the span now (equivalent to dropping it).
    pub fn done(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(s) = self.state.take() else { return };
        let secs = s.t0.elapsed().as_secs_f64();
        let ts_us = s
            .wall_start
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        let mut obj = JsonObj::new().u64("ts_us", ts_us).str("span", s.name);
        for (k, v) in s.fields {
            obj = obj.push(k, v);
        }
        emit_line(obj.f64("secs", secs).build().render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_ids_are_stable_and_distinct() {
        let a = session_id(&[1, 2, 3]);
        assert_eq!(a, session_id(&[1, 2, 3]));
        assert_ne!(a, session_id(&[1, 2, 4]));
        assert_ne!(a, 0);
        assert_eq!(session_str(0), "-");
        assert_eq!(session_str(a).len(), 16);
    }

    #[test]
    fn disabled_spans_are_inert() {
        // Tests run without PRIVLOGIT_TRACE (and before any test-hook
        // install in this process): spans must be no-ops, not errors.
        if trace_enabled() {
            return; // another test in this binary force-enabled tracing
        }
        let mut sp = span("test.noop").session(7).round(1).u64("x", 2);
        assert!(!sp.active());
        sp.record_u64("bytes", 10);
        sp.done();
    }

    #[test]
    fn tag_flow_merges() {
        let mut a =
            TagFlow { sent_frames: 1, sent_bytes: 10, recv_frames: 2, recv_bytes: 20 };
        a.merge(&TagFlow { sent_frames: 3, sent_bytes: 30, recv_frames: 4, recv_bytes: 40 });
        assert_eq!(
            a,
            TagFlow { sent_frames: 4, sent_bytes: 40, recv_frames: 6, recv_bytes: 60 }
        );
    }

    #[test]
    fn log_levels_order() {
        assert!(Level::Warn < Level::Info && Level::Info < Level::Debug);
        // default level is warn: warn enabled, debug not (unless the
        // environment overrides — accept either but exercise the path)
        let _ = log_enabled(Level::Debug);
        assert!(log_enabled(Level::Warn) || std::env::var("PRIVLOGIT_LOG").is_ok());
    }
}
