//! Cross-process trace merging: the library behind `privlogit trace`.
//!
//! Each process in a deployment writes its own JSONL trace file (schema
//! [`TRACE_SCHEMA`]). This module parses and validates those files,
//! merges their events into one time-ordered timeline, and joins the
//! two ends of every wire on **(session, tag, round)** — the identity
//! that both endpoints derive independently (session from the Paillier
//! modulus hash, round from per-tag occurrence counting), so no clock
//! synchronization or wire change is needed.

use std::collections::BTreeMap;

use super::json::{self, JsonObj, JsonValue};
use super::TRACE_SCHEMA;
use crate::net::wire::tag_name;

/// Schema identifier of the merged-timeline JSON document.
pub const TIMELINE_SCHEMA: &str = "privlogit-timeline/v1";

/// Every span name a production code path may emit — the timeline
/// parser's closed vocabulary. `privlogit audit` (rule `span-schema`)
/// checks each `span("…")` call site against this set and against the
/// docs/ARCHITECTURE.md taxonomy, so a new span name must land in all
/// three places in one commit.
pub const KNOWN_SPANS: &[&str] = &[
    "proto.setup",
    "proto.iter",
    "fleet.round",
    "fleet.rpc",
    "fleet.readmit",
    "node.req",
    "peer.req",
    "fabric.setup",
    "fabric.gc_exec",
    "fabric.aggregate",
    "fabric.to_shares",
    "fabric.reveal",
    "pool.par_map",
];

/// One finished span, as read back from a per-process trace file.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Role label of the emitting process (from the file header).
    pub proc: String,
    /// Wall-clock span start, microseconds since the epoch.
    pub ts_us: u64,
    /// Span name (`fabric.gc_exec`, `fleet.round`, `node.req`, …).
    pub span: String,
    /// Session id (16 hex chars), or `"-"` before key establishment.
    pub session: String,
    /// Per-session occurrence index of this span's wire tag.
    pub round: Option<u64>,
    /// Wire tag, for spans that correspond to one wire exchange.
    pub tag: Option<u8>,
    /// Span duration in seconds.
    pub secs: f64,
    /// Bytes sent within the span (0 when the span records none).
    pub bytes_sent: u64,
    /// Bytes received within the span.
    pub bytes_recv: u64,
    /// RPC outcome classification (`fleet.rpc` spans: `ok` / `timeout`
    /// / `error`); absent on spans that record none.
    pub outcome: Option<String>,
    /// Peer node address (`fleet.rpc` spans); absent elsewhere.
    pub node: Option<String>,
}

/// A parsed per-process trace file.
#[derive(Clone, Debug)]
pub struct TraceFile {
    /// Role label from the header (`center-a`, `node:0`, …).
    pub proc: String,
    /// Emitting process id.
    pub pid: u64,
    /// Events in emission order.
    pub events: Vec<TraceEvent>,
}

fn req_str(v: &JsonValue, key: &str, at: &str) -> Result<String, String> {
    v.get(key)
        .and_then(|x| x.as_str())
        .map(str::to_string)
        .ok_or_else(|| format!("{at}: missing string field {key:?}"))
}

fn req_u64(v: &JsonValue, key: &str, at: &str) -> Result<u64, String> {
    v.get(key).and_then(|x| x.as_u64()).ok_or_else(|| format!("{at}: missing integer {key:?}"))
}

/// Parse and validate one trace file's text. Rejects a missing or
/// mismatched header schema and any event lacking the required
/// `ts_us` / `span` / `secs` fields, naming the offending line.
pub fn parse_trace(text: &str) -> Result<TraceFile, String> {
    let mut lines =
        text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty()).map(|(i, l)| (i + 1, l));
    let (_, header) = lines.next().ok_or("empty trace file")?;
    let h = json::parse(header).map_err(|e| format!("header: {e}"))?;
    let schema = req_str(&h, "schema", "header")?;
    if schema != TRACE_SCHEMA {
        return Err(format!("unsupported trace schema {schema:?} (expected {TRACE_SCHEMA:?})"));
    }
    let proc = req_str(&h, "proc", "header")?;
    let pid = req_u64(&h, "pid", "header")?;
    let mut events = Vec::new();
    for (lineno, line) in lines {
        let at = format!("line {lineno}");
        let v = json::parse(line).map_err(|e| format!("{at}: {e}"))?;
        events.push(TraceEvent {
            proc: proc.clone(),
            ts_us: req_u64(&v, "ts_us", &at)?,
            span: req_str(&v, "span", &at)?,
            secs: v
                .get("secs")
                .and_then(|x| x.as_f64())
                .ok_or_else(|| format!("{at}: missing number \"secs\""))?,
            session: v.get("session").and_then(|x| x.as_str()).unwrap_or("-").to_string(),
            round: v.get("round").and_then(|x| x.as_u64()),
            tag: v.get("tag").and_then(|x| x.as_u64()).map(|t| t as u8),
            bytes_sent: v.get("bytes_sent").and_then(|x| x.as_u64()).unwrap_or(0),
            bytes_recv: v.get("bytes_recv").and_then(|x| x.as_u64()).unwrap_or(0),
            outcome: v.get("outcome").and_then(|x| x.as_str()).map(str::to_string),
            node: v.get("node").and_then(|x| x.as_str()).map(str::to_string),
        });
    }
    Ok(TraceFile { proc, pid, events })
}

/// Aggregate view of one (process, span-name) phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanRollup {
    /// Number of spans.
    pub count: u64,
    /// Summed span durations.
    pub secs: f64,
    /// Summed bytes sent.
    pub bytes_sent: u64,
    /// Summed bytes received.
    pub bytes_recv: u64,
}

impl SpanRollup {
    fn add(&mut self, e: &TraceEvent) {
        self.count += 1;
        self.secs += e.secs;
        self.bytes_sent += e.bytes_sent;
        self.bytes_recv += e.bytes_recv;
    }
}

/// The merged cross-process timeline.
#[derive(Clone, Debug)]
pub struct Timeline {
    /// All events from all processes, ordered by wall-clock start
    /// (ties keep per-process emission order).
    pub events: Vec<TraceEvent>,
    /// Distinct process labels, in first-file order.
    pub procs: Vec<String>,
}

impl Timeline {
    /// Merge parsed trace files into one time-ordered event stream.
    pub fn merge(files: Vec<TraceFile>) -> Timeline {
        let mut procs = Vec::new();
        let mut events = Vec::new();
        for f in files {
            if !procs.contains(&f.proc) {
                procs.push(f.proc.clone());
            }
            events.extend(f.events);
        }
        events.sort_by_key(|e| e.ts_us); // stable: ties keep file order
        Timeline { events, procs }
    }

    /// Per-phase rollup, keyed by (process, span name).
    pub fn per_phase(&self) -> BTreeMap<(String, String), SpanRollup> {
        let mut out: BTreeMap<(String, String), SpanRollup> = BTreeMap::new();
        for e in &self.events {
            out.entry((e.proc.clone(), e.span.clone())).or_default().add(e);
        }
        out
    }

    /// The cross-process join: events grouped by (session, tag, round).
    /// Each group holds one event per end of one wire exchange — e.g. a
    /// `fleet.rpc` on center-a and the matching `node.req` on the node.
    pub fn per_round(&self) -> BTreeMap<(String, u8, u64), Vec<&TraceEvent>> {
        let mut out: BTreeMap<(String, u8, u64), Vec<&TraceEvent>> = BTreeMap::new();
        for e in &self.events {
            if let (Some(tag), Some(round)) = (e.tag, e.round) {
                out.entry((e.session.clone(), tag, round)).or_default().push(e);
            }
        }
        out
    }

    /// Render the human-readable merged timeline: per-phase rollups,
    /// then the per-tag cross-process wire summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "── merged timeline: {} processes, {} events ──\n  procs: {}\n",
            self.procs.len(),
            self.events.len(),
            self.procs.join(" ")
        ));
        s.push_str("  per-phase rollup:\n");
        s.push_str(&format!(
            "    {:<12}{:<18}{:>8}{:>10}{:>12}{:>12}\n",
            "proc", "span", "count", "secs", "sent MiB", "recv MiB"
        ));
        for ((proc, span), r) in self.per_phase() {
            s.push_str(&format!(
                "    {:<12}{:<18}{:>8}{:>10.3}{:>12.3}{:>12.3}\n",
                proc,
                span,
                r.count,
                r.secs,
                r.bytes_sent as f64 / (1024.0 * 1024.0),
                r.bytes_recv as f64 / (1024.0 * 1024.0),
            ));
        }
        // Per (session, tag): how many rounds, which processes saw them,
        // and the summed span time per process.
        let mut wire: BTreeMap<(String, u8), (u64, BTreeMap<String, SpanRollup>)> =
            BTreeMap::new();
        for ((session, tag, _round), events) in self.per_round() {
            let entry = wire.entry((session, tag)).or_default();
            entry.0 += 1;
            for e in events {
                entry.1.entry(e.proc.clone()).or_default().add(e);
            }
        }
        if !wire.is_empty() {
            s.push_str("  cross-process wire rounds:\n");
            s.push_str(&format!(
                "    {:<18}{:<6}{:<14}{:>7}  per-proc secs\n",
                "session", "tag", "name", "rounds"
            ));
            for ((session, tag), (rounds, procs)) in wire {
                let per_proc: Vec<String> = procs
                    .iter()
                    .map(|(p, r)| format!("{p} {:.3}s/{} ev", r.secs, r.count))
                    .collect();
                s.push_str(&format!(
                    "    {:<18}{:#04x}  {:<14}{:>7}  {}\n",
                    session,
                    tag,
                    tag_name(tag),
                    rounds,
                    per_proc.join("  ")
                ));
            }
        }
        s
    }

    /// Render the merged timeline as JSON (schema [`TIMELINE_SCHEMA`]):
    /// the full event stream plus both rollups.
    pub fn render_json(&self) -> String {
        let events = JsonValue::Arr(
            self.events
                .iter()
                .map(|e| {
                    let mut o = JsonObj::new()
                        .str("proc", &e.proc)
                        .u64("ts_us", e.ts_us)
                        .str("span", &e.span)
                        .str("session", &e.session);
                    if let Some(round) = e.round {
                        o = o.u64("round", round);
                    }
                    if let Some(tag) = e.tag {
                        o = o.u64("tag", tag as u64).str("tag_name", tag_name(tag));
                    }
                    if let Some(outcome) = &e.outcome {
                        o = o.str("outcome", outcome);
                    }
                    if let Some(node) = &e.node {
                        o = o.str("node", node);
                    }
                    o.f64("secs", e.secs)
                        .u64("bytes_sent", e.bytes_sent)
                        .u64("bytes_recv", e.bytes_recv)
                        .build()
                })
                .collect(),
        );
        let phases = JsonValue::Arr(
            self.per_phase()
                .into_iter()
                .map(|((proc, span), r)| {
                    JsonObj::new()
                        .str("proc", &proc)
                        .str("span", &span)
                        .u64("count", r.count)
                        .f64("secs", r.secs)
                        .u64("bytes_sent", r.bytes_sent)
                        .u64("bytes_recv", r.bytes_recv)
                        .build()
                })
                .collect(),
        );
        let rounds = JsonValue::Arr(
            self.per_round()
                .into_iter()
                .map(|((session, tag, round), events)| {
                    let ends = JsonValue::Arr(
                        events
                            .iter()
                            .map(|e| {
                                JsonObj::new()
                                    .str("proc", &e.proc)
                                    .str("span", &e.span)
                                    .f64("secs", e.secs)
                                    .build()
                            })
                            .collect(),
                    );
                    JsonObj::new()
                        .str("session", &session)
                        .u64("tag", tag as u64)
                        .str("tag_name", tag_name(tag))
                        .u64("round", round)
                        .push("ends", ends)
                        .build()
                })
                .collect(),
        );
        let procs =
            JsonValue::Arr(self.procs.iter().map(|p| JsonValue::Str(p.clone())).collect());
        JsonObj::new()
            .str("schema", TIMELINE_SCHEMA)
            .push("procs", procs)
            .push("events", events)
            .push("phases", phases)
            .push("rounds", rounds)
            .build()
            .render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file_a() -> String {
        [
            r#"{"schema":"privlogit-trace/v1","proc":"center-a","pid":10}"#,
            r#"{"ts_us":100,"span":"fabric.setup","session":"00000000000000aa","secs":1.5}"#,
            concat!(
                r#"{"ts_us":300,"span":"fleet.round","session":"00000000000000aa","#,
                r#""round":0,"tag":8,"tag_name":"StepReq","bytes_sent":64,"#,
                r#""bytes_recv":128,"secs":0.2}"#
            ),
        ]
        .join("\n")
    }

    fn file_b() -> String {
        [
            r#"{"schema":"privlogit-trace/v1","proc":"node:0","pid":11}"#,
            concat!(
                r#"{"ts_us":200,"span":"node.req","session":"00000000000000aa","#,
                r#""round":0,"tag":8,"tag_name":"StepReq","secs":0.1}"#
            ),
        ]
        .join("\n")
    }

    #[test]
    fn parses_and_merges_two_processes() {
        let a = parse_trace(&file_a()).unwrap();
        let b = parse_trace(&file_b()).unwrap();
        assert_eq!((a.proc.as_str(), a.pid, a.events.len()), ("center-a", 10, 2));
        assert_eq!(b.events.len(), 1);
        let t = Timeline::merge(vec![a, b]);
        assert_eq!(t.procs, vec!["center-a", "node:0"]);
        // time-ordered across processes
        let ts: Vec<u64> = t.events.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![100, 200, 300]);
        let phases = t.per_phase();
        let round = &phases[&("center-a".into(), "fleet.round".into())];
        assert_eq!((round.count, round.bytes_sent, round.bytes_recv), (1, 64, 128));
        // the wire join pairs both ends of round 0 of StepReq
        let rounds = t.per_round();
        let ends = &rounds[&("00000000000000aa".into(), 8u8, 0u64)];
        assert_eq!(ends.len(), 2);
        assert!(ends.iter().any(|e| e.proc == "center-a"));
        assert!(ends.iter().any(|e| e.proc == "node:0"));
    }

    #[test]
    fn parses_outcome_and_node_fields() {
        let text = [
            r#"{"schema":"privlogit-trace/v1","proc":"center-a","pid":10}"#,
            concat!(
                r#"{"ts_us":1,"span":"fleet.rpc","session":"-","round":0,"tag":3,"#,
                r#""node":"127.0.0.1:9401","outcome":"timeout","secs":2.0}"#
            ),
        ]
        .join("\n");
        let f = parse_trace(&text).unwrap();
        assert_eq!(f.events[0].outcome.as_deref(), Some("timeout"));
        assert_eq!(f.events[0].node.as_deref(), Some("127.0.0.1:9401"));
        let t = Timeline::merge(vec![f]);
        let doc = json::parse(&t.render_json()).unwrap();
        let ev = &doc.get("events").unwrap().as_arr().unwrap()[0];
        assert_eq!(ev.get("outcome").unwrap().as_str(), Some("timeout"));
        assert_eq!(ev.get("node").unwrap().as_str(), Some("127.0.0.1:9401"));
        // Events without the optional fields omit them entirely.
        let doc2 = json::parse(
            &Timeline::merge(vec![parse_trace(&file_a()).unwrap()]).render_json(),
        )
        .unwrap();
        assert!(doc2.get("events").unwrap().as_arr().unwrap()[0].get("outcome").is_none());
    }

    #[test]
    fn rejects_malformed_traces() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace(r#"{"schema":"other/v9","proc":"x","pid":1}"#).is_err());
        let missing_secs = [
            r#"{"schema":"privlogit-trace/v1","proc":"x","pid":1}"#,
            r#"{"ts_us":1,"span":"a"}"#,
        ]
        .join("\n");
        let err = parse_trace(&missing_secs).unwrap_err();
        assert!(err.contains("secs"), "{err}");
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn timeline_json_round_trips() {
        let t = Timeline::merge(vec![
            parse_trace(&file_a()).unwrap(),
            parse_trace(&file_b()).unwrap(),
        ]);
        let doc = json::parse(&t.render_json()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(TIMELINE_SCHEMA));
        assert_eq!(doc.get("events").unwrap().as_arr().unwrap().len(), 3);
        let rounds = doc.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1);
        assert_eq!(rounds[0].get("tag_name").unwrap().as_str(), Some("StepReq"));
        assert_eq!(rounds[0].get("ends").unwrap().as_arr().unwrap().len(), 2);
        let human = t.render();
        assert!(human.contains("merged timeline"), "{human}");
        assert!(human.contains("StepReq"), "{human}");
    }

    #[test]
    fn known_spans_are_distinct_and_dotted() {
        let mut seen = std::collections::BTreeSet::new();
        for name in KNOWN_SPANS {
            assert!(seen.insert(name), "duplicate span name {name:?}");
            assert!(
                name.contains('.') && name.is_ascii(),
                "span names are dotted ascii identifiers, got {name:?}"
            );
        }
    }
}
