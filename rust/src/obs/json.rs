//! Hand-rolled JSON value, writer and parser (the crate carries no
//! serde). Shared by the trace exporter, the `--json` report, the
//! `privlogit trace` merge/validate subcommand and the test assertions
//! that read all of those back.
//!
//! Objects preserve insertion order (a `Vec` of pairs, not a map) so
//! emitted documents are deterministic and diffable.

use std::fmt::Write as _;

/// A JSON document node.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (all our counters fit `f64` exactly — byte and
    /// frame counts stay far below 2⁵³).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object, in insertion order.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Member lookup on an object (first match); `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number value, if this node is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number value as `u64` (counts, bytes, timestamps).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as u64)
    }

    /// The boolean value, if this node is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The string value, if this node is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this node is an array.
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact single-line document.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            JsonValue::Str(s) => write_escaped(out, s),
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse one JSON document. Trailing non-whitespace is an error (each
/// trace line is exactly one document).
pub fn parse(text: &str) -> Result<JsonValue, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing bytes at offset {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at offset {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| format!("bad number at offset {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let code = 0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                char::from_u32(code)
                            } else {
                                char::from_u32(hi)
                            };
                            out.push(c.ok_or("bad \\u escape")?);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(format!("bad escape at offset {}", self.pos)),
                    }
                    self.pos += 1;
                }
                _ => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid UTF-8".to_string())?;
                    let c = rest.chars().next().ok_or("unterminated string")?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        let chunk = self
            .bytes
            .get(self.pos..end)
            .and_then(|b| std::str::from_utf8(b).ok())
            .ok_or("truncated \\u escape")?;
        let v = u32::from_str_radix(chunk, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos = end;
        Ok(v)
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.pos)),
            }
        }
    }
}

/// Convenience: an object builder in insertion order.
#[derive(Default)]
pub struct JsonObj(Vec<(String, JsonValue)>);

impl JsonObj {
    /// Start an empty object.
    pub fn new() -> JsonObj {
        JsonObj(Vec::new())
    }

    /// Append a member.
    pub fn push(mut self, key: &str, v: JsonValue) -> JsonObj {
        self.0.push((key.to_string(), v));
        self
    }

    /// Append a string member.
    pub fn str(self, key: &str, v: &str) -> JsonObj {
        self.push(key, JsonValue::Str(v.to_string()))
    }

    /// Append a numeric member from `u64`.
    pub fn u64(self, key: &str, v: u64) -> JsonObj {
        self.push(key, JsonValue::Num(v as f64))
    }

    /// Append a numeric member from `f64`.
    pub fn f64(self, key: &str, v: f64) -> JsonObj {
        self.push(key, JsonValue::Num(v))
    }

    /// Append a boolean member.
    pub fn bool(self, key: &str, v: bool) -> JsonObj {
        self.push(key, JsonValue::Bool(v))
    }

    /// Finish into a [`JsonValue::Obj`].
    pub fn build(self) -> JsonValue {
        JsonValue::Obj(self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let doc = JsonObj::new()
            .str("schema", "privlogit-trace/v1")
            .u64("pid", 1234)
            .f64("secs", 0.25)
            .bool("ok", true)
            .push("tags", JsonValue::Arr(vec![JsonValue::Num(8.0), JsonValue::Null]))
            .build();
        let text = doc.render();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
        assert_eq!(back.get("pid").and_then(JsonValue::as_u64), Some(1234));
        assert_eq!(back.get("schema").and_then(JsonValue::as_str), Some("privlogit-trace/v1"));
    }

    #[test]
    fn escapes_and_unicode() {
        let doc = JsonValue::Str("a\"b\\c\nd\tñ€".to_string());
        assert_eq!(parse(&doc.render()).unwrap(), doc);
        assert_eq!(
            parse("\"\\u00e9\\ud83d\\ude00\"").unwrap(),
            JsonValue::Str("é😀".to_string())
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2", "{'a':1}"] {
            assert!(parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("18014398509481984").unwrap().as_u64(), Some(1u64 << 54));
    }
}
