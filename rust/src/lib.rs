//! # PrivLogit
//!
//! A production-quality reproduction of **"PrivLogit: Efficient
//! Privacy-preserving Logistic Regression by Tailoring Numerical
//! Optimizers"** (Xie, Wang, Boker, Brown — 2016, arXiv:1611.01170).
//!
//! The paper's observation: privacy-preserving logistic regression built on
//! the de-facto Newton method wastes enormous amounts of *secure* compute on
//! re-evaluating and re-inverting the Hessian every iteration. PrivLogit
//! replaces the Hessian with the constant Böhning–Lindsay bound
//! `H̃ = -¼ XᵀX - λI`, which is evaluated and (securely) inverted **once**,
//! turning every subsequent iteration into cheap secure aggregation.
//!
//! This crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the distributed protocol runtime: participating
//!   organizations ("nodes"), a two-server semi-honest aggregation center,
//!   Paillier additively-homomorphic aggregation, Yao garbled-circuit
//!   secure matrix algebra (Cholesky, back-substitution, comparison), and
//!   the three protocols of the paper: the secure **Newton** baseline,
//!   **PrivLogit-Hessian** (Algorithm 1) and **PrivLogit-Local**
//!   (Algorithm 3).
//! * **L2 (python/compile/model.py)** — the JAX compute graph for
//!   node-local plaintext statistics (gradient, log-likelihood, Gram
//!   matrix, exact Hessian), AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   sigmoid/gradient/log-likelihood tile loop, the node-local numeric
//!   hot-spot, lowered into the same HLO.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once and [`runtime`] loads them through PJRT.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`bigint`] | arbitrary-precision integers (substrate for Paillier) |
//! | [`crypto`] | ChaCha20 CSPRNG, Paillier cryptosystem, fixed-point codec |
//! | [`gc`] | boolean circuits + Yao garbling (free-XOR, half-gates, OT) |
//! | [`mpc`] | two-server secure matrix algebra over shares; split-process center peer; cost model |
//! | [`optim`] | plaintext Newton / PrivLogit optimizers (ground truth) |
//! | [`protocols`] | the three secure protocols of the paper |
//! | [`coordinator`] | node/center topology, scheduler, convergence loop |
//! | [`net`] | wire format, TCP transport, remote fleets, node servers (node-side encryption) |
//! | [`obs`] | observability: leveled logging, trace spans, JSONL exporter, per-tag wire accounting |
//! | [`runtime`] | PJRT client: load + execute AOT HLO artifacts; scoped-thread worker pool |
//! | [`linalg`] | dense matrix/vector algebra, Cholesky, solvers |
//! | [`data`] | dataset synthesis, real-study stand-ins, partitioning |
//! | [`config`] | experiment/config system + CLI parsing |
//! | [`metrics`] | counters, timers, per-phase cost accounting |
//! | [`analysis`] | the `privlogit audit` static checker: secrecy + protocol-invariant rules |
//!
//! The deployed topology (every box of the paper's Figure 1 as its own
//! OS process — node servers, `center-a` garbler/driver, `center-b`
//! evaluator, ciphertext-only fleet wire) is documented in
//! `docs/ARCHITECTURE.md` and `docs/DEPLOY.md`.

// Established test idiom: build a `Config::default()` then override the
// fields under test. Clearer than `Config { dataset: …, ..Default::default() }`
// when the point is the delta from the defaults.
#![allow(clippy::field_reassign_with_default)]

pub mod analysis;
pub mod bigint;
pub mod config;
pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod gc;
pub mod linalg;
pub mod metrics;
pub mod mpc;
pub mod net;
pub mod obs;
pub mod optim;
pub mod protocols;
pub mod runtime;
pub mod testutil;
