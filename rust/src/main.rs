//! `privlogit` — the leader binary: run privacy-preserving logistic
//! regression experiments from the command line, in-process or as a real
//! distributed deployment.
//!
//! ```text
//! privlogit run  [--dataset Loans] [--protocol privlogit-local]
//!                [--backend auto] [--orgs 4] [--lambda 1.0] [--tol 1e-6]
//!                [--modulus-bits 1024] [--threaded] [--center-tcp]
//!                [--seed 42] [--config FILE]
//! privlogit compare [same flags]    # all three protocols side by side
//! privlogit list                    # the paper's evaluation suite
//!
//! # Distributed (see docs/DEPLOY.md):
//! privlogit node     --listen 127.0.0.1:9401 --dataset Wine --orgs 4 --org 0
//! privlogit center-b --listen 127.0.0.1:9700 [--once]
//! privlogit center-a --peer 127.0.0.1:9700 --nodes 127.0.0.1:9401,... [run flags]
//! privlogit center   --nodes 127.0.0.1:9401,... [run flags]   # single-process center
//! ```
//!
//! `node` serves one organization's shard over TCP and, once the center
//! installs its Paillier key, encrypts every statistic itself — only
//! ciphertexts cross the fleet wire. `center-b` is Center server S2 for
//! real: the garbled-circuit evaluator that also aggregates relayed node
//! ciphertexts, draws its own blinds and keeps its own additive shares
//! (share material never crosses the peer wire). `center-a` garbles,
//! holds the Paillier key, drives the protocol against the node fleet,
//! and reports wire traffic in both directions. `center` runs both
//! Center halves in one process (threads).

use privlogit::config::Config;
use privlogit::coordinator::{checkpoint, run_protocol_durable, Backend, CenterLink, Experiment};
use privlogit::data::{dataset_by_name, WORKLOADS};
use privlogit::gc::word::FixedFmt;
use privlogit::metrics::{beta_preview, render_report, render_report_json};
use privlogit::mpc::PeerGcServer;
use privlogit::net::{wire, FleetOptions, NodeServer, RemoteFleet, TcpTransport};
use privlogit::obs;
use privlogit::obs::timeline::{parse_trace, Timeline};
use privlogit::protocols::{DurableRun, Protocol, ProtocolConfig, RunReport};

fn usage() -> ! {
    eprintln!(
        "usage: privlogit <run|compare|list|trace|ping|audit|node|center|center-a|center-b> \
         [--dataset NAME] [--protocol P] [--backend real|model|auto] [--orgs N] [--lambda L] \
         [--tol T] [--max-iters M] [--modulus-bits B] [--threaded] [--center-tcp] [--json] \
         [--seed S] [--no-pack] [--config FILE]\n\
         \n\
         distributed mode (docs/DEPLOY.md):\n\
         privlogit node     --listen ADDR --dataset NAME --orgs N --org J\n\
         privlogit center-b --listen ADDR [--once]\n\
         privlogit center-a --peer ADDR --nodes ADDR1,ADDR2,... [run flags]\n\
         privlogit center   --nodes ADDR1,ADDR2,... [run flags]\n\
         privlogit ping ADDR               # one Ping round trip to a node server\n\
         fault tolerance: [--round-timeout SECS] [--quorum Q] [--connect-timeout SECS]\n\
         durable sessions: [--state-dir DIR] [--resume DIR]   (docs/DEPLOY.md §Crash recovery)\n\
         \n\
         observability (docs/ARCHITECTURE.md §Observability):\n\
         PRIVLOGIT_LOG=warn|info|debug   stderr log level (any subcommand)\n\
         PRIVLOGIT_TRACE=PATH            write a JSONL span trace per process\n\
         privlogit trace [--validate] [--json] FILE...   merge per-process traces\n\
         privlogit audit [--json] [SRC_DIR]   secrecy/invariant static audit (exit 1 on findings)"
    );
    std::process::exit(2)
}

/// Print the run report in the format `--json` selects.
fn print_report(cfg: &Config, report: &RunReport) {
    if cfg.json {
        println!("{}", render_report_json(report));
    } else {
        print!("{}", render_report(report));
        println!("  beta: {}", beta_preview(&report.beta));
    }
}

/// `privlogit trace`: merge per-process JSONL trace files into one
/// cross-process timeline (`--validate` checks files and stops;
/// `--json` emits the `privlogit-timeline/v1` document).
fn trace_main(args: &[String]) -> anyhow::Result<()> {
    let mut validate = false;
    let mut json_out = false;
    let mut paths = Vec::new();
    for arg in args {
        match arg.as_str() {
            "--validate" => validate = true,
            "--json" => json_out = true,
            flag if flag.starts_with("--") => {
                anyhow::bail!("unknown trace flag {flag:?} (valid: --validate --json)")
            }
            path => paths.push(path.to_string()),
        }
    }
    anyhow::ensure!(!paths.is_empty(), "privlogit trace needs at least one trace file");
    let mut files = Vec::new();
    for path in &paths {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace file {path:?}: {e}"))?;
        let file = parse_trace(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        if validate {
            println!(
                "{path}: ok ({} events, proc {}, pid {})",
                file.events.len(),
                file.proc,
                file.pid
            );
        }
        files.push(file);
    }
    if validate {
        return Ok(());
    }
    let timeline = Timeline::merge(files);
    if json_out {
        println!("{}", timeline.render_json());
    } else {
        print!("{}", timeline.render());
    }
    Ok(())
}

/// `privlogit audit [--json] [SRC_DIR]`: run the machine-checked
/// secrecy and protocol-invariant audit over the crate sources
/// (docs/ARCHITECTURE.md §Static analysis). Exits non-zero when any
/// finding survives the allowlist, so CI gates on it.
fn audit_main(args: &[String]) -> anyhow::Result<()> {
    let mut json_out = false;
    let mut roots: Vec<String> = Vec::new();
    for arg in args {
        match arg.as_str() {
            "--json" => json_out = true,
            flag if flag.starts_with("--") => {
                anyhow::bail!("unknown audit flag {flag:?} (valid: --json)")
            }
            path => roots.push(path.to_string()),
        }
    }
    anyhow::ensure!(roots.len() <= 1, "privlogit audit takes at most one SRC_DIR");
    let root = roots.pop().unwrap_or_else(|| ".".to_string());
    let report = privlogit::analysis::audit(std::path::Path::new(&root))?;
    if json_out {
        println!("{}", report.render_json());
    } else {
        print!("{}", report.render_human());
    }
    if !report.findings.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

/// `privlogit ping ADDR`: one wire-level liveness probe — connect,
/// handshake, `Ping` → `Ack` — printing the round-trip time. Exits
/// non-zero if the server is unreachable or answers badly, so scripts
/// and readiness checks can gate on it.
fn ping_main(args: &[String]) -> anyhow::Result<()> {
    let mut addr = None;
    for arg in args {
        match arg.as_str() {
            flag if flag.starts_with("--") => anyhow::bail!("unknown ping flag {flag:?}"),
            a if addr.is_none() => addr = Some(a.to_string()),
            extra => anyhow::bail!("unexpected extra ping argument {extra:?}"),
        }
    }
    let Some(addr) = addr else { anyhow::bail!("usage: privlogit ping ADDR") };
    let started = std::time::Instant::now();
    let mut transport = TcpTransport::connect(&addr, wire::ROLE_CENTER)
        .map_err(|e| anyhow::anyhow!("{addr}: connect failed: {e}"))?;
    let connected = started.elapsed();
    transport.set_deadline(Some(std::time::Duration::from_secs(10)))?;
    let ping_started = std::time::Instant::now();
    transport.send_wire(&wire::WireMsg::Ping)?;
    match transport.recv_wire()? {
        wire::WireMsg::Ack => {}
        other => anyhow::bail!("{addr}: sent {other:?} where an acknowledgement was expected"),
    }
    let rtt = ping_started.elapsed();
    // Let the server exit its session loop cleanly rather than logging
    // a dropped connection.
    let _ = transport.send_wire(&wire::WireMsg::Shutdown);
    println!(
        "{addr}: ok (connect+handshake {:.1} ms, ping {:.1} ms)",
        connected.as_secs_f64() * 1e3,
        rtt.as_secs_f64() * 1e3
    );
    Ok(())
}

/// `privlogit node`: serve shard `--org` of `--dataset` (split into
/// `--orgs` partitions) on `--listen` until killed.
fn node_main(cfg: &Config) -> anyhow::Result<()> {
    let Some(data) = dataset_by_name(&cfg.dataset) else {
        anyhow::bail!(
            "unknown dataset {:?} — `privlogit list` shows the paper suite, \
             or use an inline spec like synth:n=1200,p=4,seed=7",
            cfg.dataset
        )
    };
    anyhow::ensure!(
        cfg.org < cfg.orgs,
        "--org {} out of range for --orgs {} (0-based shard index)",
        cfg.org,
        cfg.orgs
    );
    let shard = data.partition(cfg.orgs).swap_remove(cfg.org);
    let (shard_n, shard_p) = (shard.n(), shard.p());
    let engine = privlogit::runtime::default_engine();
    // Paillier randomness stays on the per-process entropy default —
    // co-deployed nodes must not share an encryption-randomness stream.
    let mut server = NodeServer::bind_with_engine(&cfg.listen, shard, engine)?;
    println!(
        "node serving {} shard {}/{} ({} samples, p={}) on {}",
        cfg.dataset,
        cfg.org,
        cfg.orgs,
        shard_n,
        shard_p,
        server.local_addr()?
    );
    server.serve_forever()?;
    Ok(())
}

/// `privlogit center-b`: serve Center server S2 — GC evaluator,
/// ciphertext aggregator and share custodian — on `--listen`; `--once`
/// exits after one center-a session.
fn center_b_main(cfg: &Config) -> anyhow::Result<()> {
    let mut server = PeerGcServer::bind(&cfg.listen, cfg.seed ^ 0xB)?;
    println!("center-b (S2: evaluator + aggregator) listening on {}", server.local_addr()?);
    if cfg.once {
        server.serve_once()?;
        println!("center-b session complete");
        Ok(())
    } else {
        server.serve_forever()?;
        Ok(())
    }
}

/// Run the protocol over remote node servers, converting a mid-protocol
/// channel panic (a vanished center-b peer) into a clean error so the
/// CLI exits non-zero with a message instead of a raw panic.
fn run_over_nodes(cfg: &Config, link: CenterLink) -> anyhow::Result<RunReport> {
    let addrs: Vec<String> =
        cfg.nodes.split(',').filter(|a| !a.is_empty()).map(|a| a.trim().to_string()).collect();
    anyhow::ensure!(
        !addrs.is_empty(),
        "--nodes must list at least one node server address (comma-separated)"
    );
    let protocol: Protocol = cfg.protocol.parse()?;
    let backend: Backend = cfg.backend.parse()?;
    let pcfg = ProtocolConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters };
    // Fault-tolerance knobs: environment first, explicit config on top.
    let mut opts = FleetOptions::from_env()?;
    if let Some(secs) = cfg.round_timeout {
        opts.round_timeout = (secs > 0.0 && secs.is_finite())
            .then(|| std::time::Duration::from_secs_f64(secs));
    }
    opts.quorum = cfg.quorum;
    if cfg.connect_timeout > 0.0 && cfg.connect_timeout.is_finite() {
        opts.connect_timeout = std::time::Duration::from_secs_f64(cfg.connect_timeout);
    }
    // Durable-session knobs: `--resume DIR` loads the latest checkpoint
    // and advances the session epoch so the node-side replay guard
    // accepts the re-key; `--state-dir DIR` (implied by --resume)
    // persists a checkpoint at every round boundary.
    let mut durable = DurableRun {
        state_dir: (!cfg.state_dir.is_empty()).then(|| cfg.state_dir.clone().into()),
        resume: None,
        seed: cfg.seed,
        modulus_bits: cfg.modulus_bits as u64,
        epoch: 0,
    };
    if !cfg.resume.is_empty() {
        let dir = std::path::PathBuf::from(&cfg.resume);
        let cp = checkpoint::load_latest(&dir)?.ok_or_else(|| {
            anyhow::anyhow!(
                "--resume {}: no checkpoint-*.json found (was the crashed center run \
                 with --state-dir pointing here?)",
                dir.display()
            )
        })?;
        obs::info(format_args!(
            "resuming session {} from checkpoint round {} (epoch {} -> {})",
            cp.session,
            cp.round,
            cp.epoch,
            cp.epoch + 1
        ));
        durable.epoch = cp.epoch + 1;
        opts.epoch = durable.epoch;
        if durable.state_dir.is_none() {
            durable.state_dir = Some(dir);
        }
        durable.resume = Some(cp);
    }
    let connect_timeout = opts.connect_timeout;
    let mut fleet = RemoteFleet::connect_with(&addrs, opts)?;
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_protocol_durable(
            protocol,
            backend,
            cfg.modulus_bits,
            FixedFmt::DEFAULT,
            &pcfg,
            cfg.seed,
            &link,
            &mut fleet,
            connect_timeout,
            &durable,
            cfg.no_pack,
        )
    }));
    match run {
        Ok(report) => report,
        Err(panic) => {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "unknown panic".to_string());
            anyhow::bail!("protocol aborted mid-run: {msg}")
        }
    }
}

/// `privlogit center` / `center-a`: run the protocol over node servers
/// at `--nodes` (center-a additionally garbles against a remote
/// `center-b` at `--peer`).
fn center_main(cfg: &Config, link: CenterLink) -> anyhow::Result<()> {
    let report = run_over_nodes(cfg, link)?;
    print_report(cfg, &report);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "list" => {
            println!(
                "{:<10} {:>10} {:>5} {:>9}  paper iters (Newton/PrivLogit)",
                "dataset", "paper n", "p", "our n"
            );
            for w in WORKLOADS {
                println!(
                    "{:<10} {:>10} {:>5} {:>9}  {}/{}",
                    w.name, w.paper_n, w.p, w.n, w.paper_iters.0, w.paper_iters.1
                );
            }
            Ok(())
        }
        "run" => {
            obs::set_proc("run");
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            let exp = Experiment::from_config(&cfg)?;
            let report = exp.run()?;
            print_report(&cfg, &report);
            Ok(())
        }
        "trace" => trace_main(&args[1..]),
        "ping" => ping_main(&args[1..]),
        "audit" => audit_main(&args[1..]),
        "compare" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            for proto in Protocol::ALL {
                let mut c = cfg.clone();
                c.protocol = proto.name().to_string();
                let exp = Experiment::from_config(&c)?;
                let report = exp.run()?;
                println!("{}", report.summary());
            }
            Ok(())
        }
        "node" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            obs::set_proc(&format!("node:{}", cfg.org));
            node_main(&cfg)
        }
        "center" => {
            obs::set_proc("center");
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            let link = if cfg.center_tcp {
                CenterLink::TcpLoopback
            } else {
                CenterLink::Mem
            };
            center_main(&cfg, link)
        }
        "center-a" => {
            obs::set_proc("center-a");
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            anyhow::ensure!(
                !cfg.peer.is_empty(),
                "center-a needs --peer ADDR (the center-b evaluator); \
                 use `privlogit center` for the single-process center"
            );
            let link = CenterLink::Peer(cfg.peer.clone());
            center_main(&cfg, link)
        }
        "center-b" => {
            obs::set_proc("center-b");
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            center_b_main(&cfg)
        }
        _ => usage(),
    }
}
