//! `privlogit` — the leader binary: run privacy-preserving logistic
//! regression experiments from the command line, in-process or as a real
//! distributed deployment.
//!
//! ```text
//! privlogit run  [--dataset Loans] [--protocol privlogit-local]
//!                [--backend auto] [--orgs 4] [--lambda 1.0] [--tol 1e-6]
//!                [--modulus-bits 1024] [--threaded] [--center-tcp]
//!                [--seed 42] [--config FILE]
//! privlogit compare [same flags]    # all three protocols side by side
//! privlogit list                    # the paper's evaluation suite
//!
//! # Distributed (see docs/DEPLOY.md):
//! privlogit node   --listen 127.0.0.1:9401 --dataset Wine --orgs 4 --org 0
//! privlogit center --nodes 127.0.0.1:9401,127.0.0.1:9402,... [run flags]
//! ```
//!
//! `node` serves one organization's shard over TCP; `center` connects to
//! every node, runs the selected protocol over the remote fleet, and
//! reports wire traffic in both directions.

use privlogit::config::Config;
use privlogit::coordinator::{run_protocol, Backend, Experiment};
use privlogit::data::{load_workload, workload, WORKLOADS};
use privlogit::gc::word::FixedFmt;
use privlogit::metrics::{beta_preview, render_report};
use privlogit::net::{NodeServer, RemoteFleet};
use privlogit::protocols::{Protocol, ProtocolConfig};

fn usage() -> ! {
    eprintln!(
        "usage: privlogit <run|compare|list|node|center> [--dataset NAME] [--protocol P] \
         [--backend real|model|auto] [--orgs N] [--lambda L] [--tol T] \
         [--max-iters M] [--modulus-bits B] [--threaded] [--center-tcp] [--seed S] \
         [--config FILE]\n\
         \n\
         distributed mode (docs/DEPLOY.md):\n\
         privlogit node   --listen ADDR --dataset NAME --orgs N --org J\n\
         privlogit center --nodes ADDR1,ADDR2,... [run flags]"
    );
    std::process::exit(2)
}

/// `privlogit node`: serve shard `--org` of `--dataset` (split into
/// `--orgs` partitions) on `--listen` until killed.
fn node_main(cfg: &Config) -> anyhow::Result<()> {
    let Some(w) = workload(&cfg.dataset) else {
        anyhow::bail!("unknown dataset {:?} — `privlogit list` shows the paper suite", cfg.dataset)
    };
    let data = load_workload(w);
    anyhow::ensure!(
        cfg.org < cfg.orgs,
        "--org {} out of range for --orgs {} (0-based shard index)",
        cfg.org,
        cfg.orgs
    );
    let shard = data.partition(cfg.orgs).swap_remove(cfg.org);
    let shard_n = shard.n();
    let engine = privlogit::runtime::default_engine();
    let mut server = NodeServer::bind_with_engine(&cfg.listen, shard, engine)?;
    println!(
        "node serving {} shard {}/{} ({} samples, p={}) on {}",
        cfg.dataset,
        cfg.org,
        cfg.orgs,
        shard_n,
        w.p,
        server.local_addr()?
    );
    server.serve_forever()?;
    Ok(())
}

/// `privlogit center`: run the protocol over node servers at `--nodes`.
fn center_main(cfg: &Config) -> anyhow::Result<()> {
    let addrs: Vec<String> =
        cfg.nodes.split(',').filter(|a| !a.is_empty()).map(|a| a.trim().to_string()).collect();
    anyhow::ensure!(
        !addrs.is_empty(),
        "--nodes must list at least one node server address (comma-separated)"
    );
    let protocol: Protocol = cfg.protocol.parse()?;
    let backend: Backend = cfg.backend.parse()?;
    let pcfg = ProtocolConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters };
    let mut fleet = RemoteFleet::connect(&addrs)?;
    let report = run_protocol(
        protocol,
        backend,
        cfg.modulus_bits,
        FixedFmt::DEFAULT,
        &pcfg,
        cfg.seed,
        cfg.center_tcp,
        &mut fleet,
    );
    print!("{}", render_report(&report));
    println!("  beta: {}", beta_preview(&report.beta));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "list" => {
            println!(
                "{:<10} {:>10} {:>5} {:>9}  paper iters (Newton/PrivLogit)",
                "dataset", "paper n", "p", "our n"
            );
            for w in WORKLOADS {
                println!(
                    "{:<10} {:>10} {:>5} {:>9}  {}/{}",
                    w.name, w.paper_n, w.p, w.n, w.paper_iters.0, w.paper_iters.1
                );
            }
            Ok(())
        }
        "run" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            let exp = Experiment::from_config(&cfg)?;
            let report = exp.run();
            print!("{}", render_report(&report));
            println!("  beta: {}", beta_preview(&report.beta));
            Ok(())
        }
        "compare" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            for proto in Protocol::ALL {
                let mut c = cfg.clone();
                c.protocol = proto.name().to_string();
                let exp = Experiment::from_config(&c)?;
                let report = exp.run();
                println!("{}", report.summary());
            }
            Ok(())
        }
        "node" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            node_main(&cfg)
        }
        "center" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            center_main(&cfg)
        }
        _ => usage(),
    }
}
