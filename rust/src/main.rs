//! `privlogit` — the leader binary: run privacy-preserving logistic
//! regression experiments from the command line.
//!
//! ```text
//! privlogit run  [--dataset Loans] [--protocol privlogit-local]
//!                [--backend auto] [--orgs 4] [--lambda 1.0] [--tol 1e-6]
//!                [--modulus-bits 1024] [--threaded] [--seed 42]
//!                [--config FILE]
//! privlogit compare [same flags]    # all three protocols side by side
//! privlogit list                    # the paper's evaluation suite
//! ```

use privlogit::config::Config;
use privlogit::coordinator::Experiment;
use privlogit::data::WORKLOADS;
use privlogit::metrics::{beta_preview, render_report};
use privlogit::protocols::Protocol;

fn usage() -> ! {
    eprintln!(
        "usage: privlogit <run|compare|list> [--dataset NAME] [--protocol P] \
         [--backend real|model|auto] [--orgs N] [--lambda L] [--tol T] \
         [--max-iters M] [--modulus-bits B] [--threaded] [--seed S] [--config FILE]"
    );
    std::process::exit(2)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "list" => {
            println!(
                "{:<10} {:>10} {:>5} {:>9}  paper iters (Newton/PrivLogit)",
                "dataset", "paper n", "p", "our n"
            );
            for w in WORKLOADS {
                println!(
                    "{:<10} {:>10} {:>5} {:>9}  {}/{}",
                    w.name, w.paper_n, w.p, w.n, w.paper_iters.0, w.paper_iters.1
                );
            }
            Ok(())
        }
        "run" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            let exp = Experiment::from_config(&cfg)?;
            let report = exp.run();
            print!("{}", render_report(&report));
            println!("  beta: {}", beta_preview(&report.beta));
            Ok(())
        }
        "compare" => {
            let mut cfg = Config::default();
            cfg.parse_args(&args[1..])?;
            for proto in Protocol::ALL {
                let mut c = cfg.clone();
                c.protocol = proto.name().to_string();
                let exp = Experiment::from_config(&c)?;
                let report = exp.run();
                println!("{}", report.summary());
            }
            Ok(())
        }
        _ => usage(),
    }
}
