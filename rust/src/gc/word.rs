//! Two's-complement fixed-point words over a [`GcBackend`].
//!
//! All the secure arithmetic the Center performs (Cholesky,
//! back-substitution, convergence comparison — paper §4) is built from
//! these word-level circuits. Gate costs (W = word bits, F = fraction
//! bits, N = W+F):
//!
//! | op | AND gates (≈) |
//! |---|---|
//! | add/sub | 2W |
//! | mul (truncating) | 1.5·N² |
//! | div (truncating) | 3·W·N |
//! | sqrt | 1.5·N² /2 |
//! | cmp | W |
//! | mux | W |
//!
//! Words are little-endian bit vectors; negative values wrap (two's
//! complement). Programs built from these ops are data-oblivious by
//! construction — no secret-dependent control flow exists in this module.

use super::backend::GcBackend;

/// Fixed-point format: `w` total bits, `f` fractional bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FixedFmt {
    /// Total word width in bits (two's complement).
    pub w: usize,
    /// Fractional bits.
    pub f: u32,
}

impl FixedFmt {
    /// Default protocol format: 40-bit words, 24 fraction bits.
    ///
    /// Node statistics are *averaged* (scaled by 1/n) before encryption,
    /// so every protocol value is O(1)–O(10²); ±2¹⁵ integer range with
    /// 2⁻²⁴ ≈ 6e-8 resolution comfortably brackets the paper's 1e-6
    /// convergence threshold.
    pub const DEFAULT: FixedFmt = FixedFmt { w: 40, f: 24 };

    /// Largest supported word width. Share arithmetic lives in `u128`
    /// words and the masked wide reveals carry `w + σ + 1` bits with the
    /// σ = 40 statistical-mask parameter, so `w` must leave headroom:
    /// `1u128 << w` and the wide-chunk assembly both overflow silently
    /// (or panic, depending on build profile) once `w` approaches 128.
    /// 64 bits is far beyond any useful fixed-point precision here.
    pub const MAX_W: usize = 64;

    /// Validating constructor for wire-controlled formats. Everything a
    /// remote peer sends (`SetKey`, `GcExec`) must pass through here so
    /// an out-of-range width is a session error at the trust boundary,
    /// not an overflow deep inside the share arithmetic.
    pub fn try_new(w: usize, f: u32) -> anyhow::Result<FixedFmt> {
        anyhow::ensure!(
            (2..=Self::MAX_W).contains(&w),
            "fixed-point word width {w} outside the supported range 2..={}",
            Self::MAX_W
        );
        anyhow::ensure!(
            (f as usize) < w,
            "fixed-point fraction bits {f} must be smaller than the word width {w}"
        );
        Ok(FixedFmt { w, f })
    }

    /// Encode an `f64` to the fixed-point integer (two's complement in
    /// `w` bits, as i128 for headroom).
    pub fn encode(&self, v: f64) -> i128 {
        let scaled = (v * (self.f as f64).exp2()).round();
        let bound = (1i128 << (self.w - 1)) as f64;
        assert!(
            scaled.abs() < bound,
            "fixed overflow: {v} needs more than {} integer bits",
            self.w as u32 - 1 - self.f
        );
        scaled as i128
    }

    /// Decode a two's-complement `w`-bit integer back to `f64`.
    pub fn decode(&self, raw: i128) -> f64 {
        self.signed(raw) as f64 / (self.f as f64).exp2()
    }

    /// Reduce an i128 to the signed `w`-bit range.
    pub fn signed(&self, raw: i128) -> i128 {
        let m = 1i128 << self.w;
        let v = raw.rem_euclid(m);
        if v >= m / 2 { v - m } else { v }
    }

    /// Unsigned residue mod 2^w.
    pub fn unsigned(&self, raw: i128) -> u128 {
        (raw.rem_euclid(1i128 << self.w)) as u128
    }
}

/// A word: little-endian wires.
pub type Word<W> = Vec<W>;

/// Build a word of public constant bits from an integer (low `w` bits).
pub fn const_word<B: GcBackend>(b: &mut B, v: i128, w: usize) -> Word<B::Wire> {
    (0..w).map(|i| b.constant((v >> i) & 1 == 1)).collect()
}

/// Full adder returning (sum, carry-out). 2 ANDs… but implemented with the
/// standard 1-AND trick: carry = (a ⊕ c)(b ⊕ c) ⊕ c.
fn full_add<B: GcBackend>(
    b: &mut B,
    a: B::Wire,
    x: B::Wire,
    c: B::Wire,
) -> (B::Wire, B::Wire) {
    let axc = b.xor(a, c);
    let xxc = b.xor(x, c);
    let sum = b.xor(axc, x);
    let t = b.and(axc, xxc);
    let carry = b.xor(t, c);
    (sum, carry)
}

/// Ripple-carry addition, truncating to the width of `a` (= width of `x`).
pub fn add<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, x: &Word<B::Wire>) -> Word<B::Wire> {
    assert_eq!(a.len(), x.len());
    let mut c = b.constant(false);
    let mut out = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let (s, nc) = full_add(b, a[i], x[i], c);
        out.push(s);
        c = nc;
    }
    out
}

/// Subtraction `a − x` (two's complement, truncating).
pub fn sub<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, x: &Word<B::Wire>) -> Word<B::Wire> {
    assert_eq!(a.len(), x.len());
    let mut c = b.constant(true); // +1 of two's complement
    let mut out = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let nx = b.not(x[i]);
        let (s, nc) = full_add(b, a[i], nx, c);
        out.push(s);
        c = nc;
    }
    out
}

/// Negation `−a`.
pub fn neg<B: GcBackend>(b: &mut B, a: &Word<B::Wire>) -> Word<B::Wire> {
    let zero = const_word(b, 0, a.len());
    sub(b, &zero, a)
}

/// Sign-extend (or truncate) to `w` bits.
pub fn resize<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, w: usize) -> Word<B::Wire> {
    let _ = b;
    let mut out = a.clone();
    let sign = *a.last().expect("empty word");
    out.resize(w, sign);
    out.truncate(w);
    out
}

/// Logical shift left by a public amount (free).
pub fn shl_const<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, k: usize) -> Word<B::Wire> {
    let zero = b.constant(false);
    let mut out = vec![zero; k.min(a.len())];
    out.extend_from_slice(&a[..a.len() - k.min(a.len())]);
    out
}

/// Arithmetic shift right by a public amount (free).
pub fn sar_const<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, k: usize) -> Word<B::Wire> {
    let _ = b;
    let sign = *a.last().expect("empty word");
    let k = k.min(a.len());
    let mut out: Word<B::Wire> = a[k..].to_vec();
    out.resize(a.len(), sign);
    out
}

/// Signed less-than `a < x` (1 wire out). Computed in w+1 bits so overflow
/// cannot corrupt the sign.
pub fn lt<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, x: &Word<B::Wire>) -> B::Wire {
    let w = a.len() + 1;
    let ae = resize(b, a, w);
    let xe = resize(b, x, w);
    let d = sub(b, &ae, &xe);
    *d.last().unwrap()
}

/// Per-bit multiplexer over words: `s ? a : x`.
pub fn mux_word<B: GcBackend>(
    b: &mut B,
    s: B::Wire,
    a: &Word<B::Wire>,
    x: &Word<B::Wire>,
) -> Word<B::Wire> {
    assert_eq!(a.len(), x.len());
    (0..a.len()).map(|i| b.mux(s, a[i], x[i])).collect()
}

/// Absolute value (returns `|a|` and the original sign wire).
pub fn abs<B: GcBackend>(b: &mut B, a: &Word<B::Wire>) -> (Word<B::Wire>, B::Wire) {
    let sign = *a.last().unwrap();
    let na = neg(b, a);
    (mux_word(b, sign, &na, a), sign)
}

/// Fixed-point multiply: `(a · x) >> f`, truncating to `w` bits.
///
/// Works modulo 2^(w+f): sign-extend both operands to w+f bits, schoolbook
/// shift-add keeping only the low w+f bits, then drop the f low bits.
pub fn mul<B: GcBackend>(
    b: &mut B,
    a: &Word<B::Wire>,
    x: &Word<B::Wire>,
    fmt: FixedFmt,
) -> Word<B::Wire> {
    let n = fmt.w + fmt.f as usize;
    let ae = resize(b, a, n);
    let xe = resize(b, x, n);
    let zero = b.constant(false);
    let mut acc = vec![zero; n];
    for i in 0..n {
        // partial product (a << i) & x_i, truncated to n bits — only the
        // upper n-i bits of acc are affected.
        let width = n - i;
        let pp: Word<B::Wire> = (0..width).map(|j| b.and(ae[j], xe[i])).collect();
        let hi: Word<B::Wire> = acc[i..].to_vec();
        let sum = add(b, &hi, &pp);
        acc[i..].copy_from_slice(&sum);
    }
    acc[fmt.f as usize..].to_vec()
}

/// Fixed-point divide: `(a << f) / x`, truncating (C-style) signed division.
///
/// Restoring long division over magnitudes, then sign correction.
pub fn div<B: GcBackend>(
    b: &mut B,
    a: &Word<B::Wire>,
    x: &Word<B::Wire>,
    fmt: FixedFmt,
) -> Word<B::Wire> {
    let n = fmt.w + fmt.f as usize;
    let (amag, asign) = abs(b, a);
    let (xmag, xsign) = abs(b, x);
    // numerator = |a| << f, n+1 bits working width (magnitudes fit in w-1
    // bits, numerator in w-1+f < n bits).
    let num = {
        let ae = resize(b, &amag, n);
        shl_const(b, &ae, fmt.f as usize)
    };
    let xe = resize(b, &xmag, n + 1);
    let zero = b.constant(false);
    let mut rem: Word<B::Wire> = vec![zero; n + 1];
    let mut quo: Word<B::Wire> = vec![zero; n];
    for i in (0..n).rev() {
        // rem = (rem << 1) | num[i]
        rem.rotate_right(1);
        rem[0] = num[i];
        // trial subtract
        let trial = sub(b, &rem, &xe);
        let too_big = *trial.last().unwrap(); // sign: rem < x
        let keep = mux_word(b, too_big, &rem, &trial);
        rem = keep;
        quo[i] = b.not(too_big);
    }
    // sign correction: q = (asign ^ xsign) ? -q : q, truncated to w bits
    let qt: Word<B::Wire> = quo[..fmt.w].to_vec();
    let s = b.xor(asign, xsign);
    let nq = neg(b, &qt);
    mux_word(b, s, &nq, &qt)
}

/// Fixed-point square root of a non-negative value: `sqrt(a)` at scale f.
///
/// Integer bitwise method on `a << f` (so the result is at scale f).
/// The input is assumed ≥ 0 (Cholesky pivots; enforced by the protocol) —
/// negative inputs produce garbage, never a panic (data-oblivious).
pub fn sqrt<B: GcBackend>(b: &mut B, a: &Word<B::Wire>, fmt: FixedFmt) -> Word<B::Wire> {
    let n = fmt.w + fmt.f as usize; // radicand width
    let ae = resize(b, a, n);
    let num = shl_const(b, &ae, fmt.f as usize); // wait: a already at scale f; (a<<f) at scale 2f, sqrt at scale f. n bits is enough for w+f.
    let zero = b.constant(false);
    // bitwise restoring sqrt: iterate k from high to low bit of result.
    // result has ceil(n/2) significant bits.
    let rbits = n.div_ceil(2);
    let mut res: Word<B::Wire> = vec![zero; n];
    let mut rem: Word<B::Wire> = vec![zero; n + 2];
    // Process radicand two bits at a time from the top.
    let numw = {
        let mut v = num;
        if v.len() % 2 == 1 {
            v.push(zero);
        }
        v
    };
    let pairs = numw.len() / 2;
    for k in (0..pairs).rev() {
        // rem = (rem << 2) | next two radicand bits
        rem.rotate_right(2);
        rem[0] = numw[2 * k];
        rem[1] = numw[2 * k + 1];
        // trial = rem - (res << 2 | 01) at position… standard: t = (res<<2)|1 shifted per step
        // Here res accumulates from the top: candidate = (res << 1 | 1) << k*… — use classic:
        // trial subtract of ((res << 2) | 1) where res is the partial root.
        let mut cand: Word<B::Wire> = vec![zero; rem.len()];
        // cand = (res << 2) | 1 — res currently holds the partial root in low bits
        cand[0] = b.constant(true);
        for (i, &r) in res.iter().enumerate().take(rem.len().saturating_sub(2)) {
            cand[i + 2] = r;
        }
        let trial = sub(b, &rem, &cand);
        let too_big = *trial.last().unwrap();
        rem = mux_word(b, too_big, &rem, &trial);
        // res = (res << 1) | !too_big
        res.rotate_right(1);
        res[0] = b.not(too_big);
    }
    let _ = rbits;
    // res holds sqrt(a<<f) = sqrt(a)·2^f… at integer scale; truncate to w bits
    let mut out: Word<B::Wire> = res[..fmt.w.min(res.len())].to_vec();
    out.resize(fmt.w, zero);
    out
}

/// `|a − x| < tol · |x|` — the paper's relative-convergence predicate
/// (§3.2), used by the secure convergence check. Returns a single wire.
pub fn rel_converged<B: GcBackend>(
    b: &mut B,
    l_new: &Word<B::Wire>,
    l_old: &Word<B::Wire>,
    tol: f64,
    fmt: FixedFmt,
) -> B::Wire {
    let d = sub(b, l_new, l_old);
    let (dmag, _) = abs(b, &d);
    let (omag, _) = abs(b, l_old);
    let t = const_word(b, fmt.encode(tol), fmt.w);
    let thresh = mul(b, &omag, &t, fmt);
    lt(b, &dmag, &thresh)
}

#[cfg(test)]
mod tests {
    use super::super::backend::{CountBackend, GcBackend, PlainBackend};
    use super::*;
    use crate::testutil::TestRng;

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    fn to_word(b: &mut PlainBackend, v: i128, w: usize) -> Word<bool> {
        (0..w).map(|i| b.constant((v >> i) & 1 == 1)).collect()
    }

    fn from_word(w: &Word<bool>) -> i128 {
        let mut v: i128 = 0;
        for (i, &bit) in w.iter().enumerate() {
            if bit {
                v |= 1 << i;
            }
        }
        // sign extend
        if *w.last().unwrap() {
            v -= 1 << w.len();
        }
        v
    }

    fn eval2(
        f: impl Fn(&mut PlainBackend, &Word<bool>, &Word<bool>) -> Word<bool>,
        a: f64,
        x: f64,
    ) -> f64 {
        let mut b = PlainBackend;
        let wa = to_word(&mut b, FMT.encode(a), FMT.w);
        let wx = to_word(&mut b, FMT.encode(x), FMT.w);
        let out = f(&mut b, &wa, &wx);
        FMT.decode(from_word(&out))
    }

    #[test]
    fn encode_decode() {
        for v in [0.0, 1.5, -1.5, 1000.25, -0.000001] {
            assert!((FMT.decode(FMT.encode(v)) - v).abs() < 1e-6);
        }
    }

    #[test]
    fn add_sub_match_f64() {
        let mut rng = TestRng::new(1);
        for _ in 0..50 {
            let a = rng.range_f64(-1000.0, 1000.0);
            let x = rng.range_f64(-1000.0, 1000.0);
            assert!((eval2(add, a, x) - (a + x)).abs() < 1e-6, "{a}+{x}");
            assert!((eval2(sub, a, x) - (a - x)).abs() < 1e-6, "{a}-{x}");
        }
    }

    #[test]
    fn neg_abs() {
        let mut b = PlainBackend;
        for v in [3.75f64, -3.75, 0.0, -1000.5] {
            let w = to_word(&mut b, FMT.encode(v), FMT.w);
            let n = neg(&mut b, &w);
            assert!((FMT.decode(from_word(&n)) + v).abs() < 1e-6);
            let (m, s) = abs(&mut b, &w);
            assert!((FMT.decode(from_word(&m)) - v.abs()).abs() < 1e-6);
            assert_eq!(s, v < 0.0);
        }
    }

    #[test]
    fn mul_matches_f64() {
        let mut rng = TestRng::new(2);
        for _ in 0..40 {
            let a = rng.range_f64(-100.0, 100.0);
            let x = rng.range_f64(-100.0, 100.0);
            let got = eval2(|b, p, q| mul(b, p, q, FMT), a, x);
            assert!((got - a * x).abs() < 2e-5, "{a}*{x} = {got}");
        }
    }

    #[test]
    fn mul_extremes() {
        // products near the representable boundary
        let got = eval2(|b, p, q| mul(b, p, q, FMT), 181.0, 181.0);
        assert!((got - 181.0 * 181.0).abs() < 1e-4);
        let got = eval2(|b, p, q| mul(b, p, q, FMT), -181.0, 181.0);
        assert!((got + 181.0 * 181.0).abs() < 1e-4);
    }

    #[test]
    fn div_matches_f64() {
        let mut rng = TestRng::new(3);
        for _ in 0..40 {
            let a = rng.range_f64(-100.0, 100.0);
            let mut x = rng.range_f64(-20.0, 20.0);
            if x.abs() < 0.01 {
                x = 1.0;
            }
            let got = eval2(|b, p, q| div(b, p, q, FMT), a, x);
            assert!((got - a / x).abs() < 2e-5, "{a}/{x} = {got}");
        }
    }

    #[test]
    fn div_signs() {
        for (a, x) in [(7.0, 2.0), (-7.0, 2.0), (7.0, -2.0), (-7.0, -2.0)] {
            let got = eval2(|b, p, q| div(b, p, q, FMT), a, x);
            assert!((got - a / x).abs() < 1e-5, "{a}/{x} = {got}");
        }
    }

    #[test]
    fn sqrt_matches_f64() {
        let mut b = PlainBackend;
        let mut rng = TestRng::new(4);
        for _ in 0..30 {
            let v = rng.range_f64(0.0001, 5000.0);
            let w = to_word(&mut b, FMT.encode(v), FMT.w);
            let s = sqrt(&mut b, &w, FMT);
            let got = FMT.decode(from_word(&s));
            assert!((got - v.sqrt()).abs() < 3e-5, "sqrt({v}) = {got} vs {}", v.sqrt());
        }
    }

    #[test]
    fn lt_and_mux() {
        let mut b = PlainBackend;
        for (a, x) in [(1.0f64, 2.0f64), (2.0, 1.0), (-5.0, 3.0), (3.0, -5.0), (4.0, 4.0)] {
            let wa = to_word(&mut b, FMT.encode(a), FMT.w);
            let wx = to_word(&mut b, FMT.encode(x), FMT.w);
            assert_eq!(lt(&mut b, &wa, &wx), a < x, "{a} < {x}");
            let s = b.constant(a < x);
            let m = mux_word(&mut b, s, &wa, &wx);
            let expect = if a < x { a } else { x };
            assert!((FMT.decode(from_word(&m)) - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn shifts() {
        let mut b = PlainBackend;
        let w = to_word(&mut b, FMT.encode(3.5), FMT.w);
        let l = shl_const(&mut b, &w, 2);
        assert!((FMT.decode(from_word(&l)) - 14.0).abs() < 1e-6);
        let r = sar_const(&mut b, &w, 1);
        assert!((FMT.decode(from_word(&r)) - 1.75).abs() < 1e-6);
        let wn = to_word(&mut b, FMT.encode(-8.0), FMT.w);
        let rn = sar_const(&mut b, &wn, 2);
        assert!((FMT.decode(from_word(&rn)) + 2.0).abs() < 1e-6);
    }

    #[test]
    fn rel_converged_predicate() {
        let mut b = PlainBackend;
        let cases = [
            (-100.0, -100.00001, true),  // tiny relative change
            (-100.0, -101.0, false),     // 1% change
            (-0.5, -0.5000001, true),
            (-0.5, -0.51, false),
        ];
        for (lo, ln, expect) in cases {
            let wo = to_word(&mut b, FMT.encode(lo), FMT.w);
            let wn = to_word(&mut b, FMT.encode(ln), FMT.w);
            let c = rel_converged(&mut b, &wn, &wo, 1e-4, FMT);
            assert_eq!(c, expect, "rel_converged({ln} vs {lo})");
        }
    }

    /// Gate counts are stable contracts for the cost model; pin rough
    /// magnitudes so regressions are caught.
    #[test]
    fn gate_count_magnitudes() {
        let mut b = CountBackend::default();
        let a: Word<_> = (0..FMT.w).map(|_| None).collect();
        let x: Word<_> = (0..FMT.w).map(|_| None).collect();
        add(&mut b, &a, &x);
        let add_ands = b.ands;
        assert!(add_ands as usize <= FMT.w, "add ≤ W ANDs, got {add_ands}");
        let mut b = CountBackend::default();
        mul(&mut b, &a, &x, FMT);
        let n = FMT.w + FMT.f as usize;
        assert!(
            (b.ands as usize) < 2 * n * n,
            "mul < 2N² ANDs, got {} (N={n})",
            b.ands
        );
        let mut b = CountBackend::default();
        div(&mut b, &a, &x, FMT);
        assert!((b.ands as usize) < 4 * n * (n + 2), "div gate count {}", b.ands);
    }

    /// Wire-controlled formats must be bounds-checked: widths that would
    /// overflow the `u128` share arithmetic (`w = 128` turns
    /// `1u128 << w` into an overflow) are rejected, as are degenerate
    /// fraction layouts.
    #[test]
    fn try_new_rejects_out_of_range_formats() {
        assert!(FixedFmt::try_new(40, 24).is_ok());
        assert!(FixedFmt::try_new(FixedFmt::MAX_W, 24).is_ok());
        for (w, f) in [(128usize, 24u32), (65, 24), (1, 0), (0, 0), (40, 40), (40, 64)] {
            assert!(FixedFmt::try_new(w, f).is_err(), "w={w} f={f} must be rejected");
        }
        let fmt = FixedFmt::try_new(FixedFmt::MAX_W, 32).unwrap();
        // The limit width must actually be usable by the share masks.
        let mask = (1u128 << fmt.w).wrapping_sub(1);
        assert_ne!(mask, 0);
    }
}
