//! Oblivious transfer: Paillier-based 1-of-2 base OT plus IKNP OT
//! extension for the evaluator's input labels.
//!
//! Per GC execution the evaluator needs one OT per input bit — tens of
//! thousands for a p×p Cholesky — so per-OT public-key work is
//! unaffordable. IKNP (CRYPTO'03, semi-honest variant) reduces this to
//! 128 base OTs *once per session*, after which each OT costs two PRG
//! bits and one fixed-key AES hash per side.
//!
//! Base OT (semi-honest, additively homomorphic): the receiver sends
//! `Enc(c)` under its own ephemeral Paillier key; the sender replies with
//! a rerandomized `Enc(m₀ + c·(m₁−m₀))`; the receiver decrypts `m_c`.

use super::channel::Channel;
use super::garble::GateHash;
use crate::bigint::BigUint;
use crate::crypto::paillier::{ChaChaSource, Ciphertext, Keypair, PublicKey};
use crate::crypto::rng::ChaChaRng;

/// Number of base OTs / width of the IKNP matrix.
pub const KAPPA: usize = 128;

/// Expand a 16-byte seed to `n` pseudorandom bits, packed LSB-first into
/// `u64` words.
fn prg_bits(seed: u128, n: usize) -> Vec<u64> {
    let mut seed_bytes = [0u8; 32];
    seed_bytes[..16].copy_from_slice(&seed.to_le_bytes());
    let mut rng = ChaChaRng::from_seed(seed_bytes);
    let words = n.div_ceil(64);
    let mut out = Vec::with_capacity(words);
    for _ in 0..words {
        out.push(rng.next_u64());
    }
    // mask tail bits for clean equality in tests
    if n % 64 != 0 {
        let last = out.len() - 1;
        out[last] &= (1u64 << (n % 64)) - 1;
    }
    out
}

fn xor_words(a: &mut [u64], b: &[u64]) {
    for (x, y) in a.iter_mut().zip(b) {
        *x ^= y;
    }
}

fn get_bit(words: &[u64], i: usize) -> bool {
    (words[i / 64] >> (i % 64)) & 1 == 1
}

fn set_bit(words: &mut [u64], i: usize, v: bool) {
    if v {
        words[i / 64] |= 1 << (i % 64);
    } else {
        words[i / 64] &= !(1 << (i % 64));
    }
}

/// Pack bools LSB-first into u64 words.
pub fn pack_bits(bits: &[bool]) -> Vec<u64> {
    let mut out = vec![0u64; bits.len().div_ceil(64)];
    for (i, &b) in bits.iter().enumerate() {
        set_bit(&mut out, i, b);
    }
    out
}

/// OT-extension sender state (the garbler: sends label pairs).
pub struct OtSender {
    /// Random choice vector `s` from the base phase.
    s: u128,
    /// Base-OT seeds `k_{s_j,j}`.
    seeds: Vec<u128>,
    hash: GateHash,
    /// Global OT counter (hash tweak uniqueness across extends).
    ctr: u64,
}

/// OT-extension receiver state (the evaluator: holds choice bits).
pub struct OtReceiver {
    /// Base-OT seed pairs `(k0_j, k1_j)`.
    seed_pairs: Vec<(u128, u128)>,
    hash: GateHash,
    ctr: u64,
}

impl OtSender {
    /// Run the base phase as base-OT *receiver* (IKNP role reversal).
    /// Peer must call [`OtReceiver::setup`] concurrently.
    pub fn setup(chan: &mut Channel, rng: &mut ChaChaRng) -> Self {
        let s_lo = rng.next_u64();
        let s_hi = rng.next_u64();
        let s = (s_hi as u128) << 64 | s_lo as u128;
        // Ephemeral Paillier key for the base OTs (receiver side).
        let kp = Keypair::generate(256, rng);
        // Send pk.n
        chan.send_blob(&kp.pk.n.to_bytes_le());
        let mut seeds = Vec::with_capacity(KAPPA);
        // Send Enc(s_j) for each j, receive Enc(m_{s_j}) back.
        for j in 0..KAPPA {
            let bit = (s >> j) & 1 == 1;
            let c = kp.pk.encrypt(
                &BigUint::from_u64(bit as u64),
                &mut ChaChaSource(rng),
            );
            chan.send_blob(&c.0.to_bytes_le());
        }
        chan.flush();
        for _ in 0..KAPPA {
            let reply = Ciphertext(BigUint::from_bytes_le(&chan.recv_blob()));
            let m = kp.sk.decrypt(&reply);
            let bytes = m.to_bytes_le();
            let mut seed = [0u8; 16];
            seed[..bytes.len().min(16)].copy_from_slice(&bytes[..bytes.len().min(16)]);
            seeds.push(u128::from_le_bytes(seed));
        }
        OtSender { s, seeds, hash: GateHash::new(), ctr: 0 }
    }

    /// Send `pairs[i] = (x0, x1)`; the receiver obtains `x_{r_i}`.
    pub fn send(&mut self, chan: &mut Channel, pairs: &[(u128, u128)]) {
        let m = pairs.len();
        if m == 0 {
            return;
        }
        let words = m.div_ceil(64);
        // Receive u_j columns; build q_j = PRG(k_{s_j}) ^ s_j·u_j.
        let mut q_cols: Vec<Vec<u64>> = Vec::with_capacity(KAPPA);
        for j in 0..KAPPA {
            let u_bytes = chan.recv_blob();
            let mut u = vec![0u64; words];
            for (w, chunk) in u.iter_mut().zip(u_bytes.chunks(8)) {
                let mut b = [0u8; 8];
                b[..chunk.len()].copy_from_slice(chunk);
                *w = u64::from_le_bytes(b);
            }
            let mut q = prg_bits(self.seeds[j], m);
            if (self.s >> j) & 1 == 1 {
                xor_words(&mut q, &u);
            }
            q_cols.push(q);
        }
        // Transpose columns to per-OT rows q_i (u128 each).
        for (i, &(x0, x1)) in pairs.iter().enumerate() {
            let mut qi: u128 = 0;
            for (j, q) in q_cols.iter().enumerate() {
                if get_bit(q, i) {
                    qi |= 1 << j;
                }
            }
            let t = self.ctr;
            self.ctr += 1;
            let y0 = x0 ^ self.hash.hash(qi, t);
            let y1 = x1 ^ self.hash.hash(qi ^ self.s, t);
            chan.send_u128(y0);
            chan.send_u128(y1);
        }
        chan.flush();
    }
}

impl OtReceiver {
    /// Run the base phase as base-OT *sender*.
    pub fn setup(chan: &mut Channel, rng: &mut ChaChaRng) -> Self {
        let n = BigUint::from_bytes_le(&chan.recv_blob());
        let n2 = n.mul(&n);
        let pk = reconstruct_pk(n, n2);
        let mut seed_pairs = Vec::with_capacity(KAPPA);
        let mut replies = Vec::with_capacity(KAPPA);
        for _ in 0..KAPPA {
            let enc_bit = Ciphertext(BigUint::from_bytes_le(&chan.recv_blob()));
            let k0 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
            let k1 = (rng.next_u64() as u128) << 64 | rng.next_u64() as u128;
            // Enc(m0 + c·(m1−m0)) = Enc(c)·(m1−m0) ⊕ m0 (mod n; both
            // messages < 2^128 ≪ n so the decrypted value is exact).
            let m0 = BigUint::from_u128(k0);
            let m1 = BigUint::from_u128(k1);
            let diff = m1.add(&pk.n.sub(&m0.rem(&pk.n))); // m1 - m0 mod n
            let scaled = pk.scalar_mul(&enc_bit, &diff.rem(&pk.n));
            let shifted = pk.add(&scaled, &pk.encrypt_trivial(&m0));
            let reply = pk.rerandomize(&shifted, &mut ChaChaSource(rng));
            replies.push(reply);
            seed_pairs.push((k0, k1));
        }
        for r in replies {
            chan.send_blob(&r.0.to_bytes_le());
        }
        chan.flush();
        OtReceiver { seed_pairs, hash: GateHash::new(), ctr: 0 }
    }

    /// Receive one message per choice bit: returns `x_{r_i}`.
    pub fn recv(&mut self, chan: &mut Channel, choices: &[bool]) -> Vec<u128> {
        let m = choices.len();
        if m == 0 {
            return Vec::new();
        }
        let r = pack_bits(choices);
        let mut t_cols: Vec<Vec<u64>> = Vec::with_capacity(KAPPA);
        for j in 0..KAPPA {
            let t = prg_bits(self.seed_pairs[j].0, m);
            let mut u = prg_bits(self.seed_pairs[j].1, m);
            xor_words(&mut u, &t);
            xor_words(&mut u, &r);
            let bytes: Vec<u8> = u.iter().flat_map(|w| w.to_le_bytes()).collect();
            chan.send_blob(&bytes);
            t_cols.push(t);
        }
        chan.flush();
        let mut out = Vec::with_capacity(m);
        for (i, &c) in choices.iter().enumerate() {
            let mut ti: u128 = 0;
            for (j, t) in t_cols.iter().enumerate() {
                if get_bit(t, i) {
                    ti |= 1 << j;
                }
            }
            let tweak = self.ctr;
            self.ctr += 1;
            let y0 = chan.recv_u128();
            let y1 = chan.recv_u128();
            let y = if c { y1 } else { y0 };
            out.push(y ^ self.hash.hash(ti, tweak));
        }
        out
    }
}

/// Rebuild a `PublicKey` from its modulus (the receiver only needs the
/// homomorphic ops, which depend on `n`/`n²` alone).
fn reconstruct_pk(n: BigUint, n2: BigUint) -> PublicKey {
    PublicKey::from_modulus(n, n2)
}

#[cfg(test)]
mod tests {
    use super::super::channel::mem_channel_pair;
    use super::*;
    use crate::testutil::TestRng;

    #[test]
    fn prg_deterministic_and_masked() {
        let a = prg_bits(42, 130);
        let b = prg_bits(42, 130);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert_eq!(a[2] >> 2, 0, "tail bits masked");
        assert_ne!(prg_bits(43, 130), a);
    }

    #[test]
    fn pack_get_roundtrip() {
        let bits: Vec<bool> = (0..100).map(|i| i % 3 == 0).collect();
        let packed = pack_bits(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(get_bit(&packed, i), b);
        }
    }

    /// Full IKNP round trip: receiver obtains exactly x_{r_i}, never the
    /// sibling message.
    #[test]
    fn ot_extension_end_to_end() {
        let (mut ca, mut cb) = mem_channel_pair();
        let mut trng = TestRng::new(77);
        let m = 500;
        let pairs: Vec<(u128, u128)> = (0..m)
            .map(|_| {
                (
                    (trng.next_u64() as u128) << 64 | trng.next_u64() as u128,
                    (trng.next_u64() as u128) << 64 | trng.next_u64() as u128,
                )
            })
            .collect();
        let choices: Vec<bool> = (0..m).map(|_| trng.bernoulli(0.5)).collect();
        let pairs_s = pairs.clone();
        let sender = std::thread::spawn(move || {
            let mut rng = ChaChaRng::from_u64_seed(1001);
            let mut s = OtSender::setup(&mut ca, &mut rng);
            s.send(&mut ca, &pairs_s);
            // second extend on the same session must also work
            let more: Vec<(u128, u128)> =
                (0..64).map(|i| (i as u128, (i + 1000) as u128)).collect();
            s.send(&mut ca, &more);
        });
        let mut rng = ChaChaRng::from_u64_seed(2002);
        let mut r = OtReceiver::setup(&mut cb, &mut rng);
        let got = r.recv(&mut cb, &choices);
        for i in 0..m {
            let expect = if choices[i] { pairs[i].1 } else { pairs[i].0 };
            assert_eq!(got[i], expect, "OT {i}");
            let other = if choices[i] { pairs[i].0 } else { pairs[i].1 };
            assert_ne!(got[i], other, "OT {i} must not leak sibling");
        }
        let choices2: Vec<bool> = (0..64).map(|i| i % 2 == 0).collect();
        let got2 = r.recv(&mut cb, &choices2);
        for (i, &c) in choices2.iter().enumerate() {
            let expect = if c { (i + 1000) as u128 } else { i as u128 };
            assert_eq!(got2[i], expect, "second extend OT {i}");
        }
        sender.join().unwrap();
    }
}
