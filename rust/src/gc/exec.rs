//! Two-party execution of garbled programs.
//!
//! A [`GcProgram`] is a deterministic, data-oblivious circuit program (see
//! [`super::backend`]). [`GcSession`] owns the duplex channel pair and the
//! persistent OT-extension state for both Center servers, and executes
//! programs by running the garbler (server S1) and evaluator (server S2)
//! on two scoped threads with the garbled material streamed between them.
//!
//! Protocol per execution:
//! 1. garbler sends active labels for its own input bits;
//! 2. evaluator obtains labels for its input bits via IKNP OT;
//! 3. both walk the program; AND-gate tables stream through the channel;
//! 4. garbler streams output-decode bits; the evaluator learns the output
//!    bits (protocols arrange outputs to be maskable/public as needed).

use super::backend::GcBackend;
use super::channel::{mem_channel_pair, Channel};
use super::garble::{Evaluator, GWire, Garbler};
use super::ot::{OtReceiver, OtSender};
use crate::crypto::rng::ChaChaRng;

/// A two-party circuit program.
///
/// `run` must be deterministic and data-oblivious: the sequence of backend
/// operations may depend only on program parameters (dimensions, formats),
/// never on wire values.
pub trait GcProgram: Sync {
    /// Number of garbler (server S1) input bits.
    fn inputs_garbler(&self) -> usize;
    /// Number of evaluator (server S2) input bits.
    fn inputs_evaluator(&self) -> usize;
    /// The circuit itself.
    fn run<B: GcBackend>(
        &self,
        b: &mut B,
        garbler_in: &[B::Wire],
        evaluator_in: &[B::Wire],
    ) -> Vec<B::Wire>;
}

/// Statistics from one program execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// AND gates garbled/evaluated.
    pub ands: u64,
    /// Evaluator input bits transferred by OT.
    pub ot_bits: u64,
    /// Wall-clock seconds for the execution.
    pub wall: f64,
}

/// Run the garbler (Center server S1) half of one program execution over
/// `chan`: stream input labels, serve the evaluator's OT, garble the
/// circuit, stream the output-decode bits. Returns `(new_gate_ctr,
/// ands)`.
///
/// This is one half of [`GcSession::execute`]; the in-process session
/// runs it on a scoped thread against [`run_evaluator`], and the
/// split-process deployment (`privlogit center-a`) runs it against a
/// remote `privlogit center-b` over TCP (see `mpc::peer`).
pub fn run_garbler<P: GcProgram>(
    chan: &mut Channel,
    ot_send: &mut OtSender,
    prog: &P,
    garbler_bits: &[bool],
    exec_seed: u64,
    gate_ctr: u64,
) -> (u64, u64) {
    assert_eq!(garbler_bits.len(), prog.inputs_garbler(), "garbler input arity");
    let rng = ChaChaRng::from_u64_seed(exec_seed);
    let mut g = Garbler::new(chan, rng, gate_ctr);
    // 1. own inputs
    let g_wires: Vec<GWire> = garbler_bits.iter().map(|&b| g.input_self(b)).collect();
    // 2. evaluator inputs via OT (sender side)
    let mut e_wires = Vec::with_capacity(prog.inputs_evaluator());
    let mut pairs = Vec::with_capacity(prog.inputs_evaluator());
    for _ in 0..prog.inputs_evaluator() {
        let (w, pair) = g.input_evaluator_pair();
        e_wires.push(w);
        pairs.push(pair);
    }
    g.flush();
    ot_send.send(g.channel(), &pairs);
    // 3. circuit
    let outs = prog.run(&mut g, &g_wires, &e_wires);
    // 4. decode info
    for &o in &outs {
        g.output(o);
    }
    g.flush();
    (g.gate_ctr, g.ands)
}

/// Run the evaluator (Center server S2) half of one program execution
/// over `chan`: receive input labels, obtain own labels via OT, evaluate
/// the streamed circuit, decode the outputs. Returns `(output_bits,
/// ands)` — the counterpart of [`run_garbler`].
pub fn run_evaluator<P: GcProgram>(
    chan: &mut Channel,
    ot_recv: &mut OtReceiver,
    prog: &P,
    evaluator_bits: &[bool],
    gate_ctr: u64,
) -> (Vec<bool>, u64) {
    assert_eq!(evaluator_bits.len(), prog.inputs_evaluator(), "evaluator input arity");
    let mut e = Evaluator::new(chan, gate_ctr);
    let g_wires: Vec<GWire> = (0..prog.inputs_garbler()).map(|_| e.input_garbler()).collect();
    let labels = ot_recv.recv(e.channel(), evaluator_bits);
    let e_wires: Vec<GWire> = labels.into_iter().map(GWire::Label).collect();
    let outs = prog.run(&mut e, &g_wires, &e_wires);
    let bits: Vec<bool> = outs.into_iter().map(|o| e.output(o)).collect();
    (bits, e.ands)
}

/// Persistent two-server GC session (base OTs done once at construction).
pub struct GcSession {
    chan_g: Channel,
    chan_e: Channel,
    ot_send: OtSender,
    ot_recv: OtReceiver,
    gate_ctr: u64,
    rng_seed: u64,
    execs: u64,
    /// Cumulative stats across executions.
    pub total: ExecStats,
}

impl GcSession {
    /// Create a session over in-memory channels: connects the two servers
    /// and runs the IKNP base phase (128 Paillier base OTs).
    pub fn new(seed: u64) -> Self {
        let (chan_g, chan_e) = mem_channel_pair();
        GcSession::over_channels(chan_g, chan_e, seed)
    }

    /// Create a session over a pre-connected channel pair — e.g. real TCP
    /// loopback sockets from [`crate::net::tcp::loopback_channel_pair`],
    /// so the two Center servers' traffic crosses the kernel network
    /// stack exactly as in the paper's two-PC testbed.
    pub fn over_channels(mut chan_g: Channel, mut chan_e: Channel, seed: u64) -> Self {
        let (ot_send, ot_recv) = std::thread::scope(|s| {
            let h = s.spawn(|| {
                let mut rng = ChaChaRng::from_u64_seed(seed ^ 0x5e55_1011);
                OtSender::setup(&mut chan_g, &mut rng)
            });
            let mut rng = ChaChaRng::from_u64_seed(seed ^ 0x0e1e_2021);
            let r = OtReceiver::setup(&mut chan_e, &mut rng);
            (h.join().expect("ot sender setup"), r)
        });
        GcSession {
            chan_g,
            chan_e,
            ot_send,
            ot_recv,
            gate_ctr: 0,
            rng_seed: seed,
            execs: 0,
            total: ExecStats::default(),
        }
    }

    /// Execute `prog` with the servers' respective input bits; returns the
    /// output bits (learned on the evaluator side) and execution stats.
    pub fn execute<P: GcProgram>(
        &mut self,
        prog: &P,
        garbler_bits: &[bool],
        evaluator_bits: &[bool],
    ) -> (Vec<bool>, ExecStats) {
        assert_eq!(garbler_bits.len(), prog.inputs_garbler(), "garbler input arity");
        assert_eq!(evaluator_bits.len(), prog.inputs_evaluator(), "evaluator input arity");
        let t0 = std::time::Instant::now();
        self.execs += 1;
        let exec_seed = self.rng_seed ^ self.execs.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let gate_ctr = self.gate_ctr;

        let chan_g = &mut self.chan_g;
        let chan_e = &mut self.chan_e;
        let ot_send = &mut self.ot_send;
        let ot_recv = &mut self.ot_recv;

        let (outputs, g_ands, e_ands) = std::thread::scope(|s| {
            // ---- Server S1: garbler thread ----
            let garbler_handle = s.spawn(move || {
                run_garbler(chan_g, ot_send, prog, garbler_bits, exec_seed, gate_ctr)
            });

            // ---- Server S2: evaluator thread (current thread) ----
            let (bits, e_ands) = run_evaluator(chan_e, ot_recv, prog, evaluator_bits, gate_ctr);
            let (new_ctr, g_ands) = garbler_handle.join().expect("garbler thread");
            (bits, g_ands, (new_ctr, e_ands))
        });

        let (new_ctr, e_ands) = e_ands;
        debug_assert_eq!(g_ands, e_ands, "garbler/evaluator gate divergence");
        self.gate_ctr = new_ctr;
        let stats = ExecStats {
            ands: g_ands,
            ot_bits: evaluator_bits.len() as u64,
            wall: t0.elapsed().as_secs_f64(),
        };
        self.total.ands += stats.ands;
        self.total.ot_bits += stats.ot_bits;
        self.total.wall += stats.wall;
        (outputs, stats)
    }

    /// Total bytes sent on both channels so far.
    pub fn bytes_transferred(&self) -> u64 {
        self.chan_g.stats().snapshot().0 + self.chan_e.stats().snapshot().0
    }

    /// Total bytes received on both channels so far.
    pub fn bytes_received(&self) -> u64 {
        self.chan_g.stats().snapshot_recv().0 + self.chan_e.stats().snapshot_recv().0
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::PlainBackend;
    use super::super::word::{self, FixedFmt};
    use super::*;
    use crate::testutil::TestRng;

    /// Program: fixed-point (a+b)·a − b over secret-shared-style inputs,
    /// plus a comparison bit. Exercises add/mul/sub/lt through the real
    /// garbling pipeline.
    struct ArithProg {
        fmt: FixedFmt,
    }

    impl GcProgram for ArithProg {
        fn inputs_garbler(&self) -> usize {
            self.fmt.w
        }
        fn inputs_evaluator(&self) -> usize {
            self.fmt.w
        }
        fn run<B: GcBackend>(
            &self,
            b: &mut B,
            ga: &[B::Wire],
            ea: &[B::Wire],
        ) -> Vec<B::Wire> {
            let a = ga.to_vec();
            let x = ea.to_vec();
            let s = word::add(b, &a, &x);
            let m = word::mul(b, &s, &a, self.fmt);
            let d = word::sub(b, &m, &x);
            let c = word::lt(b, &a, &x);
            let mut out = d;
            out.push(c);
            out
        }
    }

    fn encode_bits(fmt: FixedFmt, v: f64) -> Vec<bool> {
        let raw = fmt.unsigned(fmt.encode(v));
        (0..fmt.w).map(|i| (raw >> i) & 1 == 1).collect()
    }

    fn decode_bits(fmt: FixedFmt, bits: &[bool]) -> f64 {
        let mut raw: i128 = 0;
        for (i, &b) in bits.iter().enumerate() {
            if b {
                raw |= 1 << i;
            }
        }
        fmt.decode(raw)
    }

    #[test]
    fn garbled_matches_plain_backend() {
        let fmt = FixedFmt { w: 32, f: 16 };
        let prog = ArithProg { fmt };
        let mut session = GcSession::new(42);
        let mut rng = TestRng::new(7);
        for round in 0..5 {
            let av = rng.range_f64(-50.0, 50.0);
            let xv = rng.range_f64(-50.0, 50.0);
            let ga = encode_bits(fmt, av);
            let ea = encode_bits(fmt, xv);
            let (bits, stats) = session.execute(&prog, &ga, &ea);
            assert!(stats.ands > 0);
            // Plain-backend oracle.
            let mut pb = PlainBackend;
            let gaw: Vec<bool> = ga.clone();
            let eaw: Vec<bool> = ea.clone();
            let expect = prog.run(&mut pb, &gaw, &eaw);
            assert_eq!(bits, expect, "round {round}: garbled != plain");
            // And sanity against f64 arithmetic.
            let got = decode_bits(fmt, &bits[..fmt.w]);
            let want = (av + xv) * av - xv;
            assert!((got - want).abs() < 0.05, "round {round}: {got} vs {want}");
            assert_eq!(bits[fmt.w], av < xv);
        }
    }

    /// Repeated executions must keep tweaks unique (stateful counters) and
    /// stay correct.
    #[test]
    fn session_reuse_is_correct() {
        let fmt = FixedFmt { w: 24, f: 12 };
        let prog = ArithProg { fmt };
        let mut session = GcSession::new(1);
        let mut last_ctr = 0;
        for i in 0..3 {
            let ga = encode_bits(fmt, i as f64 + 0.5);
            let ea = encode_bits(fmt, 2.0 - i as f64);
            let (bits, _) = session.execute(&prog, &ga, &ea);
            let mut pb = PlainBackend;
            let expect = prog.run(&mut pb, &ga, &ea);
            assert_eq!(bits, expect, "exec {i}");
            assert!(session.gate_ctr > last_ctr, "gate counter must advance");
            last_ctr = session.gate_ctr;
        }
        assert!(session.bytes_transferred() > 0);
        assert_eq!(
            session.bytes_received(),
            session.bytes_transferred(),
            "every byte one server sends, the other receives"
        );
    }
}
