//! Byte-oriented duplex channels between the two Center servers.
//!
//! In the paper's testbed the servers are two PCs on ethernet; here they
//! are two threads over an in-memory [`Transport`] by default, or two
//! endpoints of a real TCP connection via
//! [`crate::net::tcp::tcp_channel`]. The channel interface is
//! deliberately dumb bytes so that every protocol message is serialized
//! for real, and the byte/message counters (both directions) give exact
//! communication-cost accounting (reported in EXPERIMENTS.md and used by
//! the network term of the cost model).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::net::{mem_transport_pair, Transport};
use crate::obs::TagFlow;

/// Shared send/recv statistics for one duplex endpoint.
#[derive(Default)]
pub struct ChannelStats {
    /// Bytes sent from this endpoint.
    pub bytes_sent: AtomicU64,
    /// Messages (flushes) from this endpoint.
    pub msgs_sent: AtomicU64,
    /// Bytes received at this endpoint.
    pub bytes_recv: AtomicU64,
    /// Messages received at this endpoint.
    pub msgs_recv: AtomicU64,
    /// Per-wire-tag accounting of the *control frames* that crossed this
    /// endpoint. Only the framed [`crate::net::wire::WireMsg`] control
    /// layer is tagged — the garbled-table / OT byte streams between the
    /// control frames stay in the aggregate counters above.
    tags: Mutex<BTreeMap<u8, TagFlow>>,
}

impl ChannelStats {
    /// Sent-side snapshot (bytes, messages).
    pub fn snapshot(&self) -> (u64, u64) {
        (self.bytes_sent.load(Ordering::Relaxed), self.msgs_sent.load(Ordering::Relaxed))
    }

    /// Received-side snapshot (bytes, messages).
    pub fn snapshot_recv(&self) -> (u64, u64) {
        (self.bytes_recv.load(Ordering::Relaxed), self.msgs_recv.load(Ordering::Relaxed))
    }

    /// Record one sent control frame of `bytes` framed bytes under `tag`.
    pub fn note_sent(&self, tag: u8, bytes: u64) {
        let mut tags = self.tags.lock().expect("channel tag stats poisoned");
        let flow = tags.entry(tag).or_default();
        flow.sent_frames += 1;
        flow.sent_bytes += bytes;
    }

    /// Record one received control frame of `bytes` framed bytes.
    pub fn note_recv(&self, tag: u8, bytes: u64) {
        let mut tags = self.tags.lock().expect("channel tag stats poisoned");
        let flow = tags.entry(tag).or_default();
        flow.recv_frames += 1;
        flow.recv_bytes += bytes;
    }

    /// Snapshot of the per-tag control-frame accounting.
    pub fn tag_flows(&self) -> BTreeMap<u8, TagFlow> {
        self.tags.lock().expect("channel tag stats poisoned").clone()
    }
}

/// One endpoint of a duplex byte channel with internal read buffering,
/// over any [`Transport`] (in-memory queue or TCP socket).
pub struct Channel {
    transport: Box<dyn Transport>,
    /// Pending bytes already received but not yet consumed.
    inbuf: Vec<u8>,
    inpos: usize,
    /// Write-combining buffer; flushed on [`Channel::flush`] or threshold.
    outbuf: Vec<u8>,
    stats: Arc<ChannelStats>,
}

/// Flush threshold for the write-combining buffer (64 KiB keeps the
/// message rate low while bounding latency).
const FLUSH_BYTES: usize = 64 * 1024;

impl Channel {
    /// Wrap a connected transport endpoint in the byte-channel interface.
    pub fn over(transport: Box<dyn Transport>) -> Channel {
        Channel {
            transport,
            inbuf: Vec::new(),
            inpos: 0,
            outbuf: Vec::new(),
            stats: Arc::new(ChannelStats::default()),
        }
    }

    /// Send raw bytes (buffered; see [`Channel::flush`]).
    pub fn send(&mut self, bytes: &[u8]) {
        self.outbuf.extend_from_slice(bytes);
        if self.outbuf.len() >= FLUSH_BYTES {
            self.flush();
        }
    }

    /// Flush buffered writes to the peer.
    pub fn flush(&mut self) {
        if self.outbuf.is_empty() {
            return;
        }
        let msg = std::mem::take(&mut self.outbuf);
        self.stats.bytes_sent.fetch_add(msg.len() as u64, Ordering::Relaxed);
        self.stats.msgs_sent.fetch_add(1, Ordering::Relaxed);
        // A closed peer is a protocol bug; surface it loudly.
        self.transport.send_msg(msg).expect("channel peer hung up");
    }

    /// Fill `buf` exactly, surfacing transport failure as an error.
    fn fill(&mut self, buf: &mut [u8]) -> std::io::Result<()> {
        let mut filled = 0;
        while filled < buf.len() {
            if self.inpos == self.inbuf.len() {
                self.inbuf = self.transport.recv_msg()?;
                self.inpos = 0;
                self.stats.bytes_recv.fetch_add(self.inbuf.len() as u64, Ordering::Relaxed);
                self.stats.msgs_recv.fetch_add(1, Ordering::Relaxed);
            }
            let take = (self.inbuf.len() - self.inpos).min(buf.len() - filled);
            buf[filled..filled + take]
                .copy_from_slice(&self.inbuf[self.inpos..self.inpos + take]);
            self.inpos += take;
            filled += take;
        }
        Ok(())
    }

    /// Receive exactly `buf.len()` bytes (blocking). Mid-protocol a
    /// vanished peer is a protocol bug, surfaced loudly.
    pub fn recv(&mut self, buf: &mut [u8]) {
        self.fill(buf).expect("channel peer hung up")
    }

    /// Receive a `Vec<u8>` of exactly `len` bytes.
    pub fn recv_vec(&mut self, len: usize) -> Vec<u8> {
        let mut v = vec![0u8; len];
        self.recv(&mut v);
        v
    }

    /// Send a `u64` (little-endian).
    pub fn send_u64(&mut self, v: u64) {
        self.send(&v.to_le_bytes());
    }

    /// Receive a `u64`.
    pub fn recv_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        self.recv(&mut b);
        u64::from_le_bytes(b)
    }

    /// Send a `u128` label.
    pub fn send_u128(&mut self, v: u128) {
        self.send(&v.to_le_bytes());
    }

    /// Receive a `u128` label.
    pub fn recv_u128(&mut self) -> u128 {
        let mut b = [0u8; 16];
        self.recv(&mut b);
        u128::from_le_bytes(b)
    }

    /// Length-prefixed blob send (flushes).
    pub fn send_blob(&mut self, bytes: &[u8]) {
        self.send_u64(bytes.len() as u64);
        self.send(bytes);
        self.flush();
    }

    /// Length-prefixed blob receive.
    pub fn recv_blob(&mut self) -> Vec<u8> {
        let len = self.recv_u64() as usize;
        self.recv_vec(len)
    }

    /// Length-prefixed blob receive that surfaces a vanished peer as
    /// `Err` instead of panicking — for session loops (e.g. the center-b
    /// GC evaluator server) that must treat a disconnecting peer at a
    /// message boundary as an orderly end of session.
    pub fn try_recv_blob(&mut self) -> std::io::Result<Vec<u8>> {
        let mut lb = [0u8; 8];
        self.fill(&mut lb)?;
        let len = u64::from_le_bytes(lb) as usize;
        if len > crate::net::wire::MAX_FRAME {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("control blob of {len} bytes exceeds the frame cap"),
            ));
        }
        let mut v = vec![0u8; len];
        self.fill(&mut v)?;
        Ok(v)
    }

    /// This endpoint's statistics handle.
    pub fn stats(&self) -> Arc<ChannelStats> {
        Arc::clone(&self.stats)
    }

    /// The underlying medium's label ("mem", "tcp").
    pub fn transport_label(&self) -> &'static str {
        self.transport.label()
    }
}

/// Create a connected duplex pair of in-memory channels.
pub fn mem_channel_pair() -> (Channel, Channel) {
    let (a, b) = mem_transport_pair();
    (Channel::over(Box::new(a)), Channel::over(Box::new(b)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_across_threads() {
        let (mut a, mut b) = mem_channel_pair();
        let t = std::thread::spawn(move || {
            a.send_u64(42);
            a.send_blob(b"hello center");
            a.send_u128(0xdead_beef_dead_beef_dead_beef_dead_beefu128);
            a.flush();
            a
        });
        assert_eq!(b.recv_u64(), 42);
        assert_eq!(b.recv_blob(), b"hello center");
        assert_eq!(b.recv_u128(), 0xdead_beef_dead_beef_dead_beef_dead_beefu128);
        let a = t.join().unwrap();
        let (bytes, msgs) = a.stats().snapshot();
        assert_eq!(bytes, 8 + 8 + 12 + 16);
        assert!(msgs >= 1);
        // Receive accounting is symmetric: everything a sent, b received.
        let (rbytes, rmsgs) = b.stats().snapshot_recv();
        assert_eq!(rbytes, bytes);
        assert_eq!(rmsgs, msgs);
        assert_eq!(b.stats().snapshot().0, 0, "b sent nothing");
    }

    #[test]
    fn tagged_control_accounting() {
        let stats = ChannelStats::default();
        stats.note_sent(0x35, 100);
        stats.note_sent(0x35, 50);
        stats.note_recv(0x22, 9);
        let flows = stats.tag_flows();
        assert_eq!(flows[&0x35].sent_frames, 2);
        assert_eq!(flows[&0x35].sent_bytes, 150);
        assert_eq!(flows[&0x22].recv_frames, 1);
        assert_eq!(flows[&0x22].recv_bytes, 9);
        assert!(!flows.contains_key(&0x01));
    }

    #[test]
    fn chunked_reads_cross_message_boundaries() {
        let (mut a, mut b) = mem_channel_pair();
        std::thread::spawn(move || {
            for i in 0..100u8 {
                a.send(&[i]);
                a.flush(); // 100 separate messages
            }
        });
        let got = b.recv_vec(100);
        assert_eq!(got, (0..100u8).collect::<Vec<_>>());
        let (rbytes, rmsgs) = b.stats().snapshot_recv();
        assert_eq!(rbytes, 100);
        assert_eq!(rmsgs, 100);
    }
}
