//! Yao garbled-circuit engine for the Center's Type-2 computations.
//!
//! The paper executes the secure matrix algebra (Cholesky decomposition,
//! back-substitution, comparison — paper §4, after Nikolaenko et al. 2013)
//! between two semi-honest Center servers with the ObliVM-GC framework.
//! ObliVM is unavailable (and Java); this module is a from-scratch garbling
//! engine with the same performance-relevant design points:
//!
//! * **free XOR** (Kolesnikov–Schneider) — XOR gates cost nothing;
//! * **point-and-permute** — single-decryption evaluation;
//! * **half-gates** (Zahur–Rosulek–Evans) — 2 ciphertexts per AND gate;
//! * **fixed-key AES** hashing — `H(X,t) = AES_k(2X⊕t) ⊕ 2X⊕t`;
//! * **streamed garbling** — the circuit is never materialized; the garbler
//!   and evaluator walk the *same deterministic program* gate by gate, so
//!   memory is bounded by the live-wire set (O(p²) for our matrix ops, not
//!   the 10⁷–10⁸ total gates);
//! * **IKNP OT extension** over Paillier base OTs for evaluator inputs.
//!
//! The architecture mirrors `fancy-garbling`/swanky: circuits are generic
//! *programs* over a [`backend::GcBackend`], with four interpreters —
//! plaintext ([`backend::PlainBackend`], the correctness oracle), gate
//! counting ([`backend::CountBackend`], feeds the §5.2 cost model),
//! garbling and evaluating ([`garble::Garbler`], [`garble::Evaluator`]).
//!
//! Two-party execution is split into reusable role halves
//! ([`exec::run_garbler`] / [`exec::run_evaluator`]): [`exec::GcSession`]
//! runs them on scoped threads of one process, while the deployed
//! two-process center (`privlogit center-a` / `center-b`, see
//! [`crate::mpc::peer`]) runs each half in its own OS process over one
//! framed TCP connection.

pub mod backend;
pub mod channel;
pub mod exec;
pub mod garble;
pub mod ot;
pub mod word;

pub use backend::{CountBackend, GcBackend, PlainBackend};
pub use channel::{mem_channel_pair, Channel, ChannelStats};
pub use exec::{run_evaluator, run_garbler, GcProgram, GcSession};
pub use word::{FixedFmt, Word};
