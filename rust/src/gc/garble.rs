//! Streaming half-gates garbler and evaluator.
//!
//! Scheme: Zahur–Rosulek–Evans half-gates with free-XOR and
//! point-and-permute. Labels are 128-bit (`u128`); the global offset `Δ`
//! has its low bit set so the label's low bit is the permute bit. The
//! gate hash is fixed-key AES: `H(X, t) = AES_k(2X ⊕ t) ⊕ (2X ⊕ t)`.
//!
//! Both parties run the *same program* ([`super::backend::GcBackend`]),
//! so tables stream through the channel in program order and neither side
//! ever materializes the circuit. Public-constant wires fold identically
//! on both sides (deterministic program ⇒ identical folding decisions),
//! which gives multiply-by-public-constant circuits their reduced cost —
//! the same asymmetry PrivLogit-Local exploits at the Paillier layer.

use aes::cipher::{generic_array::GenericArray, BlockEncrypt, KeyInit};
use aes::Aes128;

use super::backend::GcBackend;
use super::channel::Channel;
use crate::crypto::rng::ChaChaRng;

/// A garbled wire as seen by one party: a public constant or a label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GWire {
    /// Public constant (never transmitted).
    Const(bool),
    /// Garbler: the 0-label `K₀`. Evaluator: the active label.
    Label(u128),
}

/// Fixed-key AES hash `H(X, t) = AES(2X ⊕ t) ⊕ (2X ⊕ t)`.
pub struct GateHash {
    cipher: Aes128,
}

impl GateHash {
    /// Fixed public key — security rests on the random labels, not the key.
    pub fn new() -> Self {
        let key = GenericArray::from([0x5Au8; 16]);
        GateHash { cipher: Aes128::new(&key) }
    }

    /// Hash a label with tweak `t`.
    #[inline]
    pub fn hash(&self, x: u128, t: u64) -> u128 {
        let v = (x << 1) ^ (t as u128);
        let mut block = GenericArray::from(v.to_le_bytes());
        self.cipher.encrypt_block(&mut block);
        u128::from_le_bytes(block.as_slice().try_into().unwrap()) ^ v
    }
}

impl Default for GateHash {
    fn default() -> Self {
        Self::new()
    }
}

/// Garbler state (Center server S1 in our deployment).
pub struct Garbler<'c> {
    /// Global free-XOR offset (low bit set).
    pub delta: u128,
    rng: ChaChaRng,
    hash: GateHash,
    /// Monotone AND-gate counter — also the hash tweak base. Persistent
    /// across program executions within a session (tweak uniqueness).
    pub gate_ctr: u64,
    /// ANDs garbled in the current program (for metrics).
    pub ands: u64,
    chan: &'c mut Channel,
}

impl<'c> Garbler<'c> {
    /// New garbler over a channel. `delta` is drawn fresh.
    pub fn new(chan: &'c mut Channel, rng: ChaChaRng, gate_ctr: u64) -> Self {
        let mut rng = rng;
        let delta = ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128) | 1;
        Garbler { delta, rng, hash: GateHash::new(), gate_ctr, ands: 0, chan }
    }

    fn fresh_label(&mut self) -> u128 {
        (self.rng.next_u64() as u128) << 64 | self.rng.next_u64() as u128
    }

    /// Garble one of the garbler's own input bits: pick `K₀`, send the
    /// active label.
    pub fn input_self(&mut self, bit: bool) -> GWire {
        let k0 = self.fresh_label();
        let active = if bit { k0 ^ self.delta } else { k0 };
        self.chan.send_u128(active);
        GWire::Label(k0)
    }

    /// Prepare the label pair for one evaluator input bit (fed to OT).
    pub fn input_evaluator_pair(&mut self) -> (GWire, (u128, u128)) {
        let k0 = self.fresh_label();
        (GWire::Label(k0), (k0, k0 ^ self.delta))
    }

    /// Send the decode bit for an output wire; constants need nothing.
    pub fn output(&mut self, w: GWire) {
        if let GWire::Label(k0) = w {
            self.chan.send(&[(k0 & 1) as u8]);
        }
    }

    /// Flush pending garbled material to the evaluator.
    pub fn flush(&mut self) {
        self.chan.flush();
    }

    /// Access the underlying channel (e.g. to run OT mid-session).
    pub fn channel(&mut self) -> &mut Channel {
        self.chan
    }
}

impl GcBackend for Garbler<'_> {
    type Wire = GWire;

    fn constant(&mut self, v: bool) -> GWire {
        GWire::Const(v)
    }

    fn xor(&mut self, a: GWire, b: GWire) -> GWire {
        match (a, b) {
            (GWire::Const(x), GWire::Const(y)) => GWire::Const(x ^ y),
            (GWire::Const(true), GWire::Label(k)) | (GWire::Label(k), GWire::Const(true)) => {
                GWire::Label(k ^ self.delta)
            }
            (GWire::Const(false), w) | (w, GWire::Const(false)) => w,
            (GWire::Label(ka), GWire::Label(kb)) => GWire::Label(ka ^ kb),
        }
    }

    fn not(&mut self, a: GWire) -> GWire {
        match a {
            GWire::Const(v) => GWire::Const(!v),
            GWire::Label(k) => GWire::Label(k ^ self.delta),
        }
    }

    fn and(&mut self, a: GWire, b: GWire) -> GWire {
        let (a0, b0) = match (a, b) {
            (GWire::Const(false), _) | (_, GWire::Const(false)) => return GWire::Const(false),
            (GWire::Const(true), w) | (w, GWire::Const(true)) => return w,
            (GWire::Label(x), GWire::Label(y)) => (x, y),
        };
        // Half-gates (ZRE'15, Fig. 1). pa/pb are permute bits of the
        // 0-labels; j/j' are unique tweaks.
        let j = self.gate_ctr * 2;
        let jp = j + 1;
        self.gate_ctr += 1;
        self.ands += 1;
        let pa = a0 & 1 == 1;
        let pb = b0 & 1 == 1;
        let h_a0 = self.hash.hash(a0, j);
        let h_a1 = self.hash.hash(a0 ^ self.delta, j);
        let h_b0 = self.hash.hash(b0, jp);
        let h_b1 = self.hash.hash(b0 ^ self.delta, jp);
        // Generator half-gate.
        let tg = h_a0 ^ h_a1 ^ if pb { self.delta } else { 0 };
        let wg0 = h_a0 ^ if pa { tg } else { 0 };
        // Evaluator half-gate.
        let te = h_b0 ^ h_b1 ^ a0;
        let we0 = h_b0 ^ if pb { te ^ a0 } else { 0 };
        self.chan.send_u128(tg);
        self.chan.send_u128(te);
        GWire::Label(wg0 ^ we0)
    }
}

/// Evaluator state (Center server S2).
pub struct Evaluator<'c> {
    hash: GateHash,
    /// Must mirror the garbler's counter exactly.
    pub gate_ctr: u64,
    /// ANDs evaluated in the current program.
    pub ands: u64,
    chan: &'c mut Channel,
}

impl<'c> Evaluator<'c> {
    /// New evaluator over the peer channel.
    pub fn new(chan: &'c mut Channel, gate_ctr: u64) -> Self {
        Evaluator { hash: GateHash::new(), gate_ctr, ands: 0, chan }
    }

    /// Receive the active label for a garbler input.
    pub fn input_garbler(&mut self) -> GWire {
        GWire::Label(self.chan.recv_u128())
    }

    /// Access the underlying channel (e.g. to run OT mid-session).
    pub fn channel(&mut self) -> &mut Channel {
        self.chan
    }

    /// Decode an output wire using the garbler's decode bit.
    pub fn output(&mut self, w: GWire) -> bool {
        match w {
            GWire::Const(v) => v,
            GWire::Label(active) => {
                let mut d = [0u8; 1];
                self.chan.recv(&mut d);
                ((active & 1) as u8 ^ d[0]) == 1
            }
        }
    }
}

impl GcBackend for Evaluator<'_> {
    type Wire = GWire;

    fn constant(&mut self, v: bool) -> GWire {
        GWire::Const(v)
    }

    fn xor(&mut self, a: GWire, b: GWire) -> GWire {
        match (a, b) {
            (GWire::Const(x), GWire::Const(y)) => GWire::Const(x ^ y),
            // NOT of an active label leaves the label unchanged — the
            // garbler's decode bit absorbs the flip (free-XOR).
            (GWire::Const(true), GWire::Label(k)) | (GWire::Label(k), GWire::Const(true)) => {
                GWire::Label(k)
            }
            (GWire::Const(false), w) | (w, GWire::Const(false)) => w,
            (GWire::Label(ka), GWire::Label(kb)) => GWire::Label(ka ^ kb),
        }
    }

    fn not(&mut self, a: GWire) -> GWire {
        match a {
            GWire::Const(v) => GWire::Const(!v),
            GWire::Label(k) => GWire::Label(k),
        }
    }

    fn and(&mut self, a: GWire, b: GWire) -> GWire {
        let (al, bl) = match (a, b) {
            (GWire::Const(false), _) | (_, GWire::Const(false)) => return GWire::Const(false),
            (GWire::Const(true), w) | (w, GWire::Const(true)) => return w,
            (GWire::Label(x), GWire::Label(y)) => (x, y),
        };
        let j = self.gate_ctr * 2;
        let jp = j + 1;
        self.gate_ctr += 1;
        self.ands += 1;
        let tg = self.chan.recv_u128();
        let te = self.chan.recv_u128();
        let sa = al & 1 == 1;
        let sb = bl & 1 == 1;
        let wg = self.hash.hash(al, j) ^ if sa { tg } else { 0 };
        let we = self.hash.hash(bl, jp) ^ if sb { te ^ al } else { 0 };
        GWire::Label(wg ^ we)
    }
}

#[cfg(test)]
mod tests {
    use super::super::channel::mem_channel_pair;
    use super::*;

    /// Exhaustive truth-table check of a single garbled AND/XOR/NOT via
    /// the wire-level API (the integration-level randomized check lives in
    /// exec.rs tests).
    #[test]
    fn garbled_gates_truth_tables() {
        for (va, vb) in [(false, false), (false, true), (true, false), (true, true)] {
            let (mut ca, mut cb) = mem_channel_pair();
            let handle = std::thread::spawn(move || {
                let rng = ChaChaRng::from_u64_seed(99);
                let mut g = Garbler::new(&mut ca, rng, 0);
                let vb_pair = g.input_evaluator_pair();
                // deliver the evaluator's label directly (no OT in this
                // unit test): send the active label for vb.
                let active_b = if vb { vb_pair.1 .1 } else { vb_pair.1 .0 };
                g.chan.send_u128(active_b);
                let wa = g.input_self(va);
                let wb = vb_pair.0;
                let and = g.and(wa, wb);
                let xor = g.xor(wa, wb);
                let not = g.not(wa);
                g.output(and);
                g.output(xor);
                g.output(not);
                g.flush();
            });
            let mut e = Evaluator::new(&mut cb, 0);
            let wb = GWire::Label(e.chan.recv_u128());
            let wa = e.input_garbler();
            let and = e.and(wa, wb);
            let xor = e.xor(wa, wb);
            let not = e.not(wa);
            assert_eq!(e.output(and), va & vb, "AND({va},{vb})");
            assert_eq!(e.output(xor), va ^ vb, "XOR({va},{vb})");
            assert_eq!(e.output(not), !va, "NOT({va})");
            handle.join().unwrap();
        }
    }

    #[test]
    fn hash_is_tweaked() {
        let h = GateHash::new();
        assert_ne!(h.hash(5, 1), h.hash(5, 2));
        assert_ne!(h.hash(5, 1), h.hash(6, 1));
        // deterministic
        assert_eq!(h.hash(12345, 7), h.hash(12345, 7));
    }
}
