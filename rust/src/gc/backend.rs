//! The circuit-program abstraction: boolean operations generic over an
//! interpreting backend.
//!
//! A *program* (e.g. "Cholesky-decompose a p×p fixed-point matrix") is
//! ordinary Rust code over [`GcBackend`] operations. Running it under
//! [`PlainBackend`] evaluates in the clear (the correctness oracle);
//! under [`CountBackend`] it counts non-free gates (feeding the §5.2 cost
//! model *exactly*, not asymptotically); under [`crate::gc::garble`]'s
//! `Garbler`/`Evaluator` it produces/consumes a streamed garbled circuit.
//!
//! Programs must be **data-oblivious and deterministic**: both Center
//! servers execute the same op sequence. All control flow depends only on
//! public values (dimensions, formats, public constants). That invariant
//! is what makes streamed garbling possible (no circuit materialization).

/// A boolean-circuit interpreter.
pub trait GcBackend {
    /// Wire handle. `Copy` keeps word-level code ergonomic.
    type Wire: Copy;

    /// A public constant wire.
    fn constant(&mut self, v: bool) -> Self::Wire;
    /// XOR (free under free-XOR garbling).
    fn xor(&mut self, a: Self::Wire, b: Self::Wire) -> Self::Wire;
    /// AND (the costly gate: 2 ciphertexts, 4/2 AES calls).
    fn and(&mut self, a: Self::Wire, b: Self::Wire) -> Self::Wire;
    /// NOT (free).
    fn not(&mut self, a: Self::Wire) -> Self::Wire;

    /// OR via De Morgan (1 AND).
    fn or(&mut self, a: Self::Wire, b: Self::Wire) -> Self::Wire {
        let na = self.not(a);
        let nb = self.not(b);
        let n = self.and(na, nb);
        self.not(n)
    }

    /// 2-to-1 multiplexer: `s ? a : b` (1 AND).
    fn mux(&mut self, s: Self::Wire, a: Self::Wire, b: Self::Wire) -> Self::Wire {
        let d = self.xor(a, b);
        let sd = self.and(s, d);
        self.xor(sd, b)
    }
}

/// Plaintext interpreter — wires are actual booleans.
#[derive(Default)]
pub struct PlainBackend;

impl GcBackend for PlainBackend {
    type Wire = bool;

    fn constant(&mut self, v: bool) -> bool {
        v
    }
    fn xor(&mut self, a: bool, b: bool) -> bool {
        a ^ b
    }
    fn and(&mut self, a: bool, b: bool) -> bool {
        a & b
    }
    fn not(&mut self, a: bool) -> bool {
        !a
    }
}

/// Gate-counting interpreter.
///
/// Wires carry a constant-ness flag so that the same constant-folding the
/// garbler performs is reflected in the counts (AND with a public constant
/// is free — this is exactly why PrivLogit-Local's multiply-by-constant is
/// cheap, the asymmetry the paper exploits).
#[derive(Default)]
pub struct CountBackend {
    /// Non-free (AND) gates executed.
    pub ands: u64,
    /// Free (XOR/NOT) gates executed.
    pub frees: u64,
}

/// Count-backend wire: `Some(v)` = public constant, `None` = secret.
pub type CountWire = Option<bool>;

impl GcBackend for CountBackend {
    type Wire = CountWire;

    fn constant(&mut self, v: bool) -> CountWire {
        Some(v)
    }

    fn xor(&mut self, a: CountWire, b: CountWire) -> CountWire {
        match (a, b) {
            (Some(x), Some(y)) => Some(x ^ y),
            _ => {
                self.frees += 1;
                None
            }
        }
    }

    fn and(&mut self, a: CountWire, b: CountWire) -> CountWire {
        match (a, b) {
            (Some(false), _) | (_, Some(false)) => Some(false),
            (Some(true), other) | (other, Some(true)) => other,
            _ => {
                self.ands += 1;
                None
            }
        }
    }

    fn not(&mut self, a: CountWire) -> CountWire {
        self.frees += 1;
        a.map(|v| !v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_gates() {
        let mut b = PlainBackend;
        let t = b.constant(true);
        let f = b.constant(false);
        assert!(b.xor(t, f));
        assert!(!b.xor(t, t));
        assert!(b.and(t, t));
        assert!(!b.and(t, f));
        assert!(b.or(f, t));
        assert!(!b.not(t));
        assert!(b.mux(t, t, f));
        assert!(!b.mux(f, t, f));
    }

    #[test]
    fn count_constant_folding() {
        let mut b = CountBackend::default();
        let secret: CountWire = None;
        let zero = b.constant(false);
        let one = b.constant(true);
        // AND with constants must be free.
        assert_eq!(b.and(secret, zero), Some(false));
        assert_eq!(b.and(secret, one), None);
        assert_eq!(b.ands, 0);
        // secret AND secret costs one gate
        b.and(secret, secret);
        assert_eq!(b.ands, 1);
        // mux with secret selector: 1 AND
        b.mux(secret, secret, secret);
        assert_eq!(b.ands, 2);
    }
}
