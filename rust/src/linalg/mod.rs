//! Dense linear algebra substrate (row-major `f64`).
//!
//! Used for ground-truth optimizers, node-side fallbacks when PJRT
//! artifacts are not built, and as the numeric oracle the secure
//! fixed-point pipeline is validated against.

use std::ops::{Index, IndexMut};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Rows.
    pub rows: usize,
    /// Columns.
    pub cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Underlying row-major slice.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable row-major slice.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix product `self · other`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Gram matrix `selfᵀ · self` (symmetric; exploits symmetry).
    pub fn gram(&self) -> Matrix {
        let p = self.cols;
        let mut g = Matrix::zeros(p, p);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..p {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in i..p {
                    g[(i, j)] += xi * row[j];
                }
            }
        }
        for i in 0..p {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// `self + s·I` in place (regularization).
    pub fn add_diag(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += s;
        }
    }

    /// Element-wise addition.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Cholesky decomposition of a symmetric positive-definite matrix:
    /// returns lower-triangular `L` with `L·Lᵀ = self`.
    pub fn cholesky(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "cholesky needs square");
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for j in 0..n {
            let mut d = self[(j, j)];
            for k in 0..j {
                d -= l[(j, k)] * l[(j, k)];
            }
            if d <= 0.0 {
                return None; // not PD
            }
            let dj = d.sqrt();
            l[(j, j)] = dj;
            for i in j + 1..n {
                let mut s = self[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                l[(i, j)] = s / dj;
            }
        }
        Some(l)
    }

    /// Solve `self · x = b` for symmetric positive-definite `self` via
    /// Cholesky (two triangular solves).
    pub fn solve_spd(&self, b: &[f64]) -> Option<Vec<f64>> {
        let l = self.cholesky()?;
        Some(l.solve_cholesky(b))
    }

    /// Given `self = L` lower-triangular from Cholesky, solve
    /// `L·Lᵀ·x = b` (forward then backward substitution).
    pub fn solve_cholesky(&self, b: &[f64]) -> Vec<f64> {
        let n = self.rows;
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self[(i, k)] * y[k];
            }
            y[i] = s / self[(i, i)];
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self[(k, i)] * x[k];
            }
            x[i] = s / self[(i, i)];
        }
        x
    }

    /// Inverse of an SPD matrix via Cholesky (used to materialize
    /// `H̃⁻¹` for PrivLogit-Local).
    pub fn inverse_spd(&self) -> Option<Matrix> {
        let n = self.rows;
        let l = self.cholesky()?;
        let mut inv = Matrix::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = l.solve_cholesky(&e);
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        Some(inv)
    }

    /// Max absolute element difference (test helper / convergence).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `a − b` element-wise.
pub fn vsub(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// `a + b` element-wise.
pub fn vadd(a: &[f64], b: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// Scale a vector.
pub fn vscale(a: &[f64], s: f64) -> Vec<f64> {
    a.iter().map(|x| x * s).collect()
}

/// L2 norm.
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Pearson R² between two vectors (the paper's Fig. 2 metric).
pub fn r_squared(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma).powi(2)).sum();
    let vb: f64 = b.iter().map(|y| (y - mb).powi(2)).sum();
    if va == 0.0 || vb == 0.0 {
        return if a == b { 1.0 } else { 0.0 };
    }
    (cov * cov) / (va * vb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_all_close, assert_close, TestRng};

    fn random_spd(rng: &mut TestRng, n: usize) -> Matrix {
        // A = B·Bᵀ + n·I is SPD
        let mut b = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.gaussian();
            }
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn matmul_identity() {
        let mut rng = TestRng::new(1);
        let a = random_spd(&mut rng, 5);
        let i = Matrix::identity(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn gram_matches_matmul() {
        let mut rng = TestRng::new(2);
        let mut x = Matrix::zeros(20, 6);
        for v in x.as_mut_slice() {
            *v = rng.gaussian();
        }
        let g1 = x.gram();
        let g2 = x.transpose().matmul(&x);
        assert!(g1.max_abs_diff(&g2) < 1e-10);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = TestRng::new(3);
        for n in [1, 2, 5, 12] {
            let a = random_spd(&mut rng, n);
            let l = a.cholesky().expect("SPD");
            let rec = l.matmul(&l.transpose());
            assert!(rec.max_abs_diff(&a) < 1e-9, "n={n}");
            // lower triangular
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Matrix::from_rows(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig −1, 3
        assert!(m.cholesky().is_none());
    }

    #[test]
    fn solve_spd_property() {
        let mut rng = TestRng::new(4);
        for n in [1, 3, 8] {
            let a = random_spd(&mut rng, n);
            let x_true: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
            let b = a.matvec(&x_true);
            let x = a.solve_spd(&b).unwrap();
            assert_all_close(&x, &x_true, 1e-8, "solve_spd");
        }
    }

    #[test]
    fn inverse_spd_property() {
        let mut rng = TestRng::new(5);
        let a = random_spd(&mut rng, 7);
        let inv = a.inverse_spd().unwrap();
        assert!(a.matmul(&inv).max_abs_diff(&Matrix::identity(7)) < 1e-8);
    }

    #[test]
    fn r_squared_perfect_and_imperfect() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![2.0, 4.0, 6.0, 8.0];
        assert_close(r_squared(&a, &b), 1.0, 1e-12, "linear => R²=1");
        let c = vec![1.0, -2.0, 3.5, 0.0];
        assert!(r_squared(&a, &c) < 0.9);
    }

    #[test]
    fn vector_helpers() {
        assert_close(dot(&[1., 2.], &[3., 4.]), 11.0, 1e-12, "dot");
        assert_eq!(vsub(&[3., 4.], &[1., 1.]), vec![2., 3.]);
        assert_eq!(vadd(&[3., 4.], &[1., 1.]), vec![4., 5.]);
        assert_close(norm2(&[3., 4.]), 5.0, 1e-12, "norm");
    }
}
