//! PJRT runtime: load and execute the AOT HLO artifacts.
//!
//! `make artifacts` lowers the L2 JAX functions (which call the L1 Pallas
//! kernels) to HLO text once, at build time. This module is everything the
//! request path needs: parse `artifacts/manifest.txt`, compile each HLO
//! module on the PJRT CPU client (once, cached), and execute them on node
//! data — python never runs here.
//!
//! Shapes are static per artifact: row tiles of `TILE_N` and feature pads
//! from the manifest. [`PjrtCompute`] pads rows with `w = 0` (masked, so
//! padding is exact — tested in `python/tests`) and features with zero
//! columns, then accumulates per-tile partial statistics host-side.
//!
//! [`CpuCompute`] is the pure-rust fallback (identical results via
//! [`crate::optim`]) used when artifacts are absent; every experiment
//! records which engine produced it. (In this build image the PJRT
//! bindings themselves are stubbed — see `runtime::xla` — so the
//! fallback is always taken; the seam is unchanged.)
//!
//! This module also hosts [`pool`], the crate-wide scoped-thread worker
//! pool used by the Paillier hot paths (`PRIVLOGIT_THREADS`).

pub mod pool;
mod xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::optim::{local_gram_quarter, local_hessian, local_stats};

/// Row-tile height — must match `python/compile/aot.py::TILE_N`.
pub const TILE_N: usize = 256;

/// Node-local statistics engine: the per-iteration plaintext compute of
/// every organization (paper Eq. 4/5/6/9), pre-scaled by `scale = 1/n_total`.
pub trait NodeCompute {
    /// Fused gradient + log-likelihood at `beta`, times `scale`.
    fn stats(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> (Vec<f64>, f64);
    /// PrivLogit surrogate-Hessian share `¼XᵀX · scale`.
    fn gram_quarter(&mut self, data: &Dataset, scale: f64) -> Matrix;
    /// Exact Hessian share `XᵀAX · scale` (Newton baseline).
    fn hessian(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> Matrix;
    /// Engine label for reports.
    fn label(&self) -> &'static str;
}

/// Pure-rust fallback engine.
#[derive(Default)]
pub struct CpuCompute;

impl NodeCompute for CpuCompute {
    fn stats(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> (Vec<f64>, f64) {
        let s = local_stats(data, beta);
        (s.grad.iter().map(|v| v * scale).collect(), s.loglik * scale)
    }

    fn gram_quarter(&mut self, data: &Dataset, scale: f64) -> Matrix {
        let mut g = local_gram_quarter(data);
        g.scale(scale);
        g
    }

    fn hessian(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> Matrix {
        let mut h = local_hessian(data, beta);
        h.scale(scale);
        h
    }

    fn label(&self) -> &'static str {
        "cpu (rust fallback)"
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
struct ArtifactMeta {
    name: String,
    p_pad: usize,
    path: PathBuf,
}

/// PJRT-backed engine executing the AOT artifacts.
pub struct PjrtCompute {
    client: xla::PjRtClient,
    metas: Vec<ArtifactMeta>,
    /// Compiled executables, keyed by (function name, p_pad).
    cache: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    /// Executions performed (metrics).
    pub executions: u64,
}

impl PjrtCompute {
    /// Open the artifact directory (expects `manifest.txt` from
    /// `make artifacts`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("manifest.txt in {dir:?} — run `make artifacts`"))?;
        let mut metas = Vec::new();
        for line in manifest.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("malformed manifest line: {line}");
            }
            let tile: usize = parts[1].parse()?;
            if tile != TILE_N {
                bail!("artifact tile {tile} != runtime TILE_N {TILE_N}");
            }
            metas.push(ArtifactMeta {
                name: parts[0].to_string(),
                p_pad: parts[2].parse()?,
                path: dir.join(parts[3]),
            });
        }
        if metas.is_empty() {
            bail!("empty manifest in {dir:?}");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtCompute { client, metas, cache: HashMap::new(), executions: 0 })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new("artifacts"))
    }

    /// Smallest feature pad covering `p`.
    fn pad_for(&self, p: usize) -> Result<usize> {
        self.metas
            .iter()
            .filter(|m| m.p_pad >= p)
            .map(|m| m.p_pad)
            .min()
            .ok_or_else(|| anyhow!("no artifact pads p={p}"))
    }

    fn executable(&mut self, name: &str, p_pad: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (name.to_string(), p_pad);
        if !self.cache.contains_key(&key) {
            let meta = self
                .metas
                .iter()
                .find(|m| m.name == name && m.p_pad == p_pad)
                .ok_or_else(|| anyhow!("artifact {name} p{p_pad} missing"))?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().expect("utf8 path"),
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name} p{p_pad}: {e:?}"))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(&key).unwrap())
    }

    /// Pad one row tile: returns (x_tile row-major f32, y, w) of exactly
    /// TILE_N × p_pad.
    fn tile_inputs(
        data: &Dataset,
        row0: usize,
        p_pad: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let p = data.p();
        let mut x = vec![0f32; TILE_N * p_pad];
        let mut y = vec![0f32; TILE_N];
        let mut w = vec![0f32; TILE_N];
        for i in 0..TILE_N {
            let r = row0 + i;
            if r >= data.n() {
                break;
            }
            let row = data.x.row(r);
            for j in 0..p {
                x[i * p_pad + j] = row[j] as f32;
            }
            y[i] = data.y[r] as f32;
            w[i] = 1.0;
        }
        (x, y, w)
    }

    fn run(
        &mut self,
        name: &str,
        p_pad: usize,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.executions += 1;
        let exe = self.executable(name, p_pad)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        result.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    fn literal_matrix(vals: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        xla::Literal::vec1(vals)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Fallible fused stats (the trait wrapper panics on artifact bugs —
    /// callers that want graceful degradation use this).
    pub fn try_stats(
        &mut self,
        data: &Dataset,
        beta: &[f64],
        scale: f64,
    ) -> Result<(Vec<f64>, f64)> {
        let p = data.p();
        let p_pad = self.pad_for(p)?;
        let mut beta_pad = vec![0f32; p_pad];
        for (b, &v) in beta_pad.iter_mut().zip(beta) {
            *b = v as f32;
        }
        let mut g = vec![0f64; p];
        let mut l = 0f64;
        let mut row0 = 0;
        while row0 < data.n() {
            let (x, y, w) = Self::tile_inputs(data, row0, p_pad);
            let xs = Self::literal_matrix(&x, TILE_N, p_pad)?;
            let ys = xla::Literal::vec1(&y);
            let ws = xla::Literal::vec1(&w);
            let bs = xla::Literal::vec1(&beta_pad);
            let sc = xla::Literal::scalar(scale as f32);
            let out = self.run("node_stats", p_pad, &[xs, ys, ws, bs, sc])?;
            let gv = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            let lv = out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            for j in 0..p {
                g[j] += gv[j] as f64;
            }
            l += lv[0] as f64;
            row0 += TILE_N;
        }
        Ok((g, l))
    }

    /// Fallible Gram share.
    pub fn try_gram_quarter(&mut self, data: &Dataset, scale: f64) -> Result<Matrix> {
        self.try_matrix_stat("node_gram", data, None, scale)
    }

    /// Fallible exact-Hessian share.
    pub fn try_hessian(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> Result<Matrix> {
        self.try_matrix_stat("node_hessian", data, Some(beta), scale)
    }

    fn try_matrix_stat(
        &mut self,
        name: &str,
        data: &Dataset,
        beta: Option<&[f64]>,
        scale: f64,
    ) -> Result<Matrix> {
        let p = data.p();
        let p_pad = self.pad_for(p)?;
        let beta_pad: Vec<f32> = beta
            .map(|b| {
                let mut v = vec![0f32; p_pad];
                for (o, &x) in v.iter_mut().zip(b) {
                    *o = x as f32;
                }
                v
            })
            .unwrap_or_default();
        let mut acc = Matrix::zeros(p, p);
        let mut row0 = 0;
        while row0 < data.n() {
            let (x, _y, w) = Self::tile_inputs(data, row0, p_pad);
            let xs = Self::literal_matrix(&x, TILE_N, p_pad)?;
            let ws = xla::Literal::vec1(&w);
            let sc = xla::Literal::scalar(scale as f32);
            let inputs: Vec<xla::Literal> = if beta.is_some() {
                vec![xs, ws, xla::Literal::vec1(&beta_pad), sc]
            } else {
                vec![xs, ws, sc]
            };
            let out = self.run(name, p_pad, &inputs)?;
            let m = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
            for i in 0..p {
                for j in 0..p {
                    acc[(i, j)] += m[i * p_pad + j] as f64;
                }
            }
            row0 += TILE_N;
        }
        Ok(acc)
    }
}

impl NodeCompute for PjrtCompute {
    fn stats(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> (Vec<f64>, f64) {
        self.try_stats(data, beta, scale).expect("PJRT node_stats")
    }

    fn gram_quarter(&mut self, data: &Dataset, scale: f64) -> Matrix {
        self.try_gram_quarter(data, scale).expect("PJRT node_gram")
    }

    fn hessian(&mut self, data: &Dataset, beta: &[f64], scale: f64) -> Matrix {
        self.try_hessian(data, beta, scale).expect("PJRT node_hessian")
    }

    fn label(&self) -> &'static str {
        "pjrt (AOT JAX/Pallas artifacts)"
    }
}

/// Open the PJRT engine if artifacts exist, else fall back to CPU —
/// logging the choice. The request path never imports python either way.
pub fn default_engine() -> Box<dyn NodeCompute> {
    match PjrtCompute::open_default() {
        Ok(e) => Box::new(e),
        Err(err) => {
            eprintln!("[runtime] PJRT artifacts unavailable ({err:#}); using CPU fallback");
            Box::new(CpuCompute)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthesize;
    use crate::testutil::{assert_all_close, assert_close};

    fn artifacts_present() -> bool {
        Path::new("artifacts/manifest.txt").exists()
    }

    #[test]
    fn cpu_engine_matches_optim() {
        let d = synthesize("t", 700, 6, 21);
        let beta = vec![0.05; 6];
        let mut eng = CpuCompute;
        let (g, l) = eng.stats(&d, &beta, 1.0 / 700.0);
        let s = local_stats(&d, &beta);
        assert_all_close(
            &g,
            &s.grad.iter().map(|v| v / 700.0).collect::<Vec<_>>(),
            1e-12,
            "cpu grad",
        );
        assert_close(l, s.loglik / 700.0, 1e-12, "cpu loglik");
    }

    /// The heart of the three-layer claim: PJRT-executed Pallas artifacts
    /// reproduce the rust reference on real (non-tile-aligned) data.
    #[test]
    fn pjrt_matches_cpu_engine() {
        if !artifacts_present() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut pjrt = PjrtCompute::open_default().expect("open artifacts");
        let mut cpu = CpuCompute;
        // n deliberately not a multiple of TILE_N; p not a pad size
        let d = synthesize("t", 700, 11, 22);
        let beta: Vec<f64> = (0..11).map(|j| 0.1 * (j as f64 - 5.0)).collect();
        let scale = 1.0 / 700.0;

        let (g_p, l_p) = pjrt.stats(&d, &beta, scale);
        let (g_c, l_c) = cpu.stats(&d, &beta, scale);
        assert_all_close(&g_p, &g_c, 1e-4, "pjrt vs cpu grad");
        assert_close(l_p, l_c, 1e-4, "pjrt vs cpu loglik");

        let gm_p = pjrt.gram_quarter(&d, scale);
        let gm_c = cpu.gram_quarter(&d, scale);
        assert!(gm_p.max_abs_diff(&gm_c) < 1e-4, "gram diff");

        let h_p = pjrt.hessian(&d, &beta, scale);
        let h_c = cpu.hessian(&d, &beta, scale);
        assert!(h_p.max_abs_diff(&h_c) < 1e-4, "hessian diff");
        assert!(pjrt.executions >= 9, "tiled executions: {}", pjrt.executions);
    }

    #[test]
    fn pjrt_pad_selection() {
        if !artifacts_present() {
            return;
        }
        let pjrt = PjrtCompute::open_default().unwrap();
        assert_eq!(pjrt.pad_for(12).unwrap(), 16);
        assert_eq!(pjrt.pad_for(16).unwrap(), 16);
        assert_eq!(pjrt.pad_for(33).unwrap(), 64);
        assert_eq!(pjrt.pad_for(400).unwrap(), 512);
        assert!(pjrt.pad_for(1000).is_err());
    }
}
