//! Stub PJRT bindings.
//!
//! The real `xla` crate (PJRT C-API bindings for executing the AOT HLO
//! artifacts) is not available in this build image, so this module
//! provides the same surface with a client constructor that reports
//! unavailability. [`super::PjrtCompute::open`] therefore fails cleanly
//! and [`super::default_engine`] falls back to the pure-rust
//! [`super::CpuCompute`] — the degradation path the runtime was designed
//! around. Re-enabling real PJRT execution means deleting this module
//! and adding the `xla` crate to `Cargo.toml`; no call site changes.

/// Error type mirroring the binding crate's (only its `Debug` rendering
/// is consumed by the runtime layer).
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error("PJRT bindings are not built into this binary".to_string()))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client — unavailable in this build.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Compile a computation — unreachable while `cpu()` fails.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with on-host literals — unreachable in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to a host literal — unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Parsed HLO module text (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an `.hlo.txt` artifact — unavailable in this build.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A host literal (stub constructors so call sites type-check).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    /// 1-D f32 literal.
    pub fn vec1(_vals: &[f32]) -> Literal {
        Literal
    }

    /// Scalar f32 literal.
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    /// Reshape — unreachable in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Extract a host vector — unreachable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    /// Untuple — unreachable in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}
