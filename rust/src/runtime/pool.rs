//! Dependency-free data parallelism over `std::thread::scope`.
//!
//! The crate deliberately carries no heavy dependencies (no rayon), but
//! the Paillier hot paths — batch encryption, per-row `Enc(H̃⁻¹) ⊗ g`
//! multi-exponentiation, per-element ciphertext aggregation — are
//! embarrassingly parallel. This module is the one shared primitive
//! they use: a bounded fan-out of scoped worker threads over an index
//! range, with results collected in index order so every parallel path
//! is **bit-identical** to its sequential execution.
//!
//! Worker count: callers pass an explicit count (tests pin 1 vs N to
//! prove determinism); [`threads`] reads the `PRIVLOGIT_THREADS`
//! environment variable and falls back to the machine's available
//! parallelism.
//!
//! Ledger note: callers attribute *wall* seconds measured around the
//! parallel section (never summed per-thread time), so cost accounting
//! stays exact whatever the worker count.

/// Worker count for parallel sections: `PRIVLOGIT_THREADS` if set to a
/// positive integer, else the machine's available parallelism, else 1.
/// (An unset, zero or unparsable variable falls through to the machine
/// default rather than silently degrading to one worker.)
pub fn threads() -> usize {
    std::env::var("PRIVLOGIT_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Map `f` over `0..n` using at most `workers` scoped threads, returning
/// results in index order. `workers <= 1` (or `n <= 1`) runs inline on
/// the calling thread — the two executions produce identical results,
/// since `f(i)` must not depend on evaluation order.
pub fn par_map_indexed<U, F>(n: usize, workers: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    // Trace only genuinely fanned-out sections (the single-worker early
    // return above stays span-free): with tracing disabled this is one
    // relaxed atomic load, and tracing never reorders the work — slots
    // are filled in index order regardless.
    let _sp =
        crate::obs::span("pool.par_map").u64("n", n as u64).u64("workers", workers as u64);
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = Vec::new();
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (c, slots) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = c * chunk;
                for (off, slot) in slots.iter_mut().enumerate() {
                    *slot = Some(f(base + off));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_index_order() {
        for workers in [1usize, 2, 3, 8, 64] {
            let got = par_map_indexed(17, workers, |i| i * i);
            let expect: Vec<usize> = (0..17).map(|i| i * i).collect();
            assert_eq!(got, expect, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(par_map_indexed(0, 8, |i| i), Vec::<usize>::new());
        assert_eq!(par_map_indexed(1, 8, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_equals_sequential_on_heavyish_work() {
        let work = |i: usize| {
            let mut acc = i as u64;
            for _ in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            acc
        };
        assert_eq!(par_map_indexed(33, 4, work), par_map_indexed(33, 1, work));
    }

    #[test]
    fn threads_is_positive() {
        assert!(threads() >= 1);
    }
}
