//! Two-server secure computation layer (the Center of Figure 1).
//!
//! Composes the Paillier layer ([`crate::crypto`]) and the garbled-circuit
//! engine ([`crate::gc`]) into the operations the paper's protocols need:
//!
//! * share conversion (Paillier ⇄ additive shares mod 2^w, blinded
//!   decryption after Nikolaenko et al. 2013);
//! * secure Cholesky, back-substitution, matrix inversion and comparison
//!   as garbled programs ([`circuits`]);
//! * the [`fabric::SecureFabric`] facade with a fully-executed backend
//!   ([`fabric::RealFabric`]) and a calibrated cost-model backend
//!   ([`fabric::ModelFabric`]) for paper-scale sweeps ([`costmodel`]);
//! * the two Center servers as separate OS processes ([`peer`]): a
//!   serializable program spec plus the S1 client / S2 server halves
//!   behind `privlogit center-a` / `center-b` — center-b aggregates
//!   relayed node ciphertexts, draws its own blinds and keeps its own
//!   additive shares ([`fabric::S2Custody`]); share material never
//!   crosses the peer wire.

pub mod circuits;
pub mod costmodel;
pub mod fabric;
pub mod peer;

pub use circuits::{tri_idx, tri_len};
pub use costmodel::{CostLedger, CostModel};
pub use fabric::{
    EncData, EncMat, EncVec, ModelFabric, PreparedHinv, RealFabric, S2Custody, SecVec,
    SecureFabric, ShareLink, ShareVec, Shared,
};
pub use peer::{PeerCensus, PeerGcClient, PeerGcServer, ProgSpec};
