//! The two Center servers as separate OS processes.
//!
//! The paper's Figure 1 deploys the Center as *two mutually untrusting
//! servers*: S1 garbles and holds the Paillier key, S2 evaluates and
//! aggregates. In-process they are the two halves of
//! [`GcSession::execute`](crate::gc::exec::GcSession) running on scoped
//! threads; this module puts the evaluator half behind a real TCP
//! endpoint so `privlogit center-a` (garbler + protocol driver) and
//! `privlogit center-b` (evaluator) run as genuinely separate processes:
//!
//! * [`ProgSpec`] — a serializable description of the five garbled
//!   programs ([`crate::mpc::circuits`]), so center-b can reconstruct
//!   the exact circuit center-a is about to garble (garbling is
//!   streamed; both sides must walk the same deterministic program).
//! * [`PeerGcClient`] — center-a's end: sends a
//!   [`WireMsg::GcExec`] control frame, runs
//!   [`run_garbler`](crate::gc::exec::run_garbler) over the same
//!   channel, then reads the [`WireMsg::GcOut`] output bits.
//! * [`PeerGcServer`] — center-b's end: answers each `GcExec` by running
//!   [`run_evaluator`](crate::gc::exec::run_evaluator) and returning the
//!   decoded output bits.
//!
//! Everything — control frames, garbled tables, OT extension, decode
//! bits — crosses one framed, CRC-checked TCP connection (handshake role
//! [`wire::ROLE_PEER`]). Control frames travel as length-prefixed
//! [`Channel`] blobs, and the two phases strictly alternate, so the byte
//! stream never desynchronizes.
//!
//! Honest scope note (see `docs/ARCHITECTURE.md`): this splits the GC
//! *transport and execution* across processes. The protocol driver in
//! center-a still computes both servers' additive shares and ships
//! center-b its evaluator inputs, exactly as the in-process simulation
//! does — custody of the shares is not yet split.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::time::{Duration, Instant};

use super::circuits::{
    CholeskyShareProg, ConvergedProg, InverseMaskedProg, NewtonStepProg, SolveProg,
};
use crate::crypto::rng::ChaChaRng;
use crate::gc::channel::Channel;
use crate::gc::exec::{run_evaluator, run_garbler, ExecStats, GcSession};
use crate::gc::ot::{OtReceiver, OtSender};
use crate::gc::word::FixedFmt;
use crate::net::tcp::{tcp_channel, TcpTransport};
use crate::net::wire::{self, WireMsg};

/// How long [`PeerGcClient::connect`] retries the center-b address
/// (covers start-up ordering between the two center processes).
pub const PEER_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// A wire-serializable description of one garbled program — everything
/// center-b needs to reconstruct the circuit (`fmt` travels separately
/// in the [`WireMsg::GcExec`] frame).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProgSpec {
    /// Full Newton step: Cholesky + solve, Δ revealed.
    Newton {
        /// Dimensionality.
        p: usize,
    },
    /// Cholesky with re-shared (masked) output.
    CholeskyShare {
        /// Dimensionality.
        p: usize,
    },
    /// Back-substitution on shared `L`, Δ revealed.
    Solve {
        /// Dimensionality.
        p: usize,
    },
    /// `H⁻¹` with Paillier-ready masked wide reveal.
    InverseMasked {
        /// Dimensionality.
        p: usize,
    },
    /// Single-bit relative-convergence check.
    Converged {
        /// Relative tolerance.
        tol: f64,
    },
}

impl ProgSpec {
    /// Wire kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            ProgSpec::Newton { .. } => 1,
            ProgSpec::CholeskyShare { .. } => 2,
            ProgSpec::Solve { .. } => 3,
            ProgSpec::InverseMasked { .. } => 4,
            ProgSpec::Converged { .. } => 5,
        }
    }

    /// Dimensionality parameter (0 for the convergence check).
    pub fn p(&self) -> usize {
        match *self {
            ProgSpec::Newton { p }
            | ProgSpec::CholeskyShare { p }
            | ProgSpec::Solve { p }
            | ProgSpec::InverseMasked { p } => p,
            ProgSpec::Converged { .. } => 0,
        }
    }

    /// Tolerance parameter (0 except for the convergence check).
    pub fn tol(&self) -> f64 {
        match *self {
            ProgSpec::Converged { tol } => tol,
            _ => 0.0,
        }
    }

    /// Rebuild from wire parts; `None` for an unknown kind byte.
    pub fn from_parts(kind: u8, p: usize, tol: f64) -> Option<ProgSpec> {
        match kind {
            1 => Some(ProgSpec::Newton { p }),
            2 => Some(ProgSpec::CholeskyShare { p }),
            3 => Some(ProgSpec::Solve { p }),
            4 => Some(ProgSpec::InverseMasked { p }),
            5 => Some(ProgSpec::Converged { tol }),
            _ => None,
        }
    }
}

/// Run the garbler half for `spec` (monomorphized dispatch over the five
/// concrete programs).
fn garble_spec(
    spec: &ProgSpec,
    fmt: FixedFmt,
    chan: &mut Channel,
    ot: &mut OtSender,
    bits: &[bool],
    exec_seed: u64,
    gate_ctr: u64,
) -> (u64, u64) {
    match *spec {
        ProgSpec::Newton { p } => {
            run_garbler(chan, ot, &NewtonStepProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::CholeskyShare { p } => {
            run_garbler(chan, ot, &CholeskyShareProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::Solve { p } => {
            run_garbler(chan, ot, &SolveProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::InverseMasked { p } => {
            run_garbler(chan, ot, &InverseMaskedProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::Converged { tol } => {
            run_garbler(chan, ot, &ConvergedProg { fmt, tol }, bits, exec_seed, gate_ctr)
        }
    }
}

/// Run the evaluator half for `spec` (center-b side of [`garble_spec`]).
fn evaluate_spec(
    spec: &ProgSpec,
    fmt: FixedFmt,
    chan: &mut Channel,
    ot: &mut OtReceiver,
    bits: &[bool],
    gate_ctr: u64,
) -> (Vec<bool>, u64) {
    match *spec {
        ProgSpec::Newton { p } => {
            run_evaluator(chan, ot, &NewtonStepProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::CholeskyShare { p } => {
            run_evaluator(chan, ot, &CholeskyShareProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::Solve { p } => {
            run_evaluator(chan, ot, &SolveProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::InverseMasked { p } => {
            run_evaluator(chan, ot, &InverseMaskedProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::Converged { tol } => {
            run_evaluator(chan, ot, &ConvergedProg { fmt, tol }, bits, gate_ctr)
        }
    }
}

/// Execute `spec` on an in-process [`GcSession`] (both halves on scoped
/// threads) — the [`ProgSpec`]-dispatch twin of [`PeerGcClient::execute`]
/// used by the single-process and loopback center links.
pub fn execute_local(
    session: &mut GcSession,
    spec: &ProgSpec,
    fmt: FixedFmt,
    garbler_bits: &[bool],
    evaluator_bits: &[bool],
) -> (Vec<bool>, ExecStats) {
    match *spec {
        ProgSpec::Newton { p } => {
            session.execute(&NewtonStepProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::CholeskyShare { p } => {
            session.execute(&CholeskyShareProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::Solve { p } => {
            session.execute(&SolveProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::InverseMasked { p } => {
            session.execute(&InverseMaskedProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::Converged { tol } => {
            session.execute(&ConvergedProg { fmt, tol }, garbler_bits, evaluator_bits)
        }
    }
}

/// Center-a's connection to a remote center-b evaluator: the garbler's
/// persistent state (base OTs done once at connect) plus the shared
/// AND-gate counter both processes advance in lockstep.
pub struct PeerGcClient {
    chan: Channel,
    ot_send: OtSender,
    gate_ctr: u64,
    rng_seed: u64,
    execs: u64,
}

impl PeerGcClient {
    /// Connect to a `privlogit center-b` at `addr` (retrying for up to
    /// [`PEER_CONNECT_TIMEOUT`]) and run the IKNP base-OT phase.
    pub fn connect(addr: &str, seed: u64) -> io::Result<PeerGcClient> {
        let transport =
            TcpTransport::connect_retry(addr, wire::ROLE_PEER, PEER_CONNECT_TIMEOUT)?;
        let mut chan = tcp_channel(transport);
        let mut rng = ChaChaRng::from_u64_seed(seed ^ 0x5e55_1011);
        let ot_send = OtSender::setup(&mut chan, &mut rng);
        Ok(PeerGcClient { chan, ot_send, gate_ctr: 0, rng_seed: seed, execs: 0 })
    }

    /// Execute one garbled program against the remote evaluator; returns
    /// the output bits (decoded on center-b, returned in the
    /// [`WireMsg::GcOut`] control frame) and execution stats.
    ///
    /// Panics if center-b vanishes mid-program — the same loud-failure
    /// contract as every [`Channel`] user; `privlogit center-a` converts
    /// it into a clean CLI error at the top level.
    pub fn execute(
        &mut self,
        spec: &ProgSpec,
        fmt: FixedFmt,
        garbler_bits: &[bool],
        evaluator_bits: &[bool],
    ) -> (Vec<bool>, ExecStats) {
        let t0 = Instant::now();
        self.execs += 1;
        let exec_seed = self.rng_seed ^ self.execs.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let exec = WireMsg::GcExec {
            prog: spec.kind(),
            p: spec.p() as u32,
            w: fmt.w as u32,
            f: fmt.f,
            tol: spec.tol(),
            gate_ctr: self.gate_ctr,
            eval_bits: evaluator_bits.to_vec(),
        };
        self.chan.send_blob(&exec.encode());
        let (new_ctr, ands) = garble_spec(
            spec,
            fmt,
            &mut self.chan,
            &mut self.ot_send,
            garbler_bits,
            exec_seed,
            self.gate_ctr,
        );
        self.gate_ctr = new_ctr;
        let reply = self.chan.try_recv_blob().expect("center-b peer hung up mid-program");
        let bits = match WireMsg::decode(&reply) {
            Ok(WireMsg::GcOut { bits }) => bits,
            Ok(other) => panic!("center-b sent {other:?} where GcOut was expected"),
            Err(e) => panic!("center-b sent an undecodable control frame: {e}"),
        };
        let stats = ExecStats {
            ands,
            ot_bits: evaluator_bits.len() as u64,
            wall: t0.elapsed().as_secs_f64(),
        };
        (bits, stats)
    }

    /// Bytes sent to center-b so far (control + labels + tables + OT).
    pub fn bytes_sent(&self) -> u64 {
        self.chan.stats().snapshot().0
    }

    /// Bytes received from center-b so far (OT columns + output frames).
    pub fn bytes_received(&self) -> u64 {
        self.chan.stats().snapshot_recv().0
    }
}

impl Drop for PeerGcClient {
    fn drop(&mut self) {
        // Best-effort: let center-b exit its session loop cleanly. The
        // channel panics if the peer is already gone; a panic here (or
        // during unwind) must not abort the process.
        let chan = &mut self.chan;
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            chan.send_blob(&WireMsg::Shutdown.encode());
        }));
    }
}

/// The center-b process: a listening GC evaluator server. Each accepted
/// center-a connection gets a fresh OT session and is served to
/// completion (`Shutdown` or disconnect).
pub struct PeerGcServer {
    listener: TcpListener,
    seed: u64,
}

impl PeerGcServer {
    /// Bind to `addr` (port 0 for an ephemeral port). `seed` drives this
    /// server's own randomness (base-OT messages).
    pub fn bind(addr: &str, seed: u64) -> io::Result<PeerGcServer> {
        Ok(PeerGcServer { listener: TcpListener::bind(addr)?, seed })
    }

    /// The bound address (useful with ephemeral ports).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept one center-a connection and serve it to completion.
    pub fn serve_once(&mut self) -> io::Result<()> {
        let (stream, _) = self.listener.accept()?;
        let transport = TcpTransport::accept(stream, wire::ROLE_PEER)?;
        self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        serve_session(tcp_channel(transport), self.seed)
    }

    /// Serve center-a connections forever (one at a time). A failed
    /// *session* is logged and the next connection awaited; a failed
    /// *accept* means the listener itself is broken and is propagated.
    pub fn serve_forever(&mut self) -> io::Result<()> {
        loop {
            let (stream, _) = self.listener.accept()?;
            self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let seed = self.seed;
            let session = TcpTransport::accept(stream, wire::ROLE_PEER)
                .map(tcp_channel)
                .and_then(|chan| serve_session(chan, seed));
            if let Err(e) = session {
                eprintln!("center-b session ended with error: {e}");
            }
        }
    }
}

/// Answer [`WireMsg::GcExec`] frames on one established center-a
/// connection until `Shutdown` or disconnect.
fn serve_session(mut chan: Channel, seed: u64) -> io::Result<()> {
    let mut rng = ChaChaRng::from_u64_seed(seed ^ 0x0e1e_2021);
    let mut ot_recv = OtReceiver::setup(&mut chan, &mut rng);
    loop {
        let blob = match chan.try_recv_blob() {
            Ok(b) => b,
            // EOF at a control boundary: center-a exited; orderly end.
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::UnexpectedEof
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::ConnectionAborted
                ) =>
            {
                return Ok(())
            }
            Err(e) => return Err(e),
        };
        match WireMsg::decode(&blob).map_err(io::Error::from)? {
            WireMsg::Shutdown => return Ok(()),
            WireMsg::GcExec { prog, p, w, f, tol, gate_ctr, eval_bits } => {
                let fmt = FixedFmt { w: w as usize, f };
                let spec = ProgSpec::from_parts(prog, p as usize, tol).ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("unknown garbled-program kind {prog:#04x}"),
                    )
                })?;
                let (bits, _ands) =
                    evaluate_spec(&spec, fmt, &mut chan, &mut ot_recv, &eval_bits, gate_ctr);
                chan.send_blob(&WireMsg::GcOut { bits }.encode());
            }
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("center-a sent {other:?}, which center-b does not serve"),
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::backend::PlainBackend;
    use crate::gc::exec::GcProgram;
    use crate::mpc::circuits::tri_len;
    use crate::mpc::fabric::share_vec;

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    /// Split-process GC (client garbler ↔ server evaluator over real
    /// loopback TCP) must produce bit-identical outputs to the plain
    /// backend oracle, across repeated executions on one session.
    #[test]
    fn peer_client_server_matches_plain_backend() {
        let mut server = PeerGcServer::bind("127.0.0.1:0", 7).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve_once().unwrap());

        let mut client = PeerGcClient::connect(&addr, 41).unwrap();
        let mut rng = crate::crypto::rng::ChaChaRng::from_u64_seed(9);
        let p = 3;

        for trial in 0..2 {
            // A well-conditioned SPD matrix and gradient, as shares.
            let mut tri = Vec::new();
            for i in 0..p {
                for j in 0..=i {
                    tri.push(if i == j { 2.0 + i as f64 } else { 0.25 });
                }
            }
            let g = vec![1.0, -0.5, 0.25];
            let h_shares = share_vec(FMT, &tri, &mut rng);
            let g_shares = share_vec(FMT, &g, &mut rng);
            let mut ga = Vec::new();
            let mut ea = Vec::new();
            for s in h_shares.iter().chain(&g_shares) {
                for i in 0..FMT.w {
                    ga.push((s.a >> i) & 1 == 1);
                    ea.push((s.b >> i) & 1 == 1);
                }
            }
            let spec = ProgSpec::Newton { p };
            let (bits, stats) = client.execute(&spec, FMT, &ga, &ea);
            assert!(stats.ands > 0, "trial {trial}: gates streamed");

            // Plain-backend oracle over the same inputs.
            let prog = NewtonStepProg { p, fmt: FMT };
            let mut pb = PlainBackend;
            let expect = prog.run(&mut pb, &ga, &ea);
            assert_eq!(bits, expect, "trial {trial}: remote GC != plain backend");
            assert_eq!(bits.len(), p * FMT.w);
            assert_eq!(tri.len(), tri_len(p));
        }

        assert!(client.bytes_sent() > 0 && client.bytes_received() > 0);
        drop(client); // sends Shutdown; server exits cleanly
        server_thread.join().unwrap();
    }
}
