//! The two Center servers as separate OS processes.
//!
//! The paper's Figure 1 deploys the Center as *two mutually untrusting
//! servers*: S1 garbles and holds the Paillier key, S2 evaluates and
//! aggregates. In-process they are the two halves of
//! [`GcSession::execute`](crate::gc::exec::GcSession) running on scoped
//! threads; this module puts the **whole S2 role** behind a real TCP
//! endpoint so `privlogit center-a` (garbler + protocol driver) and
//! `privlogit center-b` (evaluator + aggregator + share custodian) run
//! as genuinely separate processes:
//!
//! * [`ProgSpec`] — a serializable description of the five garbled
//!   programs ([`crate::mpc::circuits`]), so center-b can reconstruct
//!   the exact circuit center-a is about to garble (garbling is
//!   streamed; both sides must walk the same deterministic program).
//! * [`PeerGcClient`] — center-a's (S1's) end: installs the Paillier
//!   public key ([`WireMsg::SetKey`]), relays node ciphertexts for S2 to
//!   aggregate ([`WireMsg::Aggregate`]), requests blind conversions
//!   ([`WireMsg::Blind`]), and drives garbled executions
//!   ([`WireMsg::GcExec`]) that reference S2's *stored share handles*
//!   instead of shipping evaluator bits.
//! * [`PeerGcServer`] — center-b's end: a real S2. It `⊕`-aggregates
//!   relayed ciphertext vectors, draws its own blinds ρ for the
//!   blind-decryption conversion and **keeps its own additive shares**
//!   in a per-session store, feeds those shares into
//!   [`run_evaluator`](crate::gc::exec::run_evaluator) itself, stores
//!   masked Cholesky outputs as fresh shares, and encrypts its own
//!   masked wide outputs for the `Enc(H̃⁻¹)` materialization.
//!
//! Everything — control frames, garbled tables, OT extension, decode
//! bits — crosses one framed TCP connection (handshake role
//! [`wire::ROLE_PEER`]). Control frames travel as length-prefixed
//! [`Channel`] blobs, and the phases strictly alternate, so the byte
//! stream never desynchronizes.
//!
//! **Custody note** (see `docs/ARCHITECTURE.md`): S2's share halves and
//! blinds never cross this wire. The only frame that *can* carry share
//! values toward center-b is [`WireMsg::ShareInput`], which exists for
//! test drivers that legitimately hold both halves; a protocol run never
//! sends it, and the census test in `rust/tests/net_three_process.rs`
//! asserts exactly that. What center-a still sees is the relayed
//! per-node *ciphertexts* (it holds the decryption key, so the relay —
//! unlike direct node→S2 connections — leaves the "S1 does not decrypt
//! node ciphertexts" property procedural rather than structural).

use std::collections::{BTreeMap, HashMap};
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::time::{Duration, Instant};

use super::circuits::{
    CholeskyShareProg, ConvergedProg, InverseMaskedProg, NewtonStepProg, SolveProg, SIGMA,
};
use super::fabric::{blind_b_half, packed_blinds, words_of_bits};
use crate::bigint::{BigUint, RandomSource};
use crate::crypto::packed::{PackedCodec, PackedMeta};
use crate::crypto::paillier::{ChaChaSource, Ciphertext, PublicKey};
use crate::crypto::rng::ChaChaRng;
use crate::gc::channel::Channel;
use crate::gc::exec::{run_evaluator, run_garbler, ExecStats, GcProgram, GcSession};
use crate::gc::ot::{OtReceiver, OtSender};
use crate::gc::word::FixedFmt;
use crate::net::tcp::{tcp_channel, TcpTransport};
use crate::net::wire::{self, WireMsg};
use crate::obs;
use crate::runtime::pool;

/// How long [`PeerGcClient::connect`] retries the center-b address
/// (covers start-up ordering between the two center processes).
/// [`PeerGcClient::connect_with`] takes the configured value instead,
/// so the peer link honors the same `--connect-timeout` as the fleet.
pub const PEER_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// [`WireMsg::GcExec`] output mode: reveal the output bits to S1
/// (by-design-public values: Newton step, solve, convergence bit).
pub const OUT_REVEAL: u8 = 0;
/// Output mode: center-b stores the output bits as its own fresh share
/// halves under `out_handle` (Cholesky-with-reshare) and replies `Ack`.
pub const OUT_SHARE: u8 = 1;
/// Output mode: center-b assembles the masked wide outputs, encrypts
/// them itself, subtracts S1's randomized `Enc(C + r)` corrections and
/// replies with the finished ciphertexts (masked-inverse
/// materialization).
pub const OUT_ENCRYPT: u8 = 2;

/// A wire-serializable description of one garbled program — everything
/// center-b needs to reconstruct the circuit (`fmt` travels separately
/// in the [`WireMsg::GcExec`] frame).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProgSpec {
    /// Full Newton step: Cholesky + solve, Δ revealed.
    Newton {
        /// Dimensionality.
        p: usize,
    },
    /// Cholesky with re-shared (masked) output.
    CholeskyShare {
        /// Dimensionality.
        p: usize,
    },
    /// Back-substitution on shared `L`, Δ revealed.
    Solve {
        /// Dimensionality.
        p: usize,
    },
    /// `H⁻¹` with Paillier-ready masked wide reveal.
    InverseMasked {
        /// Dimensionality.
        p: usize,
    },
    /// Single-bit relative-convergence check.
    Converged {
        /// Relative tolerance.
        tol: f64,
    },
}

impl ProgSpec {
    /// Wire kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            ProgSpec::Newton { .. } => 1,
            ProgSpec::CholeskyShare { .. } => 2,
            ProgSpec::Solve { .. } => 3,
            ProgSpec::InverseMasked { .. } => 4,
            ProgSpec::Converged { .. } => 5,
        }
    }

    /// Dimensionality parameter (0 for the convergence check).
    pub fn p(&self) -> usize {
        match *self {
            ProgSpec::Newton { p }
            | ProgSpec::CholeskyShare { p }
            | ProgSpec::Solve { p }
            | ProgSpec::InverseMasked { p } => p,
            ProgSpec::Converged { .. } => 0,
        }
    }

    /// Tolerance parameter (0 except for the convergence check).
    pub fn tol(&self) -> f64 {
        match *self {
            ProgSpec::Converged { tol } => tol,
            _ => 0.0,
        }
    }

    /// Rebuild from wire parts; `None` for an unknown kind byte.
    pub fn from_parts(kind: u8, p: usize, tol: f64) -> Option<ProgSpec> {
        match kind {
            1 => Some(ProgSpec::Newton { p }),
            2 => Some(ProgSpec::CholeskyShare { p }),
            3 => Some(ProgSpec::Solve { p }),
            4 => Some(ProgSpec::InverseMasked { p }),
            5 => Some(ProgSpec::Converged { tol }),
            _ => None,
        }
    }
}

/// Evaluator input arity of `spec` — both sides derive it from the
/// program description, so S2 can validate its assembled share bits
/// before the streamed evaluation starts.
fn eval_arity(spec: &ProgSpec, fmt: FixedFmt) -> usize {
    match *spec {
        ProgSpec::Newton { p } => NewtonStepProg { p, fmt }.inputs_evaluator(),
        ProgSpec::CholeskyShare { p } => CholeskyShareProg { p, fmt }.inputs_evaluator(),
        ProgSpec::Solve { p } => SolveProg { p, fmt }.inputs_evaluator(),
        ProgSpec::InverseMasked { p } => InverseMaskedProg { p, fmt }.inputs_evaluator(),
        ProgSpec::Converged { tol } => ConvergedProg { fmt, tol }.inputs_evaluator(),
    }
}

/// Run the garbler half for `spec` (monomorphized dispatch over the five
/// concrete programs).
fn garble_spec(
    spec: &ProgSpec,
    fmt: FixedFmt,
    chan: &mut Channel,
    ot: &mut OtSender,
    bits: &[bool],
    exec_seed: u64,
    gate_ctr: u64,
) -> (u64, u64) {
    match *spec {
        ProgSpec::Newton { p } => {
            run_garbler(chan, ot, &NewtonStepProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::CholeskyShare { p } => {
            run_garbler(chan, ot, &CholeskyShareProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::Solve { p } => {
            run_garbler(chan, ot, &SolveProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::InverseMasked { p } => {
            run_garbler(chan, ot, &InverseMaskedProg { p, fmt }, bits, exec_seed, gate_ctr)
        }
        ProgSpec::Converged { tol } => {
            run_garbler(chan, ot, &ConvergedProg { fmt, tol }, bits, exec_seed, gate_ctr)
        }
    }
}

/// Run the evaluator half for `spec` (center-b side of [`garble_spec`]).
fn evaluate_spec(
    spec: &ProgSpec,
    fmt: FixedFmt,
    chan: &mut Channel,
    ot: &mut OtReceiver,
    bits: &[bool],
    gate_ctr: u64,
) -> (Vec<bool>, u64) {
    match *spec {
        ProgSpec::Newton { p } => {
            run_evaluator(chan, ot, &NewtonStepProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::CholeskyShare { p } => {
            run_evaluator(chan, ot, &CholeskyShareProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::Solve { p } => {
            run_evaluator(chan, ot, &SolveProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::InverseMasked { p } => {
            run_evaluator(chan, ot, &InverseMaskedProg { p, fmt }, bits, gate_ctr)
        }
        ProgSpec::Converged { tol } => {
            run_evaluator(chan, ot, &ConvergedProg { fmt, tol }, bits, gate_ctr)
        }
    }
}

/// Execute `spec` on an in-process [`GcSession`] (both halves on scoped
/// threads) — the [`ProgSpec`]-dispatch twin of the peer client's
/// executors, used by the single-process and loopback center links.
pub fn execute_local(
    session: &mut GcSession,
    spec: &ProgSpec,
    fmt: FixedFmt,
    garbler_bits: &[bool],
    evaluator_bits: &[bool],
) -> (Vec<bool>, ExecStats) {
    match *spec {
        ProgSpec::Newton { p } => {
            session.execute(&NewtonStepProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::CholeskyShare { p } => {
            session.execute(&CholeskyShareProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::Solve { p } => {
            session.execute(&SolveProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::InverseMasked { p } => {
            session.execute(&InverseMaskedProg { p, fmt }, garbler_bits, evaluator_bits)
        }
        ProgSpec::Converged { tol } => {
            session.execute(&ConvergedProg { fmt, tol }, garbler_bits, evaluator_bits)
        }
    }
}

/// Control-frame census of one peer connection: tag byte → frame count,
/// per direction. The custody tests build their proof on this — the only
/// frame that can carry S2 share values is [`WireMsg::ShareInput`], so
/// `sent` containing no `TAG_SHARE_INPUT` entry (and [`WireMsg::GcExec`]
/// carrying handles by construction) means no share material crossed.
#[derive(Clone, Debug, Default)]
pub struct PeerCensus {
    /// Frames center-a sent to center-b (tag byte → count).
    pub sent: BTreeMap<u8, u64>,
    /// Frames center-a received from center-b (tag byte → count).
    pub recv: BTreeMap<u8, u64>,
}

/// Center-a's connection to a remote center-b: the garbler's persistent
/// state (base OTs done once at connect), the shared AND-gate counter
/// both processes advance in lockstep, and the control-frame census.
pub struct PeerGcClient {
    chan: Channel,
    ot_send: OtSender,
    gate_ctr: u64,
    rng_seed: u64,
    execs: u64,
    /// The session epoch claimed in the hello, carried on `SetKey` so
    /// center-b's re-key guard sees the same epoch as the nodes'.
    epoch: u64,
    sent_tags: BTreeMap<u8, u64>,
    recv_tags: BTreeMap<u8, u64>,
}

impl PeerGcClient {
    /// Connect to a `privlogit center-b` at `addr` (retrying for up to
    /// [`PEER_CONNECT_TIMEOUT`]) at session epoch 0 and run the IKNP
    /// base-OT phase.
    ///
    /// The GC link has *no default deadline* — long silent gaps while
    /// the garbler streams gate material are legitimate — but an
    /// explicit `PRIVLOGIT_ROUND_TIMEOUT` applies here too, so an
    /// operator can bound a wedged peer.
    pub fn connect(addr: &str, seed: u64) -> io::Result<PeerGcClient> {
        PeerGcClient::connect_with(addr, seed, PEER_CONNECT_TIMEOUT, 0)
    }

    /// [`connect`](PeerGcClient::connect) with explicit knobs: how long
    /// connect-time retries keep trying (the configured
    /// `--connect-timeout`, so the peer link and the fleet share one
    /// knob instead of a hardcoded constant) and the session epoch
    /// (non-zero when a center resumes from a checkpoint).
    pub fn connect_with(
        addr: &str,
        seed: u64,
        connect_timeout: Duration,
        epoch: u64,
    ) -> io::Result<PeerGcClient> {
        let mut transport = TcpTransport::connect_retry_at_epoch(
            addr,
            wire::ROLE_PEER,
            connect_timeout,
            epoch,
        )?;
        if let Some(deadline) = crate::net::tcp::env_deadline() {
            transport.set_deadline(Some(deadline))?;
        }
        let mut chan = tcp_channel(transport);
        let mut rng = ChaChaRng::from_u64_seed(seed ^ 0x5e55_1011);
        let ot_send = OtSender::setup(&mut chan, &mut rng);
        Ok(PeerGcClient {
            chan,
            ot_send,
            gate_ctr: 0,
            rng_seed: seed,
            execs: 0,
            epoch,
            sent_tags: BTreeMap::new(),
            recv_tags: BTreeMap::new(),
        })
    }

    fn send_ctrl(&mut self, msg: &WireMsg) {
        let body = msg.encode();
        *self.sent_tags.entry(msg.tag()).or_insert(0) += 1;
        // +8 for the u64 length prefix `send_blob` frames with.
        self.chan.stats().note_sent(msg.tag(), body.len() as u64 + 8);
        self.chan.send_blob(&body);
    }

    fn recv_ctrl(&mut self) -> io::Result<WireMsg> {
        let blob = self.chan.try_recv_blob()?;
        let msg = WireMsg::decode(&blob).map_err(io::Error::from)?;
        *self.recv_tags.entry(msg.tag()).or_insert(0) += 1;
        self.chan.stats().note_recv(msg.tag(), blob.len() as u64 + 8);
        Ok(msg)
    }

    /// Receive a control frame, panicking on a vanished peer — the same
    /// loud-failure contract as every [`Channel`] user mid-protocol;
    /// the center CLIs convert the unwind into a clean error exit.
    // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
    fn recv_ctrl_loud(&mut self, expect: &str) -> WireMsg {
        match self.recv_ctrl() {
            Ok(m) => m,
            Err(e) => panic!("center-b peer failed while {expect} was expected: {e}"),
        }
    }

    /// Install the Paillier public material at center-b (session start):
    /// S2 needs the modulus to aggregate, blind and re-encrypt, and the
    /// fixed-point format to size its share words.
    pub fn install_key(&mut self, n: &BigUint, fmt: FixedFmt) -> io::Result<()> {
        // The packing fields stay zero on the peer link: S2 is keyed at
        // fabric build time, before the center derives its packing
        // layout, and the packed Blind frame is self-describing.
        self.send_ctrl(&WireMsg::SetKey {
            n: n.clone(),
            w: fmt.w as u32,
            f: fmt.f,
            epoch: self.epoch,
            pack_k: 0,
            pack_slot_bits: 0,
            pack_max_parts: 0,
        });
        match self.recv_ctrl()? {
            WireMsg::Ack => Ok(()),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("center-b answered SetKey with {other:?}"),
            )),
        }
    }

    /// Relay per-node ciphertext vectors for S2 to `⊕`-aggregate;
    /// returns the aggregated vector.
    pub fn aggregate(&mut self, scale: u32, parts: &[&[Ciphertext]]) -> Vec<Ciphertext> {
        let wire_parts: Vec<Vec<BigUint>> = parts
            .iter()
            .map(|cts| cts.iter().map(|c| c.0.clone()).collect())
            .collect();
        self.send_ctrl(&WireMsg::Aggregate { scale, parts: wire_parts });
        match self.recv_ctrl_loud("the aggregated ciphertexts") {
            WireMsg::Ciphertexts { cts, .. } => cts.into_iter().map(Ciphertext).collect(),
            // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
            other => panic!("center-b answered Aggregate with {other:?}"),
        }
    }

    /// Blind-convert `cts` to additive shares: S2 draws its blinds,
    /// stores its own halves under `handle`, and returns the blinded
    /// ciphertexts for S1 to decrypt into its halves. A `Some(packed)`
    /// metadata describes a slot-packed payload (wire v6): S2
    /// re-validates the layout and draws one blind per slot.
    pub fn blind(
        &mut self,
        handle: u64,
        cts: &[Ciphertext],
        packed: Option<PackedMeta>,
    ) -> Vec<Ciphertext> {
        let wire_cts: Vec<BigUint> = cts.iter().map(|c| c.0.clone()).collect();
        let (packed_k, packed_slot_bits, packed_len, packed_parts) = match packed {
            Some(m) => (m.k, m.slot_bits, m.len as u64, m.parts as u64),
            None => (0, 0, 0, 0),
        };
        self.send_ctrl(&WireMsg::Blind {
            handle,
            cts: wire_cts,
            packed_k,
            packed_slot_bits,
            packed_len,
            packed_parts,
        });
        match self.recv_ctrl_loud("the blinded ciphertexts") {
            WireMsg::Ciphertexts { cts, .. } => cts.into_iter().map(Ciphertext).collect(),
            // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
            other => panic!("center-b answered Blind with {other:?}"),
        }
    }

    /// Install explicit S2 share values under `handle`. **Test drivers
    /// only** — this ships share material across the wire, which a
    /// protocol run never does (the custody census asserts it).
    pub fn share_input(&mut self, handle: u64, vals: &[u128]) {
        self.send_ctrl(&WireMsg::ShareInput { handle, vals: vals.to_vec() });
        match self.recv_ctrl_loud("the share-input acknowledgement") {
            WireMsg::Ack => {}
            // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
            other => panic!("center-b answered ShareInput with {other:?}"),
        }
    }

    /// Send the `GcExec` control frame and stream the garbled program.
    fn garble(
        &mut self,
        spec: &ProgSpec,
        fmt: FixedFmt,
        garbler_bits: &[bool],
        handles: &[u64],
        out_mode: u8,
        out_handle: u64,
    ) -> u64 {
        self.execs += 1;
        let exec_seed = self.rng_seed ^ self.execs.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.send_ctrl(&WireMsg::GcExec {
            prog: spec.kind(),
            p: spec.p() as u32,
            w: fmt.w as u32,
            f: fmt.f,
            tol: spec.tol(),
            gate_ctr: self.gate_ctr,
            handles: handles.to_vec(),
            out_mode,
            out_handle,
        });
        let (new_ctr, ands) = garble_spec(
            spec,
            fmt,
            &mut self.chan,
            &mut self.ot_send,
            garbler_bits,
            exec_seed,
            self.gate_ctr,
        );
        self.gate_ctr = new_ctr;
        ands
    }

    /// Execute one garbled program whose output is revealed; center-b's
    /// evaluator inputs come from its stored share `handles`.
    pub fn execute_reveal(
        &mut self,
        spec: &ProgSpec,
        fmt: FixedFmt,
        garbler_bits: &[bool],
        handles: &[u64],
    ) -> (Vec<bool>, ExecStats) {
        let t0 = Instant::now();
        let ands = self.garble(spec, fmt, garbler_bits, handles, OUT_REVEAL, 0);
        let bits = match self.recv_ctrl_loud("the revealed output bits") {
            WireMsg::GcOut { bits } => bits,
            // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
            other => panic!("center-b sent {other:?} where GcOut was expected"),
        };
        let stats = ExecStats {
            ands,
            ot_bits: eval_arity(spec, fmt) as u64,
            wall: t0.elapsed().as_secs_f64(),
        };
        (bits, stats)
    }

    /// Execute one garbled program whose output center-b keeps as its
    /// own fresh share halves under `out_handle` (Cholesky re-share);
    /// nothing but an acknowledgement comes back.
    pub fn execute_to_share(
        &mut self,
        spec: &ProgSpec,
        fmt: FixedFmt,
        garbler_bits: &[bool],
        handles: &[u64],
        out_handle: u64,
    ) -> ExecStats {
        let t0 = Instant::now();
        let ands = self.garble(spec, fmt, garbler_bits, handles, OUT_SHARE, out_handle);
        match self.recv_ctrl_loud("the share-output acknowledgement") {
            WireMsg::Ack => {}
            // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
            other => panic!("center-b sent {other:?} where Ack was expected"),
        }
        ExecStats {
            ands,
            ot_bits: eval_arity(spec, fmt) as u64,
            wall: t0.elapsed().as_secs_f64(),
        }
    }

    /// Execute the masked-inverse program: center-b assembles and
    /// encrypts its wide outputs itself, subtracts S1's *randomized*
    /// `Enc(C + r)` `corrections`, and returns the finished ciphertexts.
    pub fn execute_encrypt(
        &mut self,
        spec: &ProgSpec,
        fmt: FixedFmt,
        garbler_bits: &[bool],
        handles: &[u64],
        corrections: &[Ciphertext],
    ) -> (Vec<Ciphertext>, ExecStats) {
        let t0 = Instant::now();
        let ands = self.garble(spec, fmt, garbler_bits, handles, OUT_ENCRYPT, 0);
        self.send_ctrl(&WireMsg::Ciphertexts {
            scale: fmt.f,
            secs: 0.0,
            cts: corrections.iter().map(|c| c.0.clone()).collect(),
        });
        let cts = match self.recv_ctrl_loud("the corrected ciphertexts") {
            WireMsg::Ciphertexts { cts, .. } => cts.into_iter().map(Ciphertext).collect(),
            // audit:allow(panic-free): S1-side loud-failure contract; the CLI catches the unwind
            other => panic!("center-b sent {other:?} where ciphertexts were expected"),
        };
        let stats = ExecStats {
            ands,
            ot_bits: eval_arity(spec, fmt) as u64,
            wall: t0.elapsed().as_secs_f64(),
        };
        (cts, stats)
    }

    /// The control-frame census of this connection so far.
    pub fn census(&self) -> PeerCensus {
        PeerCensus { sent: self.sent_tags.clone(), recv: self.recv_tags.clone() }
    }

    /// Per-tag control-frame byte/frame accounting (the GC/OT byte
    /// streams between control frames are untagged and stay in the
    /// aggregate [`bytes_sent`](Self::bytes_sent) /
    /// [`bytes_received`](Self::bytes_received) counters).
    pub fn tag_flows(&self) -> BTreeMap<u8, crate::obs::TagFlow> {
        self.chan.stats().tag_flows()
    }

    /// Bytes sent to center-b so far (control + labels + tables + OT).
    pub fn bytes_sent(&self) -> u64 {
        self.chan.stats().snapshot().0
    }

    /// Bytes received from center-b so far (OT columns + output frames).
    pub fn bytes_received(&self) -> u64 {
        self.chan.stats().snapshot_recv().0
    }
}

impl Drop for PeerGcClient {
    fn drop(&mut self) {
        // Best-effort: let center-b exit its session loop cleanly. The
        // channel panics if the peer is already gone; a panic here (or
        // during unwind) must not abort the process.
        let chan = &mut self.chan;
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            chan.send_blob(&WireMsg::Shutdown.encode());
        }));
    }
}

/// The center-b process: a listening S2 server. Each accepted center-a
/// connection gets a fresh OT session, its own share store and its own
/// randomness stream, and is served to completion (`Shutdown` or
/// disconnect).
pub struct PeerGcServer {
    listener: TcpListener,
    seed: u64,
}

impl PeerGcServer {
    /// Bind to `addr` (port 0 for an ephemeral port). `seed` is mixed
    /// with per-process entropy: S2's blinds ρ must not be predictable
    /// to S1 (a predictable blind lets the key holder unblind the share
    /// conversion), so even identically-configured center-b deployments
    /// get distinct randomness streams. GC evaluation and OT reception
    /// are randomness-insensitive, so replies stay correct either way.
    pub fn bind(addr: &str, seed: u64) -> io::Result<PeerGcServer> {
        Ok(PeerGcServer {
            listener: TcpListener::bind(addr)?,
            seed: seed ^ crate::net::server::entropy_seed(),
        })
    }

    /// The bound address (useful with ephemeral ports).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept one center-a connection and serve it to completion.
    pub fn serve_once(&mut self) -> io::Result<()> {
        let (stream, _) = self.listener.accept()?;
        let mut transport = TcpTransport::accept(stream, wire::ROLE_PEER)?;
        if let Some(deadline) = crate::net::tcp::env_deadline() {
            transport.set_deadline(Some(deadline))?;
        }
        let epoch = transport.peer_epoch;
        self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let session = serve_session(tcp_channel(transport), self.seed, epoch);
        obs::flush();
        session
    }

    /// Serve center-a connections forever (one at a time). A failed
    /// *session* is logged and the next connection awaited; a failed
    /// *accept* means the listener itself is broken and is propagated.
    pub fn serve_forever(&mut self) -> io::Result<()> {
        loop {
            let (stream, _) = self.listener.accept()?;
            self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let seed = self.seed;
            let session = TcpTransport::accept(stream, wire::ROLE_PEER)
                .and_then(|mut t| {
                    if let Some(deadline) = crate::net::tcp::env_deadline() {
                        t.set_deadline(Some(deadline))?;
                    }
                    Ok(t)
                })
                .and_then(|t| {
                    let epoch = t.peer_epoch;
                    serve_session(tcp_channel(t), seed, epoch)
                });
            match session {
                Ok(()) => obs::info(format_args!("center-b session complete")),
                Err(e) => {
                    obs::warn(format_args!("center-b session ended with error: {e}"))
                }
            }
            obs::flush();
        }
    }
}

/// Per-session Paillier material at S2, installed by [`WireMsg::SetKey`].
struct S2Crypto {
    pk: PublicKey,
    fmt: FixedFmt,
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Serve one established center-a connection as a full S2 until
/// `Shutdown` or disconnect: aggregate relayed ciphertexts, blind and
/// keep shares, evaluate garbled programs over the stored shares.
fn serve_session(mut chan: Channel, seed: u64, handshake_epoch: u64) -> io::Result<()> {
    let mut rng = ChaChaRng::from_u64_seed(seed ^ 0x0e1e_2021);
    let mut ot_recv = OtReceiver::setup(&mut chan, &mut rng);
    let mut crypto: Option<S2Crypto> = None;
    // Same re-key rule as the node server: starts at the connector's
    // handshake claim, advances with every accepted SetKey.
    let mut session_epoch = handshake_epoch;
    // S2's share custody: handle → share words. Lives exactly as long
    // as the session; center-a only ever holds the opaque handles.
    let mut store: HashMap<u64, Vec<u128>> = HashMap::new();
    // Trace join keys: the session adopts center-a's id at SetKey (both
    // ends hash the same modulus) and counts per-tag occurrences — the
    // same counters the client side advances, so (session, tag, round)
    // lines up across the two processes with no wire change.
    let mut session_id = 0u64;
    let mut rounds: BTreeMap<u8, u64> = BTreeMap::new();
    loop {
        let blob = match chan.try_recv_blob() {
            Ok(b) => b,
            // EOF at a control boundary: center-a exited; orderly end.
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::UnexpectedEof
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::ConnectionAborted
                ) =>
            {
                return Ok(())
            }
            Err(e) => return Err(e),
        };
        let msg = WireMsg::decode(&blob).map_err(io::Error::from)?;
        let tag = msg.tag();
        let round = {
            let ctr = rounds.entry(tag).or_insert(0);
            let r = *ctr;
            *ctr += 1;
            r
        };
        let mut sp = obs::span("peer.req").tag(tag).round(round);
        if tag != wire::TAG_SET_KEY {
            sp.record_session(session_id);
        }
        let stats = chan.stats();
        let before = sp.active().then(|| {
            sp.record_u64("req_bytes", blob.len() as u64 + 8);
            (stats.snapshot().0, stats.snapshot_recv().0)
        });
        match msg {
            WireMsg::Shutdown => return Ok(()),
            WireMsg::SetKey { n, w, f, epoch, .. } => {
                // The pack_* fields are ignored on the peer link: S2 is
                // keyed before the center derives its packing layout,
                // and every packed Blind frame re-describes its layout.
                // Mirror the node-side re-key rule: a second SetKey on
                // one session would splice key material mid-protocol,
                // unless it is a resume re-key under a strictly
                // advancing session epoch (wire v5). S2's blinds come
                // from the session randomness stream, which is never
                // rewound, so accepting the advancing case cannot
                // replay randomness here.
                if crypto.is_some() && epoch <= session_epoch {
                    return Err(invalid(format!(
                        "center-a sent a second SetKey in one session; re-keying requires \
                         a fresh connection (epoch {epoch} does not advance past \
                         {session_epoch})"
                    )));
                }
                let fmt = crate::net::server::validate_set_key(&n, w, f)?;
                session_id = obs::session_id(&n.to_bytes_le());
                sp.record_session(session_id);
                sp.record_u64("epoch", epoch);
                session_epoch = epoch;
                let n2 = n.mul(&n);
                crypto = Some(S2Crypto { pk: PublicKey::from_modulus(n, n2), fmt });
                chan.send_blob(&WireMsg::Ack.encode());
            }
            WireMsg::ShareInput { handle, vals } => {
                store.insert(handle, vals);
                chan.send_blob(&WireMsg::Ack.encode());
            }
            WireMsg::Aggregate { scale, parts } => {
                let c = crypto
                    .as_ref()
                    .ok_or_else(|| invalid("Aggregate before SetKey".into()))?;
                if parts.is_empty() {
                    return Err(invalid("Aggregate carries no parts".into()));
                }
                // audit:allow(panic-free): parts is checked non-empty just above
                let len = parts[0].len();
                if parts.iter().any(|p| p.len() != len) {
                    return Err(invalid("Aggregate parts have mismatched lengths".into()));
                }
                let t0 = Instant::now();
                let cols: Vec<Vec<Ciphertext>> = parts
                    .into_iter()
                    .map(|p| p.into_iter().map(Ciphertext).collect())
                    .collect();
                let pk = &c.pk;
                let acc: Vec<BigUint> = pool::par_map_indexed(len, pool::threads(), |i| {
                    // audit:allow(panic-free): every part's length was checked equal to len
                    let column: Vec<&Ciphertext> = cols.iter().map(|cts| &cts[i]).collect();
                    pk.add_many(&column).0
                });
                chan.send_blob(
                    &WireMsg::Ciphertexts {
                        scale,
                        secs: t0.elapsed().as_secs_f64(),
                        cts: acc,
                    }
                    .encode(),
                );
            }
            WireMsg::Blind {
                handle,
                cts,
                packed_k,
                packed_slot_bits,
                packed_len,
                packed_parts,
            } => {
                let c =
                    crypto.as_ref().ok_or_else(|| invalid("Blind before SetKey".into()))?;
                let w = c.fmt.w;
                let t0 = Instant::now();
                // Blinds ρ come serially from OUR stream and the b
                // halves below never leave this process. The blind must
                // be a *randomized* encryption: a trivial one is a
                // deterministic factor, and S1 (who sent `cts` and holds
                // the key) could strip it as bl·ct⁻¹ and read ρ — the
                // same leak class as the inverse corrections going the
                // other way. `encrypt_batch` draws randomness serially
                // and fans the modpows out, like the Aggregate arm.
                let (blinded, bvals) = if packed_parts == 0 {
                    let lift = BigUint::one().shl(w - 1); // C = 2^{w-1}
                    let bound = BigUint::one().shl(w + SIGMA);
                    let blinds: Vec<BigUint> =
                        cts.iter().map(|_| lift.add(&rng.below(&bound))).collect();
                    let enc_blinds = c.pk.encrypt_batch(
                        &blinds,
                        &mut ChaChaSource(&mut rng),
                        pool::threads(),
                    );
                    let bvals: Vec<u128> =
                        blinds.iter().map(|blind| blind_b_half(blind, w)).collect();
                    let pk = &c.pk;
                    let blinded: Vec<BigUint> =
                        pool::par_map_indexed(cts.len(), pool::threads(), |i| {
                            // audit:allow(panic-free): i < cts.len(); enc_blinds was built per ct
                            pk.add(&Ciphertext(cts[i].clone()), &enc_blinds[i]).0
                        });
                    (blinded, bvals)
                } else {
                    // Packed conversion (wire v6). The frame describes
                    // its own layout; re-derive it through the same
                    // headroom validation S1 ran, with the claimed
                    // fan-in as the bound, so a bad or hostile layout
                    // is rejected here rather than silently wrapping
                    // our blinds into a neighbouring slot.
                    let codec = PackedCodec::from_wire(
                        c.pk.n.bit_len() as u32,
                        c.fmt,
                        packed_k,
                        packed_slot_bits,
                        packed_parts,
                    )
                    .map_err(|e| invalid(format!("Blind claims a bad packed layout: {e}")))?;
                    let len = packed_len as usize;
                    if len == 0 || cts.len() != codec.cts_needed(len) {
                        return Err(invalid(format!(
                            "packed Blind of {len} values needs {} ciphertexts, got {}",
                            codec.cts_needed(len),
                            cts.len()
                        )));
                    }
                    // One blind per logical slot; the b halves must be
                    // per value, since GcExec later reads one w-bit
                    // share word per logical value from our custody.
                    let (rhos, bvals) =
                        packed_blinds(&mut rng, w, packed_parts as u128, len);
                    let slot_b = packed_slot_bits as usize;
                    let k = packed_k as usize;
                    let masks: Vec<BigUint> = (0..cts.len())
                        .map(|ci| {
                            let lo = ci * k;
                            let hi = lo + codec.slots_in_ct(len, ci);
                            let mut m = BigUint::zero();
                            for i in (lo..hi).rev() {
                                // audit:allow(panic-free): hi <= len and rhos has len entries
                                m = m.shl(slot_b).add(&rhos[i]);
                            }
                            m
                        })
                        .collect();
                    let enc_masks = c.pk.encrypt_batch(
                        &masks,
                        &mut ChaChaSource(&mut rng),
                        pool::threads(),
                    );
                    let pk = &c.pk;
                    let blinded: Vec<BigUint> =
                        pool::par_map_indexed(cts.len(), pool::threads(), |i| {
                            // audit:allow(panic-free): i < cts.len(); enc_masks was built per ct
                            pk.add(&Ciphertext(cts[i].clone()), &enc_masks[i]).0
                        });
                    (blinded, bvals)
                };
                store.insert(handle, bvals);
                chan.send_blob(
                    &WireMsg::Ciphertexts {
                        scale: 0,
                        secs: t0.elapsed().as_secs_f64(),
                        cts: blinded,
                    }
                    .encode(),
                );
            }
            WireMsg::GcExec { prog, p, w, f, tol, gate_ctr, handles, out_mode, out_handle } => {
                let fmt = FixedFmt::try_new(w as usize, f)
                    .map_err(|e| invalid(format!("GcExec carries a bad format: {e}")))?;
                if let Some(c) = &crypto {
                    if c.fmt != fmt {
                        return Err(invalid(format!(
                            "GcExec format {fmt:?} diverges from the session format {:?}",
                            c.fmt
                        )));
                    }
                }
                let spec = ProgSpec::from_parts(prog, p as usize, tol).ok_or_else(|| {
                    invalid(format!("unknown garbled-program kind {prog:#04x}"))
                })?;
                // Evaluator inputs come from OUR share custody.
                let mut eval_bits = Vec::new();
                for h in &handles {
                    let vals = store
                        .get(h)
                        .ok_or_else(|| invalid(format!("unknown share handle {h}")))?;
                    for &v in vals {
                        eval_bits.extend((0..fmt.w).map(|i| (v >> i) & 1 == 1));
                    }
                }
                let expect = eval_arity(&spec, fmt);
                if eval_bits.len() != expect {
                    return Err(invalid(format!(
                        "handles supply {} evaluator bits, program {prog} needs {expect}",
                        eval_bits.len()
                    )));
                }
                let (bits, _ands) =
                    evaluate_spec(&spec, fmt, &mut chan, &mut ot_recv, &eval_bits, gate_ctr);
                match out_mode {
                    OUT_REVEAL => chan.send_blob(&WireMsg::GcOut { bits }.encode()),
                    OUT_SHARE => {
                        // The masked outputs ARE our fresh share halves.
                        store.insert(out_handle, words_of_bits(&bits, fmt.w));
                        chan.send_blob(&WireMsg::Ack.encode());
                    }
                    OUT_ENCRYPT => {
                        let c = crypto
                            .as_ref()
                            .ok_or_else(|| invalid("OUT_ENCRYPT before SetKey".into()))?;
                        let t0 = Instant::now();
                        let wide = InverseMaskedProg { p: p as usize, fmt }.wide();
                        let ys: Vec<BigUint> = words_of_bits(&bits, wide)
                            .into_iter()
                            .map(BigUint::from_u128)
                            .collect();
                        // Encrypt with OUR randomness, then subtract the
                        // corrections S1 sends next.
                        let enc_ys = c.pk.encrypt_batch(
                            &ys,
                            &mut ChaChaSource(&mut rng),
                            pool::threads(),
                        );
                        let corr = match WireMsg::decode(&chan.try_recv_blob()?)
                            .map_err(io::Error::from)?
                        {
                            WireMsg::Ciphertexts { cts, .. } => cts,
                            other => {
                                return Err(invalid(format!(
                                    "center-a sent {other:?} where corrections were expected"
                                )))
                            }
                        };
                        if corr.len() != enc_ys.len() {
                            return Err(invalid(format!(
                                "{} corrections for {} wide outputs",
                                corr.len(),
                                enc_ys.len()
                            )));
                        }
                        // ⊖ inverts the correction mod n²: a non-unit is
                        // a session error here, not a worker panic there.
                        if let Some(bad) =
                            corr.iter().position(|ct| !ct.gcd(&c.pk.n2).is_one())
                        {
                            return Err(invalid(format!(
                                "correction ciphertext {bad} is not invertible mod n²"
                            )));
                        }
                        let pk = &c.pk;
                        let out: Vec<BigUint> =
                            pool::par_map_indexed(enc_ys.len(), pool::threads(), |i| {
                                // audit:allow(panic-free): corr.len() was checked == enc_ys.len()
                                pk.sub(&enc_ys[i], &Ciphertext(corr[i].clone())).0
                            });
                        chan.send_blob(
                            &WireMsg::Ciphertexts {
                                scale: fmt.f,
                                secs: t0.elapsed().as_secs_f64(),
                                cts: out,
                            }
                            .encode(),
                        );
                    }
                    m => return Err(invalid(format!("unknown GcExec output mode {m:#04x}"))),
                }
            }
            other => {
                return Err(invalid(format!(
                    "center-a sent {other:?}, which center-b does not serve"
                )))
            }
        }
        if let Some((s0, r0)) = before {
            sp.record_u64("bytes_sent", stats.snapshot().0 - s0);
            sp.record_u64("bytes_recv", stats.snapshot_recv().0 - r0);
        }
        sp.done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::paillier::Keypair;
    use crate::gc::backend::PlainBackend;
    use crate::mpc::circuits::tri_len;
    use crate::mpc::fabric::{share_vec, u128_of};

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    /// Split-process GC (client garbler ↔ server evaluator over real
    /// loopback TCP) must produce bit-identical outputs to the plain
    /// backend oracle, across repeated executions on one session — with
    /// the evaluator inputs installed as S2-held shares, never as bits
    /// in the `GcExec` frame.
    #[test]
    fn peer_client_server_matches_plain_backend() {
        let mut server = PeerGcServer::bind("127.0.0.1:0", 7).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve_once().unwrap());

        let mut client = PeerGcClient::connect(&addr, 41).unwrap();
        let mut rng = crate::crypto::rng::ChaChaRng::from_u64_seed(9);
        let p = 3;

        for trial in 0..2u64 {
            // A well-conditioned SPD matrix and gradient, as shares.
            let mut tri = Vec::new();
            for i in 0..p {
                for j in 0..=i {
                    tri.push(if i == j { 2.0 + i as f64 } else { 0.25 });
                }
            }
            let g = vec![1.0, -0.5, 0.25];
            let h_shares = share_vec(FMT, &tri, &mut rng);
            let g_shares = share_vec(FMT, &g, &mut rng);
            let mut ga = Vec::new();
            let mut ea = Vec::new();
            for s in h_shares.iter().chain(&g_shares) {
                for i in 0..FMT.w {
                    ga.push((s.a >> i) & 1 == 1);
                    ea.push((s.b >> i) & 1 == 1);
                }
            }
            // This is a both-halves-in-one-hand test driver: install
            // S2's halves explicitly, then execute over the handles.
            let (hh, gh) = (10 * trial + 1, 10 * trial + 2);
            client.share_input(hh, &h_shares.iter().map(|s| s.b).collect::<Vec<_>>());
            client.share_input(gh, &g_shares.iter().map(|s| s.b).collect::<Vec<_>>());
            let spec = ProgSpec::Newton { p };
            let (bits, stats) = client.execute_reveal(&spec, FMT, &ga, &[hh, gh]);
            assert!(stats.ands > 0, "trial {trial}: gates streamed");
            assert_eq!(stats.ot_bits as usize, ea.len());

            // Plain-backend oracle over the same inputs.
            let prog = NewtonStepProg { p, fmt: FMT };
            let mut pb = PlainBackend;
            let expect = prog.run(&mut pb, &ga, &ea);
            assert_eq!(bits, expect, "trial {trial}: remote GC != plain backend");
            assert_eq!(bits.len(), p * FMT.w);
            assert_eq!(tri.len(), tri_len(p));
        }

        let census = client.census();
        assert_eq!(census.sent.get(&wire::TAG_SHARE_INPUT), Some(&4));
        assert_eq!(census.sent.get(&wire::TAG_GC_EXEC), Some(&2));
        // Per-tag byte accounting agrees with the frame census, and the
        // tagged control bytes are a strict subset of the stream total
        // (garbled tables / OT columns stay untagged).
        let flows = client.tag_flows();
        assert_eq!(flows[&wire::TAG_SHARE_INPUT].sent_frames, 4);
        assert_eq!(flows[&wire::TAG_GC_EXEC].sent_frames, 2);
        assert_eq!(flows[&wire::TAG_GC_OUT].recv_frames, 2);
        let ctrl_sent: u64 = flows.values().map(|f| f.sent_bytes).sum();
        assert!(ctrl_sent > 0 && ctrl_sent < client.bytes_sent());
        assert!(client.bytes_sent() > 0 && client.bytes_received() > 0);
        drop(client); // sends Shutdown; server exits cleanly
        server_thread.join().unwrap();
    }

    /// S2's aggregate + blind custody path: center-b folds relayed
    /// ciphertexts and blinds with its own ρ; the decrypted blinded
    /// value recombines with the share it kept (recovered here through a
    /// revealing GC execution, since the b halves never cross the wire).
    #[test]
    fn peer_aggregate_and_blind_share_custody() {
        let mut server = PeerGcServer::bind("127.0.0.1:0", 8).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve_once().unwrap());

        let mut rng = crate::crypto::rng::ChaChaRng::from_u64_seed(11);
        let kp = Keypair::generate(256, &mut rng);
        let mut client = PeerGcClient::connect(&addr, 42).unwrap();
        client.install_key(&kp.pk.n, FMT).unwrap();

        // Two "nodes" encrypt halves of [1.5, -0.25]; S2 aggregates.
        let codec = crate::crypto::fixed::FixedCodec::new(kp.pk.n.clone(), FMT.f);
        let vals = [1.5f64, -0.25];
        let enc = |v: f64, rng: &mut crate::crypto::rng::ChaChaRng| {
            kp.pk.encrypt(&codec.encode(v / 2.0), &mut ChaChaSource(rng))
        };
        let part_a: Vec<Ciphertext> = vals.iter().map(|&v| enc(v, &mut rng)).collect();
        let part_b: Vec<Ciphertext> = vals.iter().map(|&v| enc(v, &mut rng)).collect();
        let agg = client.aggregate(FMT.f, &[&part_a[..], &part_b[..]]);
        assert_eq!(agg.len(), vals.len());
        for (ct, &v) in agg.iter().zip(&vals) {
            assert_eq!(codec.decode(&kp.sk.decrypt(ct)), v, "aggregate decrypts to the sum");
        }

        // Blind conversion of the first aggregate (a 1-element vector,
        // so it can feed the 1-element Converged inputs below): S1's
        // half comes from the blinded decryption, S2's half stays at the
        // server under handle 5.
        let blinded = client.blind(5, &agg[..1], None);
        let mask_w = (1u128 << FMT.w) - 1;
        let a_half = u128_of(&kp.sk.decrypt(&blinded[0])) & mask_w;
        assert_ne!(blinded[0], agg[0], "blinding must change the ciphertext");

        // Recombination proof through a revealing program: Converged
        // compares the value behind handle 5 (a_half + S2's hidden b ≡
        // 1.5) against a freshly-shared scalar. Equal values converge,
        // a far value must not — which can only hold if the S2-held
        // half recombines to exactly the aggregated plaintext.
        let bits_of = |v: u128| (0..FMT.w).map(move |i| (v >> i) & 1 == 1);
        for (other, expect) in [(vals[0], true), (3.0, false)] {
            let sh = share_vec(FMT, &[other], &mut rng);
            let handle = if expect { 7 } else { 8 };
            client.share_input(handle, &[sh[0].b]);
            let mut ga: Vec<bool> = bits_of(a_half).collect();
            ga.extend(bits_of(sh[0].a));
            let (bits, _) = client.execute_reveal(
                &ProgSpec::Converged { tol: 1e-6 },
                FMT,
                &ga,
                &[5, handle],
            );
            assert_eq!(
                bits[0], expect,
                "recombined 1.5 vs {other}: converged bit must be {expect}"
            );
        }

        drop(client);
        server_thread.join().unwrap();
    }

    /// A `GcExec` naming an unknown share handle is a clean session
    /// error on center-b (the server thread returns `Err`, it does not
    /// panic); center-a's stream panic is caught by its CLI layer.
    #[test]
    fn unknown_handle_is_session_error_not_panic() {
        let mut server = PeerGcServer::bind("127.0.0.1:0", 9).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve_once());

        let mut client = PeerGcClient::connect(&addr, 43).unwrap();
        let ga = vec![false; 2 * FMT.w];
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            client.execute_reveal(&ProgSpec::Converged { tol: 1e-6 }, FMT, &ga, &[99])
        }));
        assert!(run.is_err(), "client side aborts loudly mid-program");
        let session = server_thread.join().expect("center-b thread must not panic");
        let err = session.expect_err("unknown handle must fail the session");
        assert!(err.to_string().contains("unknown share handle"), "got: {err}");
        std::mem::forget(client); // its channel is already poisoned
    }
}
