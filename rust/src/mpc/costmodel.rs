//! Calibrated cost model for paper-scale experiments.
//!
//! The paper's largest workloads (SimuX100–SimuX400, Table 2) ran for
//! hours-to-days on the authors' two-PC testbed. Executing every garbled
//! gate for those sizes is pointless busywork — the *relative* protocol
//! costs are fully determined by exact operation counts (the same
//! accounting the paper's §5.2 complexity analysis uses) times measured
//! per-primitive costs. The [`ModelFabric`](super::fabric::ModelFabric)
//! therefore computes identical numerics in plaintext while advancing a
//! virtual clock from this table.
//!
//! Calibration: `cargo bench --bench micro_primitives` measures every
//! primitive on this machine and writes `artifacts/calibration.txt`;
//! [`CostModel::load`] picks it up (falling back to built-in defaults
//! measured on the dev container). Every experiment output labels which
//! backend produced it.

/// Per-primitive costs (seconds) plus a network model.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Garble+evaluate one AND gate (streamed, amortized).
    pub t_and: f64,
    /// One extended OT (evaluator input bit, amortized).
    pub t_ot: f64,
    /// Paillier encryption (full-range randomness).
    pub t_enc: f64,
    /// Homomorphic addition of two ciphertexts.
    pub t_add: f64,
    /// Scalar multiply with a full-width (≈ modulus-size) exponent.
    pub t_scalar_full: f64,
    /// Scalar multiply with a small (fixed-point, ≈ f-bit) exponent —
    /// the PrivLogit-Local "multiplication-by-constant" primitive.
    pub t_scalar_small: f64,
    /// One term of an `Enc(H̃⁻¹)⊗g` row computed by the Straus
    /// multi-exponentiation path (squaring chain and additions
    /// amortized across the row; single-threaded) — what the real
    /// backend actually pays per (row, column) pair, measured as
    /// `apply_hinv_row / p` by the micro-bench. Substantially below
    /// `t_scalar_small`, which times a standalone scalar multiply with
    /// its own full squaring chain.
    pub t_apply_term: f64,
    /// One term of an `Enc(H̃⁻¹)⊗g` row against *slot-packed* row
    /// ciphertexts: the same Straus-amortized multi-exp, but each
    /// ciphertext carries k packed entries, so the squaring chain and
    /// additions amortize over k terms at once. Expected ≈
    /// `t_apply_term / k` plus the shared chain overhead; measured by
    /// the `apply_row_packed` micro-bench.
    pub t_apply_term_packed: f64,
    /// Blinded decryption round (mask + decrypt + unmask).
    pub t_decrypt: f64,
    /// One-way message latency (models the paper's ethernet; applied per
    /// protocol round).
    pub latency: f64,
    /// Bandwidth for the byte-volume term (bytes/sec).
    pub bandwidth: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Defaults measured in this container (1024-bit Paillier modulus,
        // W=40/F=24 fixed point); overridden by artifacts/calibration.txt.
        CostModel {
            t_and: 150e-9,
            t_ot: 250e-9,
            t_enc: 450e-6,
            t_add: 2e-6,
            t_scalar_full: 450e-6,
            t_scalar_small: 40e-6,
            t_apply_term: 12e-6,
            t_apply_term_packed: 7e-6,
            t_decrypt: 900e-6,
            latency: 200e-6,
            bandwidth: 117e6, // ~1 Gb ethernet, the paper's testbed link
        }
    }
}

impl CostModel {
    /// Load calibration written by the `micro_primitives` bench, falling
    /// back to defaults for missing keys.
    pub fn load(path: &str) -> Self {
        let mut m = CostModel::default();
        let Ok(text) = std::fs::read_to_string(path) else {
            return m;
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, val)) = line.split_once('=') else { continue };
            let Ok(v) = val.trim().parse::<f64>() else { continue };
            match key.trim() {
                "t_and" => m.t_and = v,
                "t_ot" => m.t_ot = v,
                "t_enc" => m.t_enc = v,
                "t_add" => m.t_add = v,
                "t_scalar_full" => m.t_scalar_full = v,
                "t_scalar_small" => m.t_scalar_small = v,
                "t_apply_term" => m.t_apply_term = v,
                "t_apply_term_packed" => m.t_apply_term_packed = v,
                "t_decrypt" => m.t_decrypt = v,
                "latency" => m.latency = v,
                "bandwidth" => m.bandwidth = v,
                _ => {}
            }
        }
        m
    }

    /// Default calibration file location.
    pub const CALIBRATION_PATH: &'static str = "artifacts/calibration.txt";
}

/// Cumulative cost ledger, shared by the real and modeled fabrics so
/// reports come out of one code path.
#[derive(Clone, Debug, Default)]
pub struct CostLedger {
    /// Center compute seconds (secure ops; measured or modeled).
    pub center_secs: f64,
    /// Node compute seconds (sum over *rounds* of the max across nodes —
    /// nodes run in parallel in the deployment).
    pub node_secs: f64,
    /// Seconds attributed to the one-time setup phase.
    pub setup_secs: f64,
    /// Bytes sent across node↔center or server↔server boundaries.
    pub bytes: u64,
    /// Bytes received across those boundaries. In a lossless closed
    /// system this mirrors `bytes`; the two directions are kept separate
    /// so the accounting is symmetric and checkable.
    pub bytes_recv: u64,
    /// Real wire bytes a networked fleet measured, center → nodes
    /// ([`crate::net::fleet::RemoteFleet`]; zero for in-process fleets).
    /// Kept apart from `bytes` — which models the *target* deployment's
    /// ciphertext traffic — so the two are never double-counted and the
    /// modeled network term stays comparable across fleet kinds.
    pub fleet_bytes_sent: u64,
    /// Real wire bytes a networked fleet measured, nodes → center.
    pub fleet_bytes_recv: u64,
    /// Fleet-wire traffic broken down per wire tag (both directions,
    /// from the center's perspective). Empty for in-process fleets.
    pub fleet_tag_flows: std::collections::BTreeMap<u8, crate::obs::TagFlow>,
    /// Center-peer control-frame traffic per wire tag (center-a's view;
    /// the raw garbling/OT byte stream is *not* tagged — it stays in
    /// `bytes`/`bytes_recv`). Empty for in-process center links.
    pub peer_tag_flows: std::collections::BTreeMap<u8, crate::obs::TagFlow>,
    /// Nodes a quorum fleet excluded after missed rounds and not
    /// readmitted since (zero for in-process fleets and strict
    /// all-or-abort runs).
    pub excluded_nodes: u64,
    /// Readmission events: previously-excluded nodes restored to live
    /// membership after answering a round-boundary probe.
    pub readmitted_nodes: u64,
    /// Protocol rounds (for the latency term).
    pub rounds: u64,
    /// Paillier operation counts.
    pub paillier_encs: u64,
    /// Homomorphic additions.
    pub paillier_adds: u64,
    /// Scalar multiplications (ciphertext^k).
    pub paillier_scalar: u64,
    /// Blind decryptions.
    pub paillier_decrypts: u64,
    /// Garbled AND gates executed (or modeled).
    pub gc_ands: u64,
    /// OT-extension bits.
    pub ot_bits: u64,
    /// Scratch: per-node seconds within the current parallel round.
    pub round_node_secs: Vec<f64>,
}

impl CostLedger {
    /// Record `secs` of work done by `node` inside the current round.
    pub fn add_node(&mut self, node: usize, secs: f64) {
        if self.round_node_secs.len() <= node {
            self.round_node_secs.resize(node + 1, 0.0);
        }
        self.round_node_secs[node] += secs;
    }

    /// Close a parallel node round: wall time advances by the slowest node.
    pub fn end_node_round(&mut self) {
        let m = self.round_node_secs.iter().cloned().fold(0.0, f64::max);
        self.node_secs += m;
        self.round_node_secs.clear();
    }

    /// Total protocol time including the network model.
    pub fn total_secs(&self, net: &CostModel) -> f64 {
        self.center_secs + self.node_secs + self.network_secs(net)
    }

    /// The network term: latency per round + byte volume / bandwidth.
    pub fn network_secs(&self, net: &CostModel) -> f64 {
        self.rounds as f64 * net.latency + self.bytes as f64 / net.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let m = CostModel::default();
        assert!(m.t_and < m.t_enc, "gates are cheaper than encryptions");
        assert!(
            m.t_scalar_small < m.t_scalar_full,
            "small-exponent scalar mul must be cheaper — PrivLogit-Local depends on it"
        );
        assert!(
            m.t_apply_term < m.t_scalar_small,
            "a Straus-amortized row term must be cheaper than a standalone scalar mul"
        );
        assert!(
            m.t_apply_term_packed < m.t_apply_term,
            "a packed row term amortizes the chain over k slots and must be cheaper"
        );
    }

    #[test]
    fn load_missing_file_falls_back() {
        let m = CostModel::load("/nonexistent/calibration.txt");
        assert_eq!(m.t_and, CostModel::default().t_and);
    }

    #[test]
    fn load_parses_overrides() {
        let dir = std::env::temp_dir().join("privlogit_cal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("calibration.txt");
        std::fs::write(&path, "# cal\nt_and = 1.5e-7\nt_enc=2e-4\nbogus=1\n").unwrap();
        let m = CostModel::load(path.to_str().unwrap());
        assert_eq!(m.t_and, 1.5e-7);
        assert_eq!(m.t_enc, 2e-4);
        assert_eq!(m.t_add, CostModel::default().t_add);
    }

    #[test]
    fn ledger_node_rounds_take_max() {
        let mut l = CostLedger::default();
        l.add_node(0, 1.0);
        l.add_node(1, 3.0);
        l.add_node(2, 2.0);
        l.end_node_round();
        assert_eq!(l.node_secs, 3.0);
        l.add_node(0, 0.5);
        l.end_node_round();
        assert_eq!(l.node_secs, 3.5);
    }

    #[test]
    fn network_term() {
        let mut l = CostLedger::default();
        l.rounds = 10;
        l.bytes = 117_000_000;
        let m = CostModel { latency: 1e-3, bandwidth: 117e6, ..Default::default() };
        let net = l.network_secs(&m);
        assert!((net - (0.01 + 1.0)).abs() < 1e-9);
    }
}
