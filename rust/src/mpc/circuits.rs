//! Matrix-algebra circuit programs for the two Center servers.
//!
//! These are the paper's Type-2 secure computations expressed as
//! data-oblivious word programs (see [`crate::gc::backend`]):
//!
//! * [`cholesky_words`] — Cholesky decomposition (Alg. 2, step 6);
//! * [`tri_solve_words`] — back-substitution `L·Lᵀ·x = g` (Alg. 1, step 9);
//! * the [`GcProgram`] wrappers that recombine the servers' additive
//!   shares in-circuit, run the algebra, and reveal or re-mask outputs.
//!
//! Matrices are symmetric and packed as lower triangles, row-major:
//! `[(0,0), (1,0), (1,1), (2,0), …]`, length `p(p+1)/2`.

use crate::gc::backend::GcBackend;
use crate::gc::exec::GcProgram;
use crate::gc::word::{self, const_word, FixedFmt, Word};

/// Packed lower-triangle length for a `p×p` symmetric matrix.
pub fn tri_len(p: usize) -> usize {
    p * (p + 1) / 2
}

/// Index into the packed lower triangle (`i ≥ j`).
pub fn tri_idx(i: usize, j: usize) -> usize {
    debug_assert!(i >= j);
    i * (i + 1) / 2 + j
}

/// In-circuit Cholesky decomposition of a packed SPD matrix.
///
/// Identical operation order to [`crate::linalg::Matrix::cholesky`]:
/// `p` square roots, `tri_len(p) − p` divisions, `~p³/6` multiplies.
pub fn cholesky_words<B: GcBackend>(
    b: &mut B,
    h: &[Word<B::Wire>],
    p: usize,
    fmt: FixedFmt,
) -> Vec<Word<B::Wire>> {
    assert_eq!(h.len(), tri_len(p));
    let mut l: Vec<Word<B::Wire>> = Vec::with_capacity(tri_len(p));
    for i in 0..p {
        for j in 0..=i {
            // s = h[i][j] − Σ_k<j l[i][k]·l[j][k]
            let mut s = h[tri_idx(i, j)].clone();
            for k in 0..j {
                let prod = word::mul(b, &l[tri_idx(i, k)], &l[tri_idx(j, k)], fmt);
                s = word::sub(b, &s, &prod);
            }
            if i == j {
                l.push(word::sqrt(b, &s, fmt));
            } else {
                let d = l[tri_idx(j, j)].clone();
                l.push(word::div(b, &s, &d, fmt));
            }
        }
    }
    l
}

/// In-circuit solve of `L·Lᵀ·x = g` (forward + backward substitution).
pub fn tri_solve_words<B: GcBackend>(
    b: &mut B,
    l: &[Word<B::Wire>],
    g: &[Word<B::Wire>],
    p: usize,
    fmt: FixedFmt,
) -> Vec<Word<B::Wire>> {
    assert_eq!(l.len(), tri_len(p));
    assert_eq!(g.len(), p);
    // forward: L y = g
    let mut y: Vec<Word<B::Wire>> = Vec::with_capacity(p);
    for i in 0..p {
        let mut s = g[i].clone();
        for (k, yk) in y.iter().enumerate().take(i) {
            let prod = word::mul(b, &l[tri_idx(i, k)], yk, fmt);
            s = word::sub(b, &s, &prod);
        }
        y.push(word::div(b, &s, &l[tri_idx(i, i)], fmt));
    }
    // backward: Lᵀ x = y
    let mut x: Vec<Option<Word<B::Wire>>> = vec![None; p];
    for i in (0..p).rev() {
        let mut s = y[i].clone();
        for (k, xk) in x.iter().enumerate().skip(i + 1) {
            let prod = word::mul(b, &l[tri_idx(k, i)], xk.as_ref().unwrap(), fmt);
            s = word::sub(b, &s, &prod);
        }
        x[i] = Some(word::div(b, &s, &l[tri_idx(i, i)], fmt));
    }
    x.into_iter().map(|w| w.unwrap()).collect()
}

// ---------------------------------------------------------------------
// Input plumbing shared by the programs: each logical value enters as two
// additive shares mod 2^w (one per server) recombined with one in-circuit
// addition.

fn words_from_inputs<B: GcBackend>(
    b: &mut B,
    ga: &[B::Wire],
    ea: &[B::Wire],
    count: usize,
    w: usize,
) -> Vec<Word<B::Wire>> {
    (0..count)
        .map(|i| {
            let a: Word<B::Wire> = ga[i * w..(i + 1) * w].to_vec();
            let x: Word<B::Wire> = ea[i * w..(i + 1) * w].to_vec();
            word::add(b, &a, &x)
        })
        .collect()
}

/// One full secure Newton step: recombine shares of `H` (packed) and `g`,
/// Cholesky-decompose, solve, reveal `Δ = H⁻¹g` in clear.
///
/// Used per-iteration by the secure Newton baseline, and once per
/// iteration by nothing else — its cost is exactly the cost the paper's
/// §5.2 attributes to `O(p³ × iterations)`.
pub struct NewtonStepProg {
    /// Dimensionality.
    pub p: usize,
    /// Fixed-point format.
    pub fmt: FixedFmt,
}

impl GcProgram for NewtonStepProg {
    fn inputs_garbler(&self) -> usize {
        (tri_len(self.p) + self.p) * self.fmt.w
    }
    fn inputs_evaluator(&self) -> usize {
        (tri_len(self.p) + self.p) * self.fmt.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let (p, w) = (self.p, self.fmt.w);
        let nh = tri_len(p);
        let h = words_from_inputs(b, &ga[..nh * w], &ea[..nh * w], nh, w);
        let g = words_from_inputs(b, &ga[nh * w..], &ea[nh * w..], p, w);
        let l = cholesky_words(b, &h, p, self.fmt);
        let x = tri_solve_words(b, &l, &g, p, self.fmt);
        x.into_iter().flatten().collect()
    }
}

/// Cholesky with re-shared output: the garbler additionally inputs one
/// random mask per output word; the circuit reveals `L + mask` to the
/// evaluator (its share), the garbler keeps `−mask`.
///
/// This is `SetupOnce` (Alg. 2) for PrivLogit-Hessian: `Enc(L)` in the
/// paper becomes additive shares held by the two servers.
pub struct CholeskyShareProg {
    /// Dimensionality.
    pub p: usize,
    /// Fixed-point format.
    pub fmt: FixedFmt,
}

impl GcProgram for CholeskyShareProg {
    fn inputs_garbler(&self) -> usize {
        // H shares + one mask word per output entry
        tri_len(self.p) * self.fmt.w * 2
    }
    fn inputs_evaluator(&self) -> usize {
        tri_len(self.p) * self.fmt.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let (p, w) = (self.p, self.fmt.w);
        let nh = tri_len(p);
        let h = words_from_inputs(b, &ga[..nh * w], ea, nh, w);
        let l = cholesky_words(b, &h, p, self.fmt);
        // mask each output with the garbler's random word
        let mut out = Vec::with_capacity(nh * w);
        for (i, li) in l.iter().enumerate() {
            let mask: Word<B::Wire> = ga[(nh + i) * w..(nh + i + 1) * w].to_vec();
            let masked = word::add(b, li, &mask);
            out.extend(masked);
        }
        out
    }
}

/// Back-substitution on shared `L` and shared `g`, revealing `Δ` in clear
/// (the PrivLogit-Hessian per-iteration step — `O(p²)`).
pub struct SolveProg {
    /// Dimensionality.
    pub p: usize,
    /// Fixed-point format.
    pub fmt: FixedFmt,
}

impl GcProgram for SolveProg {
    fn inputs_garbler(&self) -> usize {
        (tri_len(self.p) + self.p) * self.fmt.w
    }
    fn inputs_evaluator(&self) -> usize {
        (tri_len(self.p) + self.p) * self.fmt.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let (p, w) = (self.p, self.fmt.w);
        let nh = tri_len(p);
        let l = words_from_inputs(b, &ga[..nh * w], &ea[..nh * w], nh, w);
        let g = words_from_inputs(b, &ga[nh * w..], &ea[nh * w..], p, w);
        let x = tri_solve_words(b, &l, &g, p, self.fmt);
        x.into_iter().flatten().collect()
    }
}

/// Statistical-masking headroom for wide reveals (bits).
pub const SIGMA: usize = 40;

/// `H⁻¹` with Paillier-ready masked reveal, in one program:
/// recombine `H`, Cholesky, solve against the identity, then for each of
/// the `tri_len(p)` distinct entries output `v + C + r` in a *wide*
/// (w+σ+1)-bit adder, where `C = 2^{w−1}` lifts the value non-negative and
/// `r` is the garbler's (w+σ)-bit statistical mask.
///
/// The evaluator (aggregation server) learns only the masked integers,
/// Paillier-encrypts them, and homomorphically subtracts `Enc(C + r)`
/// supplied by the garbler to obtain `Enc(H⁻¹_{ij})` exactly — the
/// `Enc(H̃⁻¹)` that PrivLogit-Local (Alg. 3, step 2) distributes to nodes.
pub struct InverseMaskedProg {
    /// Dimensionality.
    pub p: usize,
    /// Fixed-point format.
    pub fmt: FixedFmt,
}

impl InverseMaskedProg {
    /// Output width per entry.
    pub fn wide(&self) -> usize {
        self.fmt.w + SIGMA + 1
    }
}

impl GcProgram for InverseMaskedProg {
    fn inputs_garbler(&self) -> usize {
        // H shares + a (w+σ)-bit mask per output entry
        tri_len(self.p) * self.fmt.w + tri_len(self.p) * (self.fmt.w + SIGMA)
    }
    fn inputs_evaluator(&self) -> usize {
        tri_len(self.p) * self.fmt.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let (p, w) = (self.p, self.fmt.w);
        let nh = tri_len(p);
        let wide = self.wide();
        let h = words_from_inputs(b, &ga[..nh * w], ea, nh, w);
        let l = cholesky_words(b, &h, p, self.fmt);
        // Triangular inverse T = L⁻¹ with the reciprocal-diagonal trick
        // (p divisions total, ~p³/6 multiplies), then Z = TᵀT (~p³/6
        // multiplies over the symmetric half). Total ≈ 3× the Cholesky
        // multiply count — the efficient structure the per-column solve
        // (p³ multiplies) wastes.
        let one = const_word(b, self.fmt.encode(1.0), w);
        let recip: Vec<Word<B::Wire>> = (0..p)
            .map(|j| word::div(b, &one, &l[tri_idx(j, j)], self.fmt))
            .collect();
        // t stored packed lower-triangular like l
        let mut t: Vec<Option<Word<B::Wire>>> = vec![None; nh];
        for j in 0..p {
            t[tri_idx(j, j)] = Some(recip[j].clone());
            for i in j + 1..p {
                // s = Σ_{k=j..i-1} l[i][k]·t[k][j]
                let mut s: Option<Word<B::Wire>> = None;
                for k in j..i {
                    let prod = word::mul(
                        b,
                        &l[tri_idx(i, k)],
                        t[tri_idx(k, j)].as_ref().unwrap(),
                        self.fmt,
                    );
                    s = Some(match s {
                        None => prod,
                        Some(acc) => word::add(b, &acc, &prod),
                    });
                }
                let scaled = word::mul(b, &s.unwrap(), &recip[i], self.fmt);
                t[tri_idx(i, j)] = Some(word::neg(b, &scaled));
            }
        }
        // Z = TᵀT (symmetric, keep i ≥ j): z[i][j] = Σ_{k≥i} t[k][i]·t[k][j]
        let mut z: Vec<Option<Word<B::Wire>>> = vec![None; nh];
        for j in 0..p {
            for i in j..p {
                let mut s: Option<Word<B::Wire>> = None;
                for k in i..p {
                    let prod = word::mul(
                        b,
                        t[tri_idx(k, i)].as_ref().unwrap(),
                        t[tri_idx(k, j)].as_ref().unwrap(),
                        self.fmt,
                    );
                    s = Some(match s {
                        None => prod,
                        Some(acc) => word::add(b, &acc, &prod),
                    });
                }
                z[tri_idx(i, j)] = s;
            }
        }
        // wide masked reveal: v_ext + C + r
        let c_lift = 1i128 << (w - 1);
        let mut out = Vec::with_capacity(nh * wide);
        for (idx, zi) in z.into_iter().enumerate() {
            let v = zi.unwrap();
            let vext = word::resize(b, &v, wide);
            let coff = const_word(b, c_lift, wide);
            let lifted = word::add(b, &vext, &coff);
            let mstart = nh * w + idx * (w + SIGMA);
            let mut mask: Word<B::Wire> = ga[mstart..mstart + w + SIGMA].to_vec();
            let zero = b.constant(false);
            mask.resize(wide, zero);
            let masked = word::add(b, &lifted, &mask);
            out.extend(masked);
        }
        out
    }
}

/// Secure convergence check (Alg. 1 step 12): reveal only the single bit
/// `|l_new − l_old| < tol · |l_old|`.
pub struct ConvergedProg {
    /// Fixed-point format.
    pub fmt: FixedFmt,
    /// Relative tolerance (paper: 1e-6).
    pub tol: f64,
}

impl GcProgram for ConvergedProg {
    fn inputs_garbler(&self) -> usize {
        2 * self.fmt.w
    }
    fn inputs_evaluator(&self) -> usize {
        2 * self.fmt.w
    }
    fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
        let w = self.fmt.w;
        let vals = words_from_inputs(b, ga, ea, 2, w);
        let c = word::rel_converged(b, &vals[0], &vals[1], self.tol, self.fmt);
        vec![c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::backend::{CountBackend, PlainBackend};
    use crate::linalg::Matrix;
    use crate::testutil::TestRng;

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    fn random_spd(rng: &mut TestRng, p: usize) -> Matrix {
        let mut b = Matrix::zeros(p, p);
        for v in b.as_mut_slice() {
            *v = rng.gaussian() * 0.3;
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(1.0 + p as f64 * 0.05);
        a
    }

    fn pack_tri(m: &Matrix) -> Vec<f64> {
        let p = m.rows;
        let mut out = Vec::with_capacity(tri_len(p));
        for i in 0..p {
            for j in 0..=i {
                out.push(m[(i, j)]);
            }
        }
        out
    }

    fn to_words(b: &mut PlainBackend, vals: &[f64]) -> Vec<Word<bool>> {
        vals.iter()
            .map(|&v| {
                let raw = FMT.unsigned(FMT.encode(v));
                (0..FMT.w).map(|i| b.constant((raw >> i) & 1 == 1)).collect()
            })
            .collect()
    }

    fn from_word_bits(bits: &[bool], fmt: FixedFmt) -> f64 {
        let mut raw: i128 = 0;
        for (i, &bit) in bits.iter().enumerate() {
            if bit {
                raw |= 1 << i;
            }
        }
        fmt.decode(raw)
    }

    #[test]
    fn tri_packing() {
        assert_eq!(tri_len(4), 10);
        assert_eq!(tri_idx(0, 0), 0);
        assert_eq!(tri_idx(1, 0), 1);
        assert_eq!(tri_idx(1, 1), 2);
        assert_eq!(tri_idx(3, 2), 8);
    }

    /// Circuit Cholesky vs f64 Cholesky on random SPD matrices.
    #[test]
    fn cholesky_circuit_matches_linalg() {
        let mut rng = TestRng::new(10);
        for p in [1, 2, 4, 6] {
            let a = random_spd(&mut rng, p);
            let expect = a.cholesky().unwrap();
            let mut b = PlainBackend;
            let h = to_words(&mut b, &pack_tri(&a));
            let l = cholesky_words(&mut b, &h, p, FMT);
            for i in 0..p {
                for j in 0..=i {
                    let got = from_word_bits(&l[tri_idx(i, j)], FMT);
                    assert!(
                        (got - expect[(i, j)]).abs() < 2e-4,
                        "p={p} L[{i}][{j}]: {got} vs {}",
                        expect[(i, j)]
                    );
                }
            }
        }
    }

    /// Circuit solve vs f64 solve.
    #[test]
    fn tri_solve_circuit_matches_linalg() {
        let mut rng = TestRng::new(11);
        let p = 5;
        let a = random_spd(&mut rng, p);
        let l = a.cholesky().unwrap();
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = l.solve_cholesky(&g);
        let mut b = PlainBackend;
        let lw = to_words(&mut b, &pack_tri(&l));
        let gw = to_words(&mut b, &g);
        let x = tri_solve_words(&mut b, &lw, &gw, p, FMT);
        for i in 0..p {
            let got = from_word_bits(&x[i], FMT);
            assert!((got - expect[i]).abs() < 5e-4, "x[{i}]: {got} vs {}", expect[i]);
        }
    }

    /// The §5.2 complexity claims, verified on exact gate counts:
    /// Newton per-iteration work is Θ(p³) while the solve is Θ(p²).
    #[test]
    fn gate_count_complexity_shape() {
        let counts: Vec<u64> = [4usize, 8, 16]
            .iter()
            .map(|&p| {
                let mut cb = CountBackend::default();
                let prog = NewtonStepProg { p, fmt: FMT };
                let na = prog.inputs_garbler();
                let ga: Vec<Option<bool>> = vec![None; na];
                let ea: Vec<Option<bool>> = vec![None; na];
                prog.run(&mut cb, &ga, &ea);
                cb.ands
            })
            .collect();
        // doubling p should multiply cost by ~8 asymptotically; allow slack
        // for the quadratic/linear terms at these small sizes.
        let r1 = counts[1] as f64 / counts[0] as f64;
        let r2 = counts[2] as f64 / counts[1] as f64;
        assert!(r1 > 2.5, "p: 4→8 cost ratio {r1}");
        assert!(r2 > r1, "super-quadratic growth expected, {r2} vs {r1}");

        // solve-only is much cheaper than the full Newton step at p=16
        let mut cb = CountBackend::default();
        let prog = SolveProg { p: 16, fmt: FMT };
        let ga: Vec<Option<bool>> = vec![None; prog.inputs_garbler()];
        let ea: Vec<Option<bool>> = vec![None; prog.inputs_evaluator()];
        prog.run(&mut cb, &ga, &ea);
        assert!(
            cb.ands * 3 < counts[2],
            "solve ({}) should be ≪ newton step ({})",
            cb.ands,
            counts[2]
        );
    }

    /// Share recombination in-circuit: a+b shares of a value produce the
    /// same Newton step as the value itself.
    #[test]
    fn share_recombination_end_to_end_plain() {
        let mut rng = TestRng::new(12);
        let p = 3;
        let a = random_spd(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();

        let prog = NewtonStepProg { p, fmt: FMT };
        // split every input into random additive shares mod 2^w
        let mut ga_bits = Vec::new();
        let mut ea_bits = Vec::new();
        let push_shared = |v: f64, ga: &mut Vec<bool>, ea: &mut Vec<bool>, rng: &mut TestRng| {
            let raw = FMT.unsigned(FMT.encode(v));
            let share_a = (rng.next_u64() as u128) & ((1u128 << FMT.w) - 1);
            let share_b = (raw.wrapping_sub(share_a)) & ((1u128 << FMT.w) - 1);
            for i in 0..FMT.w {
                ga.push((share_a >> i) & 1 == 1);
            }
            for i in 0..FMT.w {
                ea.push((share_b >> i) & 1 == 1);
            }
        };
        for v in pack_tri(&a) {
            push_shared(v, &mut ga_bits, &mut ea_bits, &mut rng);
        }
        for &v in &g {
            push_shared(v, &mut ga_bits, &mut ea_bits, &mut rng);
        }
        let mut b = PlainBackend;
        let out = prog.run(&mut b, &ga_bits, &ea_bits);
        for i in 0..p {
            let got = from_word_bits(&out[i * FMT.w..(i + 1) * FMT.w], FMT);
            assert!((got - expect[i]).abs() < 5e-4, "Δ[{i}]: {got} vs {}", expect[i]);
        }
    }

    #[test]
    fn converged_prog_plain() {
        let prog = ConvergedProg { fmt: FMT, tol: 1e-4 };
        let mut b = PlainBackend;
        let bits = |v: f64| -> Vec<bool> {
            let raw = FMT.unsigned(FMT.encode(v));
            (0..FMT.w).map(|i| (raw >> i) & 1 == 1).collect()
        };
        // garbler holds values, evaluator holds zero shares
        let zeros = vec![false; FMT.w];
        for (lnew, lold, expect) in
            [(-0.50000001, -0.5, true), (-0.45, -0.5, false), (-0.5, -0.5, true)]
        {
            let mut ga = bits(lnew);
            ga.extend(bits(lold));
            let mut ea = zeros.clone();
            ea.extend(zeros.clone());
            let out = prog.run(&mut b, &ga, &ea);
            assert_eq!(out[0], expect, "converged({lnew}, {lold})");
        }
    }
}
